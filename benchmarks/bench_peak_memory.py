"""Paper Fig. 10/15: peak memory footprint vs the TFLite-order baseline.

Per benchmark graph: baseline (Kahn/TFLite order) peak, SERENITY scheduler
peak, scheduler+rewriting peak — through both the footprint model and the
offset allocator — plus the reduction ratios the paper reports
(1.68x scheduler-only, 1.86x with rewriting, on its original cells).

PR 2 additions: every row carries the allocator-visible plan —
``arena_bytes`` (selected-policy watermark), ``peak_bytes`` (interval lower
bound), their ratio ``arena_peak_ratio`` (1.0 == fragmentation-free), the
winning ``policy``, and ``first_fit_arena`` (the pre-PR single-policy
watermark, which the selected policy must never exceed).

PR 3 addition: ``realized_bytes`` — the live-byte high-water *measured* by
actually executing the rewritten schedule against the planned arena
(``repro.core.executor``); asserted equal to ``peak_bytes``, so the
reported footprint is what the device observes, not an estimate
(DESIGN.md §6).

PR 6 additions: all planning goes through ``plan(g, PlanConfig(...))``,
and the ``pareto_*`` rows trace the recomputation frontier (DESIGN.md
§10): for each randwire cell, the peaks reachable by cloning cheap
producers under a FLOPs budget, as ``flops_ratio:peak_bytes`` points.
``best_peak`` must sit at or below the exact no-recompute optimum — the
rows are deterministic, so any drift trips ``diff_baseline.py``.

PR 8 additions: the ``frontier_*`` rows pin the latency x memory Pareto
frontier of each cell under width-2 concurrency (DESIGN.md §12) as
``makespan:peak_bytes`` points.  The latency-unconstrained endpoint is
asserted equal to the exact serial DP peak (the paper-cell acceptance
criterion), and the min-makespan point is executed against a step-packed
arena so the realized concurrent peak is measured, not estimated.
``diff_baseline.py`` diffs these frontier strings point-by-point: peaks
exactly, makespans with the unit-aware noise floor.
"""

from __future__ import annotations

import time

from repro.core import (
    PlanCache,
    PlanConfig,
    execute_plan,
    kahn_schedule,
    plan,
    plan_arena,
    plan_arena_best,
)
from repro.core.scheduler import pareto_schedule
from repro.graphs import BENCHMARK_GRAPHS, darts_network, randwire_network


def run(csv_rows: list, smoke: bool = False) -> dict:
    ratios_sched, ratios_rw, frag_ratios = [], [], []
    graphs = list(BENCHMARK_GRAPHS.items())
    if smoke:
        graphs = graphs[:2]
    for name, fn in graphs:
        g = fn()
        t0 = time.perf_counter()
        # cache=False: the row's us_per_call times cold scheduling
        base = plan(g, PlanConfig(rewrite=False, state_quota=4000),
                    cache=False)
        rew = plan(g, PlanConfig(rewrite=True, state_quota=4000),
                   cache=False)
        dt = (time.perf_counter() - t0) * 1e6
        kahn_peak = base.baseline_peaks["kahn"]
        kahn_arena = plan_arena_best(g, kahn_schedule(g).order).arena_bytes
        # the pre-PR allocator ran first_fit only, on the same schedule
        first_fit_arena = plan_arena(
            rew.graph, rew.order, policy="first_fit"
        ).arena_bytes
        arena = rew.arena
        assert arena.arena_bytes <= first_fit_arena, (
            f"{name}: selected policy ({arena.policy}) lost to first_fit"
        )
        frag = arena.frag_ratio
        frag_ratios.append(frag)
        r_s = kahn_peak / base.peak_bytes
        r_w = kahn_peak / rew.peak_bytes
        ratios_sched.append(r_s)
        ratios_rw.append(r_w)
        # run the rewritten schedule on the planned arena: the realized
        # high-water is measured from execution, and execute_plan (strict
        # by default) raises if it diverges from the plan
        ex = execute_plan(rew.graph, rew.order, arena, inputs=None)
        csv_rows.append((
            f"peak_memory/{name}", dt,
            f"kahn_kb={kahn_peak/1024:.1f};sched_kb="
            f"{base.peak_bytes/1024:.1f};rewrite_kb={rew.peak_bytes/1024:.1f};"
            f"kahn_arena_kb={kahn_arena/1024:.1f};"
            f"sched_arena_kb={base.arena_bytes/1024:.1f};"
            f"ratio_sched={r_s:.2f};ratio_rw={r_w:.2f};"
            f"arena_bytes={arena.arena_bytes};"
            f"peak_bytes={arena.peak_bytes};"
            f"arena_peak_ratio={frag:.4f};"
            f"policy={arena.policy};"
            f"first_fit_arena={first_fit_arena};"
            f"realized_bytes={ex.realized_peak_bytes}",
        ))
    # full-network rows (PR 4): stacked >=200-node deployments through the
    # hierarchical partition + isomorphic-cell reuse path; exact schedules
    # (asserted) with the same footprint-vs-Kahn accounting as the cells.
    # Execution is covered per cell above — these rows track planning.
    nets = [("randwire_net_4x8", lambda: randwire_network(n_cells=4, n=8))] \
        if smoke else [
            ("randwire_net_32x8", lambda: randwire_network(n_cells=8, n=32)),
            ("darts_net_x6", lambda: darts_network(n_cells=6)),
        ]
    for name, fn in nets:
        g = fn()
        t0 = time.perf_counter()
        rew = plan(g, PlanConfig(rewrite=True), cache=PlanCache())
        dt = (time.perf_counter() - t0) * 1e6
        assert rew.exact, f"{name}: full network fell back from the exact DP"
        kahn_peak = rew.baseline_peaks["kahn"]
        # not folded into the summary geomeans: those mirror the paper's
        # per-cell table, and the full networks would skew the comparison
        r_w = kahn_peak / rew.peak_bytes
        csv_rows.append((
            f"peak_memory/{name}", dt,
            f"nodes={len(rew.graph)};kahn_kb={kahn_peak/1024:.1f};"
            f"rewrite_kb={rew.peak_bytes/1024:.1f};ratio_rw={r_w:.2f};"
            f"arena_bytes={rew.arena.arena_bytes};"
            f"peak_bytes={rew.arena.peak_bytes};"
            f"arena_peak_ratio={rew.arena.frag_ratio:.4f};"
            f"policy={rew.arena.policy};"
            f"seg_cache_hits={rew.seg_cache_hits};exact={int(rew.exact)}",
        ))

    # latency x memory frontier rows (PR 8, DESIGN.md §12): the full
    # width-2 Pareto frontier per cell.  The serial endpoint must equal
    # the exact serial DP peak — the multi-objective search can trade
    # latency for memory but never beat (or lose) the serial optimum —
    # and the min-makespan point is executed against an arena packed with
    # its co-issue steps, asserting the realized concurrent peak.
    for name, fn in graphs:
        g = fn()
        t0 = time.perf_counter()
        front = pareto_schedule(g, max_width=2, state_quota=20_000,
                                on_quota="beam")
        dt = (time.perf_counter() - t0) * 1e6
        serial = plan(g, PlanConfig(rewrite=False, state_quota=4000),
                      cache=False)
        assert front.min_peak.peak_bytes == serial.peak_bytes, (
            f"{name}: frontier endpoint {front.min_peak.peak_bytes} != "
            f"exact serial DP peak {serial.peak_bytes}")
        fast = front.min_makespan
        apl = plan_arena_best(g, fast.order, steps=fast.steps)
        ex = execute_plan(g, fast.order, apl, inputs=None, steps=fast.steps)
        assert ex.realized_peak_bytes == apl.peak_bytes
        pts = "|".join(f"{ms}:{pk}" for ms, pk in front.pairs())
        csv_rows.append((
            f"peak_memory/frontier_{name}", dt,
            f"max_width=2;n_points={len(front.points)};"
            f"exact={int(front.exact)};"
            f"serial_peak={front.min_peak.peak_bytes};"
            f"min_makespan={fast.makespan};"
            f"min_makespan_peak={fast.peak_bytes};"
            f"makespan_stretch="
            f"{front.min_peak.makespan / fast.makespan:.3f};"
            f"frontier={pts};"
            f"realized_fast_bytes={ex.realized_peak_bytes}",
        ))

    # recomputation Pareto rows (PR 6): the peak-vs-FLOPs frontier on the
    # randwire cells, where cloning cheap multi-consumer producers buys
    # peak below the exact no-recompute optimum.  smoke bounds the beam
    # rounds so CI stays fast; the frontier points it does reach are
    # prefixes of the full run's and stay deterministic either way.
    recomp = [("randwire_cifar10", 1), ("randwire_cifar100", 3)] if smoke \
        else [("randwire_cifar10", 6), ("randwire_cifar100", 6)]
    for name, rounds in recomp:
        g = BENCHMARK_GRAPHS[name]()
        t0 = time.perf_counter()
        res = plan(g, PlanConfig(rewrite=True, recompute=True,
                                 flops_budget=1.3, recompute_rounds=rounds,
                                 state_quota=4000), cache=False)
        dt = (time.perf_counter() - t0) * 1e6
        rr = res.recompute_report
        frontier = "|".join(f"{fr:.3f}x:{pk}" for fr, pk, _ in rr.frontier)
        ex = execute_plan(res.graph, res.order, res.arena, inputs=None)
        assert res.peak_bytes <= rr.base_peak_bytes, (
            f"{name}: recompute plan worse than its own base")
        csv_rows.append((
            f"peak_memory/pareto_{name}", dt,
            f"base_peak={rr.base_peak_bytes};best_peak={res.peak_bytes};"
            f"flops_ratio={rr.flops_ratio:.3f};n_clones={rr.n_clones};"
            f"frontier={frontier};"
            f"realized_bytes={ex.realized_peak_bytes}",
        ))

    gmean = lambda xs: (
        __import__("math").exp(sum(__import__("math").log(x) for x in xs)
                               / len(xs))
    )
    summary = {
        "gmean_scheduler_only": gmean(ratios_sched),
        "gmean_with_rewriting": gmean(ratios_rw),
        "gmean_arena_peak_ratio": gmean(frag_ratios),
        "paper_scheduler_only": 1.68,
        "paper_with_rewriting": 1.86,
    }
    csv_rows.append((
        "peak_memory/summary", 0.0,
        ";".join(f"{k}={v:.2f}" if isinstance(v, float) else f"{k}={v}"
                 for k, v in summary.items()),
    ))
    return summary
