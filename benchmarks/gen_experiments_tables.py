"""Generate the EXPERIMENTS.md roofline/dry-run tables from artifacts.

    PYTHONPATH=src python -m benchmarks.gen_experiments_tables > /tmp/tables.md
"""

from __future__ import annotations

from benchmarks.bench_roofline import derive, load_cells


def fmt(x, nd=3):
    if x is None:
        return "-"
    return f"{x:.{nd}f}"


def main() -> None:
    cells = load_cells()
    rows = []
    skips = []
    for r in cells:
        d = derive(r)
        if d is None:
            skips.append(r)
        else:
            d["_mem"] = r.get("memory_analysis", {})
            rows.append(d)

    print("### Baseline roofline table (single-pod 16x16, probe-corrected)\n")
    print("| arch | shape | tC (s) | tM (s) | tX (s) | dominant | useful-FLOPs | frac |")
    print("|---|---|---|---|---|---|---|---|")
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"])):
        if d["mesh"] != "pod16x16" or d["label"] != "baseline":
            continue
        print(f"| {d['arch']} | {d['shape']} | {fmt(d['t_compute_s'])} "
              f"| {fmt(d['t_memory_s'])} | {fmt(d['t_collective_s'])} "
              f"| {d['dominant']} | {fmt(d['useful_flops_ratio'])} "
              f"| {fmt(d['roofline_fraction'], 4)} |")
    print("\n### Multi-pod (2x16x16) shard-proof (compile + memory per chip; "
          "costs uncorrected — scan bodies counted once)\n")
    print("| arch | shape | compile (s) | args GB/chip | temp GB/chip | dominant(raw) |")
    print("|---|---|---|---|---|---|")
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"])):
        if d["mesh"] != "pod2x16x16":
            continue
        print(f"| {d['arch']} | {d['shape']} | {fmt(d['compile_s'], 1)} "
              f"| {fmt(d['hbm_per_chip_gb'], 2)} "
              f"| {fmt(d['temp_per_chip_gb'], 2)} | {d['dominant']} |")
    print("\n### Skipped cells\n")
    for s in skips:
        if s["mesh"] == "pod16x16":
            print(f"- {s['arch']} x {s['shape']}: {s['skip_reason']}")
    print("\n### Perf-variant cells\n")
    print("| arch | shape | variant | tC (s) | tM (s) | tX (s) | dominant | frac |")
    print("|---|---|---|---|---|---|---|---|")
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"], d["label"])):
        if d["label"] == "baseline" or d["mesh"] != "pod16x16":
            continue
        print(f"| {d['arch']} | {d['shape']} | {d['label']} "
              f"| {fmt(d['t_compute_s'])} | {fmt(d['t_memory_s'])} "
              f"| {fmt(d['t_collective_s'])} | {d['dominant']} "
              f"| {fmt(d['roofline_fraction'], 4)} |")


if __name__ == "__main__":
    main()
