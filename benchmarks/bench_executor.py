"""Wall-clock executor benchmark: us/step across execution strategies.

For each paper cell (and the stacked >=200-node full networks in the
non-smoke run) the same ``(graph, order, plan)`` triple is executed five
ways and timed end to end (fresh arena per call — the serving steady
state; min over repetitions):

  ``eager_slice_us``  slice-per-node Python loop, one arena op dispatch per
                      read/write (the pre-fusion hot path)
  ``eager_fused_us``  fused alias-chain loop (DESIGN.md §11): chain members
                      forward values in registers, one store — a single
                      chain-kernel launch for contiguous elementwise runs —
                      per region
  ``jit_slice_us``    the slice-per-node program traced once into XLA
                      (cached on the plan), arena donated
  ``jit_fused_us``    the fused program, same whole-program jit — the fused
                      executor's fast path
  ``ref_jit_us``      ``jax.jit(reference_fn(g))``: the unscheduled
                      baseline, XLA plans the memory

Every timed strategy is first verified: eager paths bit-equal to
``run_reference`` and realized peak/extent == planned.  The acceptance
gate of the fused-execution PR is asserted here: on at least one paper
cell the fused executor (steady-state jit) must run **>= 2x** faster than
the slice-per-node hot path (``fused_speedup = eager_slice_us /
jit_fused_us``).

A second section drives the continuous-batching decode server
(``repro.launch.serve``) over a smoke model in both step modes and
reports per-token service time — ``executor/decode_serial`` vs
``executor/decode_vmap`` (the bucketed arena->arena batched program).

Rows land in ``BENCH_baseline.json``; ``diff_baseline.py`` tripwires the
``executor/`` duration columns at >2x with a unit-aware noise floor and
exact-diffs the fusion-coverage counts (``n_regions``/``max_chain``).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import PlanConfig, compile_plan, plan, reference_fn, run_reference

_REGRESSION_GATE = 2.0


def _bench_us(fn, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())          # warm (trace + compile)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _graph_rows(csv_rows: list, smoke: bool) -> dict:
    import jax

    from repro.graphs import BENCHMARK_GRAPHS, darts_network, randwire_network

    cases = [("darts_imagenet_cell", BENCHMARK_GRAPHS["darts_imagenet_cell"])]
    if not smoke:
        cases += [(n, BENCHMARK_GRAPHS[n])
                  for n in ("swiftnet_cell_c", "randwire_cifar10")]
        cases += [
            ("randwire_net_32x8", lambda: randwire_network(n_cells=8, n=32)),
            ("darts_net_x6", lambda: darts_network(n_cells=6)),
        ]
    reps = 3 if smoke else 5
    speedups: dict[str, float] = {}
    for name, mk in cases:
        res = plan(mk(), PlanConfig(), cache=False)
        g, order, apl = res.graph, res.order, res.arena
        prog_s = compile_plan(g, order, apl, fuse=False)
        prog_f = compile_plan(g, order, apl, fuse=True)

        # correctness first: both eager paths bit-equal to the reference,
        # realized footprint identical to the plan
        ref = run_reference(g)
        for prog, tag in ((prog_s, "slice"), (prog_f, "fused")):
            r = prog.run()
            assert r.realized_matches_plan, f"{name}/{tag}: footprint diverged"
            for k, v in ref.items():
                np.testing.assert_array_equal(
                    np.asarray(v), np.asarray(r.outputs[k]),
                    err_msg=f"{name}/{tag}: output {k} != run_reference")

        ext = prog_s.resolve_ext(None)
        rows = {
            "eager_slice": _bench_us(lambda: prog_s.run().outputs, reps),
            "eager_fused": _bench_us(lambda: prog_f.run().outputs, reps),
            "jit_slice": _bench_us(lambda: prog_s.run(jit=True).outputs,
                                   reps),
            "jit_fused": _bench_us(lambda: prog_f.run(jit=True).outputs,
                                   reps),
        }
        rfn = jax.jit(reference_fn(g))
        rows["ref_jit"] = _bench_us(lambda: rfn(ext), reps)
        speedup = rows["eager_slice"] / rows["jit_fused"]
        speedups[name] = speedup
        max_chain = max(len(r) for r in prog_f.regions)
        csv_rows.append((
            f"executor/step_{name}", rows["jit_fused"],
            f"eager_slice_us={rows['eager_slice']:.0f};"
            f"eager_fused_us={rows['eager_fused']:.0f};"
            f"jit_slice_us={rows['jit_slice']:.0f};"
            f"jit_fused_us={rows['jit_fused']:.0f};"
            f"ref_jit_us={rows['ref_jit']:.0f};"
            f"fused_speedup={speedup:.2f};"
            f"n_nodes={len(order)};n_regions={prog_f.n_regions};"
            f"n_fused={prog_f.n_fused_nodes};max_chain={max_chain};"
            f"arena_bytes={apl.arena_bytes}",
        ))
    cells = [n for n in speedups if not n.endswith(("_32x8", "_x6"))]
    best = max(speedups[n] for n in cells)
    assert best >= _REGRESSION_GATE, (
        f"fused executor gate: expected >= {_REGRESSION_GATE}x over the "
        f"slice-per-node hot path on at least one paper cell, best was "
        f"{best:.2f}x ({ {n: round(speedups[n], 2) for n in cells} })")
    return speedups


def _decode_rows(csv_rows: list, smoke: bool) -> dict:
    import jax

    import repro.configs as configs
    from repro.launch.serve import (
        plan_decode_arena,
        run_server,
        synth_requests,
    )
    from repro.models.zoo import build_model

    cfg = dataclasses.replace(configs.smoke("llama3.2-1b"),
                              name="llama3.2-1b-exec-bench",
                              vocab_size=4096)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_req, prompt, gen = (4, 8, 4) if smoke else (8, 16, 8)
    smax = prompt + gen
    dplan = plan_decode_arena(model, 1, smax)
    budget = 16 * dplan["arena_bytes"]    # roomy: measure decode, not queueing

    out = {}
    for mode in ("serial", "vmap"):
        # one throwaway run absorbs the prefill/decode jit tracing
        run_server(model, params,
                   synth_requests(2, prompt, gen, cfg.vocab_size, seed=1),
                   smax=smax, budget_bytes=budget, step_mode=mode, warm=1)
        reqs = synth_requests(n_req, prompt, gen, cfg.vocab_size, seed=7)
        m = run_server(model, params, reqs, smax=smax, budget_bytes=budget,
                       step_mode=mode, warm=2)
        assert m["n_served"] == n_req and m["n_rejected"] == 0
        tok_us = m["wall_s"] / max(m["n_tokens"], 1) * 1e6
        out[mode] = tok_us
        csv_rows.append((
            f"executor/decode_{mode}", m["wall_s"] * 1e6,
            f"tok_us={tok_us:.0f};n_tokens={m['n_tokens']};"
            f"tok_per_s={m['tok_per_s']:.1f};steps={m['steps']};"
            f"max_concurrent={m['max_concurrent']};"
            f"peak_reserved_bytes={m['peak_reserved_bytes']};"
            f"arena_bytes={m['arena_bytes']}",
        ))
    return out


def run(csv_rows: list, smoke: bool = False) -> dict:
    speedups = _graph_rows(csv_rows, smoke)
    decode = _decode_rows(csv_rows, smoke)
    return {
        "fused_speedups": speedups,
        "decode_tok_us": decode,
        "gate": _REGRESSION_GATE,
    }


if __name__ == "__main__":
    rows: list = []
    summary = run(rows, smoke=True)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(summary)
