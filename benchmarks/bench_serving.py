"""Multi-tenant serving: pooled admission vs one-arena-per-request.

Two sections (DESIGN.md §9):

* **Co-residency on the paper's workloads** — K copies of a cell's optimal
  arena plan co-packed by ``plan_shared_arena``; the joint extent must be
  strictly below the sum of the standalone extents (the members' transient
  slack is shared on the serial timeline).  Asserted.

* **Serving load generator** — the same request stream driven through the
  continuous-batching decode server twice under one byte budget: admission
  by pooled co-residency accounting vs the naive baseline that reserves a
  full standalone arena per request.  Reports throughput, p50/p99 request
  latency, peak reserved bytes and admitted concurrency; asserts the
  pooled server sustains **>= 2x** the naive baseline's concurrency.

Rows land in the smoke JSON / ``BENCH_baseline.json``;
``diff_baseline.py`` treats the latency and peak-bytes columns with the
same >2x unit-aware tripwire as the scheduling-time rows.
"""

from __future__ import annotations

import dataclasses
import time

from repro.core import PlanCache, plan, plan_shared_arena


def _coresidency_rows(csv_rows: list, smoke: bool) -> dict:
    from repro.graphs import BENCHMARK_GRAPHS

    names = ["darts_imagenet_cell"] if smoke else \
        ["darts_imagenet_cell", "swiftnet_cell_a", "randwire_cifar10"]
    k = 4
    out = {}
    for name in names:
        g = BENCHMARK_GRAPHS[name]()
        res = plan(g, cache=PlanCache())
        t0 = time.perf_counter()
        sh = plan_shared_arena([res.arena] * k)
        dt = (time.perf_counter() - t0) * 1e6
        assert sh.arena_bytes < sh.sum_member_bytes, (
            f"{name}: co-residency found no slack to share "
            f"({sh.arena_bytes} !< {sh.sum_member_bytes})")
        ratio = sh.sum_member_bytes / sh.arena_bytes
        out[name] = ratio
        csv_rows.append((
            f"serving/coresidency_{name}", dt,
            f"members={k};member_arena_bytes={res.arena.arena_bytes};"
            f"joint_arena_bytes={sh.arena_bytes};"
            f"sum_member_bytes={sh.sum_member_bytes};"
            f"saved_bytes={sh.saved_bytes};"
            f"sharing_ratio={ratio:.3f};policy={sh.policy}",
        ))
    return out


def _metrics_row(tag: str, dt_us: float, m: dict) -> tuple:
    return (
        f"serving/{tag}", dt_us,
        f"n_served={m['n_served']};n_rejected={m['n_rejected']};"
        f"n_tokens={m['n_tokens']};tok_per_s={m['tok_per_s']:.1f};"
        f"p50_ms={m['p50_ms']:.1f};p99_ms={m['p99_ms']:.1f};"
        f"max_concurrent={m['max_concurrent']};"
        f"peak_reserved_bytes={m['peak_reserved_bytes']};"
        f"budget_bytes={m['budget_bytes']};"
        f"arena_bytes={m['arena_bytes']};"
        f"persistent_bytes={m['persistent_bytes']};"
        f"transient_bytes={m['transient_bytes']};"
        f"warm_hits={m['warm_hits']}",
    )


def run(csv_rows: list, smoke: bool = False) -> dict:
    ratios = _coresidency_rows(csv_rows, smoke)

    import jax

    import repro.configs as configs
    from repro.launch.serve import (
        plan_decode_arena,
        run_server,
        synth_requests,
    )
    from repro.models.zoo import build_model

    # A vocab-heavy decode shape: the logits buffer is the classic per-step
    # transient that dwarfs a short-context KV state — exactly the slack
    # co-residency shares.  (The full-config ratio is even more extreme:
    # llama3.2-1b's 128k-vocab logits are ~0.5 MB/request.)
    cfg = dataclasses.replace(configs.smoke("llama3.2-1b"),
                              name="llama3.2-1b-serve-bench",
                              vocab_size=8192)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_req, prompt, gen = (8, 8, 4) if smoke else (16, 16, 8)
    smax = prompt + gen
    plan = plan_decode_arena(model, 1, smax)

    # budget: exactly what K co-resident requests need jointly
    k_target = 6
    joint = plan_shared_arena([plan["plan"]] * k_target)
    budget = joint.arena_bytes

    def load(pooled: bool) -> dict:
        reqs = synth_requests(n_req, prompt, gen, cfg.vocab_size, seed=7)
        t0 = time.perf_counter()
        m = run_server(model, params, reqs, smax=smax, budget_bytes=budget,
                       pooled=pooled, warm=2)
        m["bench_wall_s"] = time.perf_counter() - t0
        return m

    # absorb prefill/decode jit compilation before the measured runs, so
    # the reported latencies are service time, not tracing time
    run_server(model, params, synth_requests(1, prompt, gen,
                                             cfg.vocab_size, seed=1),
               smax=smax, budget_bytes=budget, pooled=True)
    naive = load(pooled=False)
    pooled = load(pooled=True)
    csv_rows.append(_metrics_row("naive", naive["bench_wall_s"] * 1e6, naive))
    csv_rows.append(_metrics_row("pooled", pooled["bench_wall_s"] * 1e6,
                                 pooled))
    assert naive["n_served"] == pooled["n_served"] == n_req
    assert pooled["max_concurrent"] >= 2 * naive["max_concurrent"], (
        f"pooled admission sustained {pooled['max_concurrent']} concurrent "
        f"requests vs naive {naive['max_concurrent']} under the same "
        f"{budget} byte budget — expected >= 2x")
    assert pooled["peak_reserved_bytes"] <= budget
    assert naive["peak_reserved_bytes"] <= budget

    return {
        "coresidency_sharing_ratios": ratios,
        "budget_bytes": budget,
        "naive_concurrency": naive["max_concurrent"],
        "pooled_concurrency": pooled["max_concurrent"],
        "concurrency_gain": pooled["max_concurrent"]
        / max(naive["max_concurrent"], 1),
    }


if __name__ == "__main__":
    rows: list = []
    summary = run(rows, smoke=True)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(summary)
