"""Multi-tenant serving: pooled admission vs one-arena-per-request.

Two sections (DESIGN.md §9):

* **Co-residency on the paper's workloads** — K copies of a cell's optimal
  arena plan co-packed by ``plan_shared_arena``; the joint extent must be
  strictly below the sum of the standalone extents (the members' transient
  slack is shared on the serial timeline).  Asserted.

* **Serving load generator** — the same request stream driven through the
  continuous-batching decode server twice under one byte budget: admission
  by pooled co-residency accounting vs the naive baseline that reserves a
  full standalone arena per request.  Reports throughput, p50/p99 request
  latency, peak reserved bytes and admitted concurrency; asserts the
  pooled server sustains **>= 2x** the naive baseline's concurrency.

* **Pareto request classes** (PR 8, DESIGN.md §12) — the latency x memory
  frontier of a paper cell mapped onto admission classes
  (:func:`repro.runtime.pool.pareto_class_plans`): a ``latency`` request
  leases the min-makespan point with pinned transients, a ``memory``
  request the min-peak point, and the pool admits each against the same
  byte budget — so the memory class sustains strictly more concurrency.
  The decode server runs the same trade-off live: a mixed-class request
  stream whose per-class measured p50 and per-class lease bytes land as a
  measured two-point ``frontier=`` row (``<p50>ms:<bytes>``).

* **Degraded mode** (DESIGN.md §13) — the mixed-class stream re-run under
  a scripted mid-run 2x budget shrink (``FaultSpec("budget_shrink")``):
  the ``serving/degraded_shrink`` row records preemptions, spilled bytes,
  re-admissions, the degradation-ladder rung counts and the p99 under
  pressure; the run asserts no request is lost and the realized arena
  never exceeds the instantaneous budget.

Rows land in the smoke JSON / ``BENCH_baseline.json``;
``diff_baseline.py`` treats the latency and peak-bytes columns with the
same >2x unit-aware tripwire as the scheduling-time rows, and diffs
``frontier=`` strings point-by-point (peaks exact, united latencies with
the noise floor).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import PlanCache, plan, plan_shared_arena
from repro.core.allocator import resident_bytes
from repro.core.scheduler import pareto_schedule
from repro.runtime.pool import ArenaPool, pareto_class_plans


def _coresidency_rows(csv_rows: list, smoke: bool) -> dict:
    from repro.graphs import BENCHMARK_GRAPHS

    names = ["darts_imagenet_cell"] if smoke else \
        ["darts_imagenet_cell", "swiftnet_cell_a", "randwire_cifar10"]
    k = 4
    out = {}
    for name in names:
        g = BENCHMARK_GRAPHS[name]()
        res = plan(g, cache=PlanCache())
        t0 = time.perf_counter()
        sh = plan_shared_arena([res.arena] * k)
        dt = (time.perf_counter() - t0) * 1e6
        assert sh.arena_bytes < sh.sum_member_bytes, (
            f"{name}: co-residency found no slack to share "
            f"({sh.arena_bytes} !< {sh.sum_member_bytes})")
        ratio = sh.sum_member_bytes / sh.arena_bytes
        out[name] = ratio
        csv_rows.append((
            f"serving/coresidency_{name}", dt,
            f"members={k};member_arena_bytes={res.arena.arena_bytes};"
            f"joint_arena_bytes={sh.arena_bytes};"
            f"sum_member_bytes={sh.sum_member_bytes};"
            f"saved_bytes={sh.saved_bytes};"
            f"sharing_ratio={ratio:.3f};policy={sh.policy}",
        ))
    return out


def _pareto_pool_rows(csv_rows: list, smoke: bool) -> dict:
    """Frontier-point-per-request-class admission on a paper cell.

    Deterministic end to end: the frontier, both class plans, the budget
    and the synchronous pool admissions are all pure functions of the
    graph, so every column exact-diffs against the baseline.
    """
    from repro.graphs import BENCHMARK_GRAPHS

    name = "swiftnet_cell_a"
    g = BENCHMARK_GRAPHS[name]()
    t0 = time.perf_counter()
    front = pareto_schedule(g, max_width=2, state_quota=20_000)
    plans = pareto_class_plans(g, front)
    dt = (time.perf_counter() - t0) * 1e6
    lat_extent = resident_bytes(plans["latency"])[1]
    mem_extent = resident_bytes(plans["memory"])[1]
    assert lat_extent == plans["latency"].arena_bytes, \
        "pinned latency plan must lease its whole arena"

    def admitted(klass: str, budget: int) -> int:
        pool = ArenaPool(budget, overlap="none")
        pool.register_pareto("cell", plans)
        count = 0
        while True:
            t = pool.submit(g, key="cell", klass=klass)
            if t.lease is None:
                break
            count += 1
        return count

    # one budget, two admission classes: how many of each fit
    budget = 4 * plans["latency"].arena_bytes
    n_lat = admitted("latency", budget)
    n_mem = admitted("memory", budget)
    assert n_mem > n_lat, (
        f"{name}: memory class should out-pack latency class "
        f"({n_mem} !> {n_lat})")
    csv_rows.append((
        f"serving/pareto_pool_{name}", dt,
        f"n_frontier_points={len(front.points)};"
        f"latency_makespan={front.min_makespan.makespan};"
        f"memory_makespan={front.min_peak.makespan};"
        f"latency_lease_bytes={lat_extent};"
        f"memory_lease_bytes={mem_extent};"
        f"memory_peak_bytes={plans['memory'].peak_bytes};"
        f"budget_bytes={budget};"
        f"admitted_latency={n_lat};admitted_memory={n_mem}",
    ))
    return {"admitted_latency": n_lat, "admitted_memory": n_mem}


def _metrics_row(tag: str, dt_us: float, m: dict) -> tuple:
    return (
        f"serving/{tag}", dt_us,
        f"n_served={m['n_served']};n_rejected={m['n_rejected']};"
        f"n_tokens={m['n_tokens']};tok_per_s={m['tok_per_s']:.1f};"
        f"p50_ms={m['p50_ms']:.1f};p99_ms={m['p99_ms']:.1f};"
        f"max_concurrent={m['max_concurrent']};"
        f"peak_reserved_bytes={m['peak_reserved_bytes']};"
        f"budget_bytes={m['budget_bytes']};"
        f"arena_bytes={m['arena_bytes']};"
        f"persistent_bytes={m['persistent_bytes']};"
        f"transient_bytes={m['transient_bytes']};"
        f"warm_hits={m['warm_hits']}",
    )


def run(csv_rows: list, smoke: bool = False) -> dict:
    ratios = _coresidency_rows(csv_rows, smoke)
    classes = _pareto_pool_rows(csv_rows, smoke)

    import jax

    import repro.configs as configs
    from repro.launch.serve import (
        plan_decode_arena,
        run_server,
        synth_requests,
    )
    from repro.models.zoo import build_model

    # A vocab-heavy decode shape: the logits buffer is the classic per-step
    # transient that dwarfs a short-context KV state — exactly the slack
    # co-residency shares.  (The full-config ratio is even more extreme:
    # llama3.2-1b's 128k-vocab logits are ~0.5 MB/request.)
    cfg = dataclasses.replace(configs.smoke("llama3.2-1b"),
                              name="llama3.2-1b-serve-bench",
                              vocab_size=8192)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_req, prompt, gen = (8, 8, 4) if smoke else (16, 16, 8)
    smax = prompt + gen
    plan = plan_decode_arena(model, 1, smax)

    # budget: exactly what K co-resident requests need jointly
    k_target = 6
    joint = plan_shared_arena([plan["plan"]] * k_target)
    budget = joint.arena_bytes

    def load(pooled: bool) -> dict:
        reqs = synth_requests(n_req, prompt, gen, cfg.vocab_size, seed=7)
        t0 = time.perf_counter()
        m = run_server(model, params, reqs, smax=smax, budget_bytes=budget,
                       pooled=pooled, warm=2)
        m["bench_wall_s"] = time.perf_counter() - t0
        return m

    # absorb prefill/decode jit compilation before the measured runs, so
    # the reported latencies are service time, not tracing time
    run_server(model, params, synth_requests(1, prompt, gen,
                                             cfg.vocab_size, seed=1),
               smax=smax, budget_bytes=budget, pooled=True)
    naive = load(pooled=False)
    pooled = load(pooled=True)
    csv_rows.append(_metrics_row("naive", naive["bench_wall_s"] * 1e6, naive))
    csv_rows.append(_metrics_row("pooled", pooled["bench_wall_s"] * 1e6,
                                 pooled))
    assert naive["n_served"] == pooled["n_served"] == n_req
    assert pooled["max_concurrent"] >= 2 * naive["max_concurrent"], (
        f"pooled admission sustained {pooled['max_concurrent']} concurrent "
        f"requests vs naive {naive['max_concurrent']} under the same "
        f"{budget} byte budget — expected >= 2x")
    assert pooled["peak_reserved_bytes"] <= budget
    assert naive["peak_reserved_bytes"] <= budget

    # mixed Pareto-class stream through the same pooled server: half the
    # requests admit as the pinned latency class, half as the tight memory
    # class; per-class measured p50 + per-class lease bytes land as a
    # measured two-point frontier row (latency point first)
    mixed = synth_requests(n_req, prompt, gen, cfg.vocab_size, seed=9,
                           latency_frac=0.5)
    t0 = time.perf_counter()
    cm = run_server(model, params, mixed, smax=smax, budget_bytes=budget,
                    pooled=True, warm=2)
    cm_wall = time.perf_counter() - t0
    served = [r for r in mixed if not r.rejected and r.done_s]
    by_class = {k: sorted(r.latency_s for r in served if r.klass == k)
                for k in ("latency", "memory")}
    assert cm["n_served"] == n_req
    assert set(cm["admitted_by_class"]) == {"latency", "memory"}
    lat_bytes = plan["arena_bytes"]           # pinned: whole arena leased
    mem_bytes = plan["resident_extent"]       # tight: resident region only
    p50 = {k: 1e3 * float(np.percentile(v, 50)) if v else 0.0
           for k, v in by_class.items()}
    csv_rows.append((
        "serving/pareto_classes", cm_wall * 1e6,
        f"n_served={cm['n_served']};"
        f"admitted_latency={cm['admitted_by_class'].get('latency', 0)};"
        f"admitted_memory={cm['admitted_by_class'].get('memory', 0)};"
        f"latency_lease_bytes={lat_bytes};memory_lease_bytes={mem_bytes};"
        f"p50_latency_class_ms={p50['latency']:.1f};"
        f"p50_memory_class_ms={p50['memory']:.1f};"
        f"frontier={p50['latency']:.1f}ms:{lat_bytes}|"
        f"{p50['memory']:.1f}ms:{mem_bytes};"
        f"peak_reserved_bytes={cm['peak_reserved_bytes']};"
        f"budget_bytes={cm['budget_bytes']}",
    ))

    # degraded mode (DESIGN.md §13): the same mixed-class stream with a
    # scripted mid-run 2x budget shrink.  The server walks the degradation
    # ladder — preempt-and-downgrade, exact vmap buckets, priority
    # preemption — instead of failing; the row records how much spilled,
    # how many came back, and what the shrink cost in tail latency.
    from repro.runtime import ChaosController, FaultPlan, FaultSpec

    deg = synth_requests(n_req, prompt, gen, cfg.vocab_size, seed=11,
                         latency_frac=0.5, priorities=(0, 1))
    chaos = ChaosController(FaultPlan([
        FaultSpec("budget_shrink", tick=3, factor=0.5)]))
    t0 = time.perf_counter()
    # start from 2x the pooled budget so the halving lands back at it:
    # every admitted request stays representable post-shrink (the smoke
    # decode's logits transient dwarfs the KV state, so halving the tight
    # budget itself would leave no room for even one standalone request)
    dm = run_server(model, params, deg, smax=smax,
                    budget_bytes=2 * budget, pooled=True, warm=2,
                    chaos=chaos)
    dm_wall = time.perf_counter() - t0
    assert dm["n_served"] + dm["n_rejected"] == n_req, \
        "degraded run lost a request (neither served nor rejected)"
    assert dm["n_served"] == n_req, (
        f"post-shrink budget still fits every request, so the ladder must "
        f"carry all of them to completion (served {dm['n_served']}, "
        f"reject codes {dm['reject_codes']})")
    assert dm["max_over_budget_bytes"] <= 0, (
        f"arena bytes exceeded the instantaneous budget by "
        f"{dm['max_over_budget_bytes']} during the shrink")
    assert dm["budget_shrinks"] >= 1
    csv_rows.append((
        "serving/degraded_shrink", dm_wall * 1e6,
        f"n_served={dm['n_served']};n_rejected={dm['n_rejected']};"
        f"n_preempted={dm['n_preempted']};spill_bytes={dm['spill_bytes']};"
        f"n_readmitted={dm['n_readmitted']};"
        f"p50_ms={dm['p50_ms']:.1f};p99_ms={dm['p99_ms']:.1f};"
        f"budget_bytes={2 * budget};"
        f"min_budget_bytes={dm['min_budget_bytes']};"
        f"peak_reserved_bytes={dm['peak_reserved_bytes']};"
        f"ladder_replan={dm['ladder']['replan']};"
        f"ladder_shrink_buckets={dm['ladder']['shrink_buckets']};"
        f"ladder_preempt={dm['ladder']['preempt']}",
    ))

    return {
        "pareto_admitted_by_class": classes,
        "coresidency_sharing_ratios": ratios,
        "budget_bytes": budget,
        "naive_concurrency": naive["max_concurrent"],
        "pooled_concurrency": pooled["max_concurrent"],
        "degraded_preemptions": dm["n_preempted"],
        "degraded_spill_bytes": dm["spill_bytes"],
        "degraded_p99_ms": dm["p99_ms"],
        "concurrency_gain": pooled["max_concurrent"]
        / max(naive["max_concurrent"], 1),
    }


if __name__ == "__main__":
    rows: list = []
    summary = run(rows, smoke=True)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(summary)
