"""Paper Fig. 13 + Table 2: scheduling time, plus the speed paths this
repo adds on top of the paper:

  * engine comparison — the scalar DP (`engine='python'`) vs the vectorized
    bitmask DP (`engine='numpy'`) vs the per-level `auto` dispatch on the
    RandWire workloads, asserting identical peaks *and* that `auto` never
    picks a path >1.5x slower than the best engine;
  * branch-and-bound pruning — states expanded by the bounded search
    (`bnb=True`, the default) vs the pre-bound reference DP (`bnb=False`)
    on the largest graphs both finish, asserting the >=5x reduction the
    pruning layer is for;
  * full networks — stacked >=200-node RandWire/DARTS deployments through
    the whole pipeline (hierarchical partition + isomorphic-cell reuse),
    asserting exact schedules (no beam fallback) in well under the paper's
    one-minute budget;
  * plan cache — cold pipeline run vs warm content-addressed cache hit;
  * arena planning — the event-driven offset allocator vs the seed's
    rebuild-and-sort live-list scan on serving-scale decode-state graphs
    (thousands of persistent buffers), cold vs warm through the plan cache.

Table 2 reports: plain DP on the 62-node SwiftNet = N/A (infeasible);
(1)+(2) = 56.5 s; (1)+(2)+(3) = 37.9 s (no rewriting).  We reproduce the
*shape* of that result: plain DP hits the state quota (reported as
'timeout'), DC makes it tractable, budgeting speeds it further.
"""

from __future__ import annotations

import time

from repro.core import (
    Graph,
    PlanCache,
    PlanConfig,
    SearchTimeout,
    dp_schedule,
    kahn_schedule,
    plan,
    plan_arena_best,
)
from repro.core.allocator import _plan_arena_reference
from repro.graphs import (
    BENCHMARK_GRAPHS,
    darts_network,
    randwire_graph,
    randwire_network,
    swiftnet_network,
)


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def _best_of(fn, reps):
    best, out = float("inf"), None
    for _ in range(reps):
        out, dt = _time(fn)
        best = min(best, dt)
    return out, best


def _decode_state_graph(n_buffers: int) -> Graph:
    """The serving decode-arena shape (`repro.launch.serve.plan_decode_arena`
    without the jax dependency): ``n_buffers`` persistent cache buffers, all
    live across the step, plus two transient activations chained off them."""
    specs = [
        dict(name=f"buf{i}", op="cache", size_bytes=4096 + 64 * (i % 7),
             preds=[])
        for i in range(n_buffers)
    ]
    specs.append(dict(name="hidden", op="act", size_bytes=8192,
                      preds=list(range(n_buffers))))
    specs.append(dict(name="logits", op="act", size_bytes=65536,
                      preds=[len(specs) - 1]))
    return Graph.build(specs, name=f"decode_state_{n_buffers}")


def run(csv_rows: list, smoke: bool = False) -> dict:
    results = {}
    # best-of-N on both engines: the ratio of true minima is the honest
    # engine comparison on a machine with background load
    reps = 1 if smoke else 7

    # --- engine comparison: scalar vs vectorized vs auto dispatch ---------
    # the auto engine must never pick a path meaningfully slower than the
    # best fixed engine — the regression this row exists to catch (the old
    # static node-count crossover made numpy 2.5x slower on RandWire-16)
    for n in ((16,) if smoke else (16, 32)):
        gw = randwire_graph(seed=10, n=n)
        eng_reps = max(reps, 3)   # best-of >= 3: ms-scale runs are jittery
        ref, t_py = _best_of(
            lambda: dp_schedule(gw, state_quota=200_000, engine="python"),
            eng_reps)
        vec, t_np = _best_of(
            lambda: dp_schedule(gw, state_quota=200_000, engine="numpy"),
            eng_reps)
        sel, t_auto = _best_of(
            lambda: dp_schedule(gw, state_quota=200_000, engine="auto"),
            eng_reps)
        assert (ref.peak_bytes, ref.final_bytes) == (vec.peak_bytes,
                                                    vec.final_bytes)
        assert (ref.peak_bytes, ref.final_bytes) == (sel.peak_bytes,
                                                    sel.final_bytes)
        t_best = min(t_py, t_np)
        # few-millisecond searches are timer noise; above that, auto must
        # stay within 1.5x of the better fixed engine
        assert t_auto <= max(1.5 * t_best, 5e-3), (
            f"auto engine {t_auto:.4f}s vs best {t_best:.4f}s on randwire{n}"
        )
        speedup = t_py / max(t_np, 1e-12)
        results[f"engine_speedup_rw{n}"] = f"{speedup:.1f}x"
        csv_rows.append((
            f"scheduling_time/randwire{n}_engine", t_np * 1e6,
            f"python_s={t_py:.4f};numpy_s={t_np:.4f};auto_s={t_auto:.4f};"
            f"speedup={speedup:.1f};"
            f"peak_kb={vec.peak_bytes // 1024};peaks_equal=1",
        ))
    gw = randwire_graph(seed=10, n=16 if smoke else 32)

    # --- branch-and-bound pruning: states expanded vs the pre-bound DP ----
    # measured on the largest single-cell graphs both searches finish; the
    # dominance + incumbent + lower-bound layer must cut expansions >= 5x
    # on the 62-node SwiftNet (the acceptance gate for the pruning rework)
    prune_graphs = [("swiftnet62", swiftnet_network(), 5.0)]
    if not smoke:
        prune_graphs.append(
            ("darts54", BENCHMARK_GRAPHS["darts_imagenet_cell"](), 5.0))
    for pname, gp, min_ratio in prune_graphs:
        bounded, t_b = _time(
            lambda: dp_schedule(gp, state_quota=400_000, bnb=True))
        legacy, t_l = _time(
            lambda: dp_schedule(gp, state_quota=400_000, bnb=False))
        assert bounded.peak_bytes == legacy.peak_bytes, pname
        ratio = legacy.n_states_expanded / max(bounded.n_states_expanded, 1)
        assert ratio >= min_ratio, (
            f"{pname}: bnb expanded {bounded.n_states_expanded} vs legacy "
            f"{legacy.n_states_expanded} ({ratio:.1f}x < {min_ratio}x)"
        )
        results[f"bnb_states_ratio_{pname}"] = f"{ratio:.1f}x"
        csv_rows.append((
            f"scheduling_time/{pname}_bnb_pruning", t_b * 1e6,
            f"bnb_expanded={bounded.n_states_expanded};"
            f"legacy_expanded={legacy.n_states_expanded};"
            f"states_ratio={ratio:.1f};bnb_s={t_b:.4f};legacy_s={t_l:.4f};"
            f"peak_kb={bounded.peak_bytes // 1024};peaks_equal=1",
        ))

    # --- full networks: stacked >=200-node deployments, exact, < 60 s -----
    nets = [
        ("randwire_net_8x16", randwire_network(n_cells=8, n=16)),
    ] if smoke else [
        ("randwire_net_32x8", randwire_network(n_cells=8, n=32)),
        ("darts_net_x6", darts_network(n_cells=6)),
        ("randwire_net_32x8_mixed",
         randwire_network(n_cells=8, seed=[10, 11, 12, 13, 10, 11, 12, 13])),
    ]
    for nname, gn in nets:
        pc = PlanCache()
        res, dt = _time(lambda: plan(
            gn, PlanConfig(compute_baselines=False), cache=pc))
        assert res.exact, f"{nname}: beam/heuristic fallback in full network"
        assert dt < 60.0, f"{nname}: {dt:.1f}s breaks the one-minute budget"
        results[f"fullnet_{nname}"] = f"{dt:.2f}s"
        csv_rows.append((
            f"scheduling_time/{nname}_fullnet", dt * 1e6,
            f"nodes={len(res.graph)};seconds={dt:.3f};"
            f"states_expanded={res.n_states_expanded};"
            f"peak_kb={res.peak_bytes // 1024};"
            f"segments={len(res.segments)};"
            f"seg_cache_hits={res.seg_cache_hits};exact={int(res.exact)}",
        ))

    # --- plan cache: cold pipeline vs warm content-addressed hit ----------
    pc = PlanCache()
    cold_res, t_cold = _time(lambda: plan(gw, cache=pc))
    warm_res, t_warm = _best_of(lambda: plan(gw, cache=pc), 5)
    assert warm_res.order == cold_res.order
    cache_speedup = t_cold / max(t_warm, 1e-12)
    results["cache_speedup"] = f"{cache_speedup:.0f}x"
    csv_rows.append((
        f"scheduling_time/randwire{n}_plancache", t_warm * 1e6,
        f"cold_ms={t_cold * 1e3:.2f};warm_us={t_warm * 1e6:.1f};"
        f"speedup={cache_speedup:.0f};"
        f"hits={pc.stats.hits};misses={pc.stats.misses}",
    ))

    # --- arena planning: event-driven sweep vs the seed live-list scan ----
    # comparison size keeps the O(n^2 log n) reference affordable; the
    # scale size shows the sweep holding milliseconds at serving scale
    n_cmp = 256 if smoke else 2048
    n_big = 2048 if smoke else 10240
    g_cmp = _decode_state_graph(n_cmp)
    order_cmp = kahn_schedule(g_cmp).order
    # best-of-3/5 even in smoke: single-shot timings of millisecond-scale
    # planning are dominated by GC pauses / machine load
    legacy, t_legacy = _best_of(
        lambda: _plan_arena_reference(g_cmp, order_cmp), 3)
    new_plan, t_sweep = _best_of(
        lambda: plan_arena_best(g_cmp, order_cmp), max(reps, 5))
    assert new_plan.arena_bytes <= legacy.arena_bytes
    arena_speedup = t_legacy / max(t_sweep, 1e-12)
    results["arena_plan_speedup"] = f"{arena_speedup:.1f}x"
    csv_rows.append((
        f"scheduling_time/arena_plan{n_cmp}_legacy_vs_sweep", t_sweep * 1e6,
        f"legacy_s={t_legacy:.4f};sweep_s={t_sweep:.4f};"
        f"speedup={arena_speedup:.1f};n_buffers={n_cmp + 2};"
        f"arena_mb={new_plan.arena_bytes / 1e6:.2f};"
        f"policy={new_plan.policy}",
    ))

    g_big = _decode_state_graph(n_big)
    order_big = kahn_schedule(g_big).order
    apc = PlanCache()
    cold_plan, t_acold = _time(
        lambda: plan_arena_best(g_big, order_big))
    apc.put(g_big, ("bench.arena",), cold_plan)

    def _warm_plan():
        hit = apc.get(g_big, ("bench.arena",))
        assert hit is not None
        return hit

    warm_plan, t_awarm = _best_of(_warm_plan, 5)
    assert warm_plan.arena_bytes == cold_plan.arena_bytes
    csv_rows.append((
        f"scheduling_time/arena_plan{n_big}_cold_vs_warm", t_awarm * 1e6,
        f"cold_ms={t_acold * 1e3:.2f};warm_us={t_awarm * 1e6:.1f};"
        f"speedup={t_acold / max(t_awarm, 1e-12):.0f};"
        f"n_buffers={n_big + 2};policy={cold_plan.policy}",
    ))

    # --- Table 2 ablation: (1) plain DP, (2) +divide&conquer, (3) +budget -
    ablation: dict = {}
    g = swiftnet_network()
    # (1) plain DP with a CI-scale quota -> expected infeasible on a *wide*
    # graph (paper Table 2's N/A row; the stacked-cell swiftnet is narrow
    # enough for plain DP, so the wide RandWire WS(48,...) shows the blowup)
    wide = randwire_graph(seed=7, n=24 if smoke else 48)
    quota = 20_000 if smoke else 200_000
    try:
        _, dt = _time(lambda: dp_schedule(wide, state_quota=quota))
        ablation["dp_only_wide"] = f"{dt:.2f}s"
    except SearchTimeout:
        ablation["dp_only_wide"] = "N/A(quota)"
    try:
        _, dt = _time(lambda: dp_schedule(g, state_quota=quota))
        ablation["dp_only"] = f"{dt:.2f}s"
    except SearchTimeout:
        ablation["dp_only"] = "N/A(quota)"

    # (1)+(2) divide and conquer, exact per segment
    _, dt = _time(lambda: plan(g, PlanConfig(
        rewrite=False, adaptive_budget=False, state_quota=None,
        compute_baselines=False, exact_threshold=10**9,
    ), cache=False))
    ablation["dp_dc"] = f"{dt:.2f}s"

    # (1)+(2)+(3) + budgeting
    _, dt = _time(lambda: plan(g, PlanConfig(
        rewrite=False, state_quota=4000, compute_baselines=False,
    ), cache=False))
    ablation["dp_dc_budget"] = f"{dt:.2f}s"

    # with rewriting (more nodes, paper: 7.2h -> 111.9s)
    _, dt = _time(lambda: plan(g, PlanConfig(
        rewrite=True, state_quota=4000, compute_baselines=False,
    ), cache=False))
    ablation["dp_dc_budget_rw"] = f"{dt:.2f}s"

    csv_rows.append((
        "scheduling_time/swiftnet62_ablation", 0.0,
        ";".join(f"{k}={v}" for k, v in ablation.items()),
    ))

    # Fig. 13: per-network scheduling times (cold, cache disabled)
    graphs = list(BENCHMARK_GRAPHS.items())
    if smoke:
        graphs = graphs[:2]
    for name, fn in graphs:
        gg = fn()
        res, dt = _time(lambda: plan(gg, PlanConfig(
            rewrite=True, state_quota=4000, compute_baselines=False,
        ), cache=False))
        csv_rows.append((
            f"scheduling_time/{name}", dt * 1e6,
            f"seconds={dt:.3f};nodes={len(res.graph)}",
        ))
    results.update(ablation)
    return results
