"""Paper Fig. 13 + Table 2: scheduling time, plus the two speed paths this
repo adds on top of the paper:

  * engine comparison — the seed scalar DP (`engine='python'`) vs the
    vectorized bitmask DP (`engine='numpy'`) on the RandWire N=32 workload,
    asserting identical peaks;
  * plan cache — cold pipeline run vs warm content-addressed cache hit.

Table 2 reports: plain DP on the 62-node SwiftNet = N/A (infeasible);
(1)+(2) = 56.5 s; (1)+(2)+(3) = 37.9 s (no rewriting).  We reproduce the
*shape* of that result: plain DP hits the state quota (reported as
'timeout'), DC makes it tractable, budgeting speeds it further.
"""

from __future__ import annotations

import time

from repro.core import PlanCache, SearchTimeout, dp_schedule, schedule
from repro.graphs import BENCHMARK_GRAPHS, randwire_graph, swiftnet_network


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def _best_of(fn, reps):
    best, out = float("inf"), None
    for _ in range(reps):
        out, dt = _time(fn)
        best = min(best, dt)
    return out, best


def run(csv_rows: list, smoke: bool = False) -> dict:
    results = {}
    # best-of-N on both engines: the ratio of true minima is the honest
    # engine comparison on a machine with background load
    reps = 1 if smoke else 7

    # --- engine comparison: seed scalar DP vs vectorized bitmask DP -------
    n = 16 if smoke else 32
    gw = randwire_graph(seed=10, n=n)
    ref, t_py = _best_of(
        lambda: dp_schedule(gw, state_quota=200_000, engine="python"), reps)
    vec, t_np = _best_of(
        lambda: dp_schedule(gw, state_quota=200_000, engine="numpy"), reps)
    assert (ref.peak_bytes, ref.final_bytes) == (vec.peak_bytes,
                                                vec.final_bytes)
    speedup = t_py / max(t_np, 1e-12)
    results["engine_speedup"] = f"{speedup:.1f}x"
    csv_rows.append((
        f"scheduling_time/randwire{n}_engine", t_np * 1e6,
        f"python_s={t_py:.4f};numpy_s={t_np:.4f};speedup={speedup:.1f};"
        f"peak_kb={vec.peak_bytes // 1024};peaks_equal=1",
    ))

    # --- plan cache: cold pipeline vs warm content-addressed hit ----------
    pc = PlanCache()
    cold_res, t_cold = _time(lambda: schedule(gw, cache=pc))
    warm_res, t_warm = _best_of(lambda: schedule(gw, cache=pc), 5)
    assert warm_res.order == cold_res.order
    cache_speedup = t_cold / max(t_warm, 1e-12)
    results["cache_speedup"] = f"{cache_speedup:.0f}x"
    csv_rows.append((
        f"scheduling_time/randwire{n}_plancache", t_warm * 1e6,
        f"cold_ms={t_cold * 1e3:.2f};warm_us={t_warm * 1e6:.1f};"
        f"speedup={cache_speedup:.0f};"
        f"hits={pc.stats.hits};misses={pc.stats.misses}",
    ))

    # --- Table 2 ablation: (1) plain DP, (2) +divide&conquer, (3) +budget -
    ablation: dict = {}
    g = swiftnet_network()
    # (1) plain DP with a CI-scale quota -> expected infeasible on a *wide*
    # graph (paper Table 2's N/A row; the stacked-cell swiftnet is narrow
    # enough for plain DP, so the wide RandWire WS(48,...) shows the blowup)
    wide = randwire_graph(seed=7, n=24 if smoke else 48)
    quota = 20_000 if smoke else 200_000
    try:
        _, dt = _time(lambda: dp_schedule(wide, state_quota=quota))
        ablation["dp_only_wide"] = f"{dt:.2f}s"
    except SearchTimeout:
        ablation["dp_only_wide"] = "N/A(quota)"
    try:
        _, dt = _time(lambda: dp_schedule(g, state_quota=quota))
        ablation["dp_only"] = f"{dt:.2f}s"
    except SearchTimeout:
        ablation["dp_only"] = "N/A(quota)"

    # (1)+(2) divide and conquer, exact per segment
    _, dt = _time(lambda: schedule(
        g, rewrite=False, adaptive_budget=False, state_quota=None,
        compute_baselines=False, exact_threshold=10**9, cache=False,
    ))
    ablation["dp_dc"] = f"{dt:.2f}s"

    # (1)+(2)+(3) + budgeting
    _, dt = _time(lambda: schedule(
        g, rewrite=False, state_quota=4000, compute_baselines=False,
        cache=False,
    ))
    ablation["dp_dc_budget"] = f"{dt:.2f}s"

    # with rewriting (more nodes, paper: 7.2h -> 111.9s)
    _, dt = _time(lambda: schedule(
        g, rewrite=True, state_quota=4000, compute_baselines=False,
        cache=False,
    ))
    ablation["dp_dc_budget_rw"] = f"{dt:.2f}s"

    csv_rows.append((
        "scheduling_time/swiftnet62_ablation", 0.0,
        ";".join(f"{k}={v}" for k, v in ablation.items()),
    ))

    # Fig. 13: per-network scheduling times (cold, cache disabled)
    graphs = list(BENCHMARK_GRAPHS.items())
    if smoke:
        graphs = graphs[:2]
    for name, fn in graphs:
        gg = fn()
        res, dt = _time(lambda: schedule(
            gg, rewrite=True, state_quota=4000, compute_baselines=False,
            cache=False,
        ))
        csv_rows.append((
            f"scheduling_time/{name}", dt * 1e6,
            f"seconds={dt:.3f};nodes={len(res.graph)}",
        ))
    results.update(ablation)
    return results
