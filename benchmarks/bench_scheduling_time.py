"""Paper Fig. 13 + Table 2: scheduling time and the ablation of
divide-and-conquer (2) and adaptive soft budgeting (3) over plain DP (1).

Table 2 reports: plain DP on the 62-node SwiftNet = N/A (infeasible);
(1)+(2) = 56.5 s; (1)+(2)+(3) = 37.9 s (no rewriting).  We reproduce the
*shape* of that result: plain DP hits the state quota (reported as
'timeout'), DC makes it tractable, budgeting speeds it further.
"""

from __future__ import annotations

import time

from repro.core import SearchTimeout, dp_schedule, schedule
from repro.graphs import BENCHMARK_GRAPHS, randwire_graph, swiftnet_network


def _time(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(csv_rows: list) -> dict:
    g = swiftnet_network()
    results = {}

    # (1) plain DP with a CI-scale quota -> expected infeasible on a *wide*
    # graph (paper Table 2's N/A row; the stacked-cell swiftnet is narrow
    # enough for plain DP, so the wide RandWire WS(48,...) shows the blowup)
    wide = randwire_graph(seed=7, n=48)
    try:
        _, dt = _time(lambda: dp_schedule(wide, state_quota=200_000))
        results["dp_only_wide"] = f"{dt:.2f}s"
    except SearchTimeout:
        results["dp_only_wide"] = "N/A(quota)"
    try:
        _, dt = _time(lambda: dp_schedule(g, state_quota=200_000))
        results["dp_only"] = f"{dt:.2f}s"
    except SearchTimeout:
        results["dp_only"] = "N/A(quota)"

    # (1)+(2) divide and conquer, exact per segment
    _, dt = _time(lambda: schedule(
        g, rewrite=False, adaptive_budget=False, state_quota=None,
        compute_baselines=False, exact_threshold=10**9,
    ))
    results["dp_dc"] = f"{dt:.2f}s"

    # (1)+(2)+(3) + budgeting
    _, dt = _time(lambda: schedule(
        g, rewrite=False, state_quota=4000, compute_baselines=False,
    ))
    results["dp_dc_budget"] = f"{dt:.2f}s"

    # with rewriting (more nodes, paper: 7.2h -> 111.9s)
    _, dt = _time(lambda: schedule(
        g, rewrite=True, state_quota=4000, compute_baselines=False,
    ))
    results["dp_dc_budget_rw"] = f"{dt:.2f}s"

    csv_rows.append((
        "scheduling_time/swiftnet62_ablation", 0.0,
        ";".join(f"{k}={v}" for k, v in results.items()),
    ))

    # Fig. 13: per-network scheduling times
    for name, fn in BENCHMARK_GRAPHS.items():
        gg = fn()
        res, dt = _time(lambda: schedule(
            gg, rewrite=True, state_quota=4000, compute_baselines=False,
        ))
        csv_rows.append((
            f"scheduling_time/{name}", dt * 1e6,
            f"seconds={dt:.3f};nodes={len(res.graph)}",
        ))
    return results
