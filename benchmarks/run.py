"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_peak_memory      Fig. 10/15  peak footprint vs TFLite order
  bench_offchip_traffic  Fig. 11     Belady off-chip traffic sweep
  bench_footprint_trace  Fig. 12     SwiftNet-A running footprint
  bench_scheduling_time  Fig. 13/T2  D&C + soft-budget ablation
  bench_roofline         (ours)      dry-run roofline table (§Roofline)
  bench_jaxpr_sched      (ours)      SERENITY-on-jaxpr liveness gains
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import (
        bench_footprint_trace,
        bench_jaxpr_sched,
        bench_offchip_traffic,
        bench_peak_memory,
        bench_roofline,
        bench_scheduling_time,
    )

    modules = [
        bench_peak_memory,
        bench_offchip_traffic,
        bench_footprint_trace,
        bench_scheduling_time,
        bench_roofline,
        bench_jaxpr_sched,
    ]
    rows: list[tuple] = []
    failed = 0
    for mod in modules:
        try:
            mod.run(rows)
        except Exception:
            failed += 1
            print(f"# BENCH FAILED: {mod.__name__}", file=sys.stderr)
            traceback.print_exc()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        raise SystemExit(f"{failed} bench modules failed")


if __name__ == "__main__":
    main()
