"""Benchmark harness: one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_peak_memory      Fig. 10/15  peak footprint vs TFLite order
  bench_offchip_traffic  Fig. 11     Belady off-chip traffic sweep
  bench_footprint_trace  Fig. 12     SwiftNet-A running footprint
  bench_scheduling_time  Fig. 13/T2  D&C + soft-budget ablation + engine/cache
  bench_roofline         (ours)      dry-run roofline table (§Roofline)
  bench_jaxpr_sched      (ours)      SERENITY-on-jaxpr liveness gains
  bench_serving          (ours)      multi-tenant pool vs per-request arenas
  bench_fleet            (ours)      sharded fleet: 10k open-loop requests,
                                     4 shards + prefill lane, SLO gates
  bench_executor         (ours)      us/step: slice-per-node vs fused vs jit
                                     executors + serial vs batched decode

``--smoke`` runs every module on tiny graph sizes with a single repetition
(seconds, not minutes) so CI can exercise each entry point; ``--json PATH``
additionally writes the rows as a machine-readable artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph sizes, single repetition (for CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON to PATH")
    ap.add_argument("--only", default=None,
                    help="run a single module (e.g. bench_scheduling_time)")
    args = ap.parse_args()

    if args.json:
        # fail fast on an unwritable artifact path, not after minutes of work
        with open(args.json, "w"):
            pass

    # importable both as `python benchmarks/run.py` and `python -m benchmarks.run`
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    if _ROOT not in sys.path:
        sys.path.insert(0, _ROOT)
    from benchmarks import (
        bench_executor,
        bench_fleet,
        bench_footprint_trace,
        bench_jaxpr_sched,
        bench_offchip_traffic,
        bench_peak_memory,
        bench_roofline,
        bench_scheduling_time,
        bench_serving,
    )

    modules = [
        bench_peak_memory,
        bench_offchip_traffic,
        bench_footprint_trace,
        bench_scheduling_time,
        bench_roofline,
        bench_jaxpr_sched,
        bench_serving,
        bench_fleet,
        bench_executor,
    ]
    if args.only:
        modules = [m for m in modules if m.__name__.endswith(args.only)]
        if not modules:
            raise SystemExit(f"unknown module {args.only!r}")
    rows: list[tuple] = []
    failures: list[str] = []
    for mod in modules:
        t0 = time.perf_counter()
        try:
            mod.run(rows, smoke=args.smoke)
        except Exception:
            failures.append(mod.__name__)
            print(f"# BENCH FAILED: {mod.__name__}", file=sys.stderr)
            traceback.print_exc()
        else:
            print(f"# {mod.__name__}: {time.perf_counter() - t0:.1f}s",
                  file=sys.stderr)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "smoke": args.smoke,
                "rows": [
                    {"name": n, "us_per_call": us, "derived": d}
                    for n, us, d in rows
                ],
                "failed_modules": failures,
            }, f, indent=2)
    if failures:
        raise SystemExit(f"{len(failures)} bench modules failed")


if __name__ == "__main__":
    main()
