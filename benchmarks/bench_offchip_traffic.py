"""Paper Fig. 11: off-chip traffic vs on-chip capacity (Belady residency).

Sweeps on-chip capacities; for each, compares the TFLite-order schedule's
off-chip bytes against SERENITY's.  Marks capacities where SERENITY
*eradicates* traffic (fits entirely on-chip) while the baseline cannot —
the paper's headline case.
"""

from __future__ import annotations

import time

from repro.core import PlanConfig, kahn_schedule, plan, simulate_traffic
from repro.graphs import BENCHMARK_GRAPHS

CAPS_KB = (64, 128, 192, 256, 320, 448, 640, 1024, 2048, 4096)


def run(csv_rows: list, smoke: bool = False) -> dict:
    best_reduction = {}
    graphs = list(BENCHMARK_GRAPHS.items())
    caps = CAPS_KB
    if smoke:
        graphs = graphs[:2]
        caps = CAPS_KB[:4]
    for name, fn in graphs:
        g = fn()
        kahn = kahn_schedule(g)
        ser = plan(g, PlanConfig(rewrite=True, state_quota=4000,
                                 compute_baselines=False))
        t0 = time.perf_counter()
        rows = []
        for cap in caps:
            tb = simulate_traffic(g, kahn.order, cap * 1024,
                                  include_weights=False)
            ts = simulate_traffic(ser.graph, ser.order, cap * 1024,
                                  include_weights=False)
            act_b = tb.read_bytes + tb.write_bytes
            act_s = ts.read_bytes + ts.write_bytes
            tag = ""
            if act_s == 0 and act_b > 0:
                tag = "ERADICATED"
            elif act_s == 0 and act_b == 0:
                tag = "N/A"           # both fit (paper's N/A cells)
            rows.append((cap, act_b, act_s, tag))
        dt = (time.perf_counter() - t0) * 1e6
        red = [
            b / s for _, b, s, _ in rows if s > 0 and b > 0
        ]
        best_reduction[name] = max(red) if red else float("inf")
        detail = "|".join(
            f"{cap}KB:{b//1024}->{s//1024}{('!' + t) if t else ''}"
            for cap, b, s, t in rows
        )
        csv_rows.append((f"offchip_traffic/{name}", dt, detail))
    csv_rows.append((
        "offchip_traffic/summary", 0.0,
        ";".join(f"{k}_maxred={v if v != float('inf') else 'inf'}"
                 for k, v in best_reduction.items())
        + ";paper_reduction_256KB=1.76",
    ))
    return best_reduction
