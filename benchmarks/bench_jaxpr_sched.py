"""Framework-integration benchmark (ours): SERENITY scheduling of jaxpr
equation graphs — liveness peak of the traced order vs the DP order on
representative irregular compute patterns (NAS-like cell, MoE-style
fan-out, multi-branch residual)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.jax_bridge import analyze_fn


def nas_cell(x):
    branches = []
    for i in range(6):
        h = jnp.tanh(x * (i + 1.0))
        h = h @ jnp.ones((x.shape[-1], 4 * x.shape[-1]), x.dtype)
        h = jax.nn.relu(h)
        h = h @ jnp.ones((4 * x.shape[-1], 16), x.dtype)
        branches.append(h)
    return jnp.sum(jnp.concatenate(branches, -1) ** 2)


def moe_fanout(x):
    outs = []
    for e in range(8):
        h = x @ jnp.ones((x.shape[-1], 256), x.dtype) * (e + 1)
        outs.append(jax.nn.gelu(h) @ jnp.ones((256, 64), x.dtype))
    return sum(o.sum() for o in outs)


def branchy_residual(x):
    hs = [jnp.tanh(x * i) @ jnp.ones((x.shape[-1], 512)) for i in
          range(1, 7)]
    return sum((h @ jnp.ones((512, 8))).sum() for h in hs)


CASES = {
    "nas_cell": nas_cell,
    "moe_fanout": moe_fanout,
    "branchy_residual": branchy_residual,
}


def run(csv_rows: list, smoke: bool = False) -> dict:
    x = jnp.ones((8, 32) if smoke else (64, 128), jnp.float32)
    out = {}
    cases = CASES
    if smoke:
        cases = dict(list(CASES.items())[:1])
    for name, fn in cases.items():
        t0 = time.perf_counter()
        rep = analyze_fn(fn, x)
        dt = (time.perf_counter() - t0) * 1e6
        out[name] = rep.reduction_vs_original
        csv_rows.append((
            f"jaxpr_sched/{name}", dt,
            f"eqns={rep.n_eqns};orig_kb={rep.original_peak//1024};"
            f"opt_kb={rep.optimal_peak//1024};"
            f"reduction={rep.reduction_vs_original:.2f};exact={rep.exact}",
        ))
    return out
