"""Sharded serving fleet under open-loop load (DESIGN.md §14).

Five sections, all on the deterministic simulated device step (pure byte
arithmetic — the rows measure scheduling policy, not kernels), all seeded,
so every tick-domain metric exact-diffs against the committed baseline:

* **fleet/loadgen** — the generated workload's shape summary (arrival
  span, prompt/gen moments, token total).  Seeds are fixed: any drift
  here means the generator changed, not the load.
* **fleet/sharded_4x** — the headline run: 10k+ requests (smoke: 1.5k)
  over 4 decode shards + 1 prefill shard, each with its own `ArenaPool`
  byte budget, plans served by one `PlannerService`.  Asserts the two
  SLOs (p99 latency in ticks, rejection rate) plus the standing
  invariants: **no request lost** and **no shard ever over its
  instantaneous budget**.
* **fleet/single_shard** — the same workload and the *same total byte
  budget* on one decode shard (the `DecodeServer` shape: one pool, one
  tick loop).  Asserts the 4-shard fleet sustains **>= 2.5x** its
  throughput (tokens/tick) — the shards' independent decode lanes are
  the win; bytes alone don't scale a single batch slot.
* **fleet/disagg_ab** — a long-prompt workload with and without the
  prefill lane: inline prefill visibly stalls decode ticks
  (``prefill_stall_ticks``); the lane removes every stall and hands
  finished prefill state to decode shards through the host-spill round
  trip (``handoffs``), token streams bit-equal.
* **fleet/chaos** — generated per-shard fault scripts (budget shrinks,
  admission faults, transient executor errors) over the sharded fleet;
  across the corpus no request is lost, budgets hold, and surviving
  token streams bit-equal the fault-free twin.
"""

from __future__ import annotations

import math
import time

from repro.runtime.chaos import FaultPlan
from repro.runtime.fleet import (
    Fleet,
    PlannerService,
    bucket_key_for,
    bucketed_records,
)
from repro.runtime.loadgen import OpenLoopLoadGen, workload_summary

# SLOs asserted on the sharded run (tick-domain, deterministic under the
# fixed seed — these are gates, not tripwires)
SLO_P99_TICKS = 600.0
SLO_REJECTION_RATE = 0.02

N_DECODE = 4
MAX_BATCH = 8
PREFILL_CHUNK = 32
BUCKETS = (48, 192, 2048)    # smax buckets; the 2048 plan exceeds every
                             # shard budget -> oversize arrivals are real
                             # router rejections, not a special case


def _planner_and_budget():
    planner = PlannerService()
    records = bucketed_records(planner, BUCKETS)
    # per-shard budget: a full decode batch of the largest servable bucket
    budget = MAX_BATCH * records[BUCKETS[-2]].alone_bytes
    assert records[BUCKETS[-1]].alone_bytes > budget, \
        "oversize bucket must overflow a shard budget"
    return planner, records, budget


def _loadgen(seed: int = 7) -> OpenLoopLoadGen:
    # ~3.5 arrivals/tick * ~6 tokens each ~= 21 tok/tick of decode demand
    # against 4*8 = 32 slots: loaded but stable, so the p99 SLO is
    # meaningful rather than queue-growth noise
    return OpenLoopLoadGen(seed, rate=3.5, prompt_mean=28.0,
                           prompt_sigma=0.8, prompt_max=1100,
                           gen_mean=6.0, gen_max=32, latency_frac=0.25,
                           priority_weights={0: 3.0, 1: 1.0},
                           tenant_weights={"a": 2.0, "b": 1.0})


def _fleet(planner, records, budget, *, n_decode=N_DECODE, n_prefill=1,
           fault_plans=None) -> Fleet:
    return Fleet(planner, key_for=bucket_key_for(records),
                 n_decode=n_decode, n_prefill=n_prefill,
                 shard_budget_bytes=budget, max_batch=MAX_BATCH,
                 prefill_chunk=PREFILL_CHUNK, fault_plans=fault_plans)


def _tokens(fleet: Fleet) -> dict[int, tuple]:
    return {r.rid: tuple(r.tokens) for r in fleet.done}


def _fmt(m: dict, extra: str = "") -> str:
    s = (f"n_requests={m['n_requests']};n_served={m['n_served']};"
         f"n_rejected={m['n_rejected']};n_lost={m['n_lost']};"
         f"rejection_rate={m['rejection_rate']};ticks={m['ticks']};"
         f"p50_ticks={m['p50_ticks']};p99_ticks={m['p99_ticks']};"
         f"tokens={m['tokens']};tok_per_tick={m['tok_per_tick']};"
         f"migrations={m['migrations']};handoffs={m['handoffs']};"
         f"preemptions={m['preemptions']};"
         f"max_over_budget={m['max_over_budget']};"
         f"prefill_stall_ticks={m['prefill_stall_ticks']}")
    return s + (";" + extra if extra else "")


def _assert_invariants(m: dict, label: str) -> None:
    assert m["n_lost"] == 0, \
        f"{label}: lost {m['n_lost']} request(s) (neither served nor rejected)"
    assert m["max_over_budget"] <= 0, (
        f"{label}: a shard exceeded its instantaneous budget by "
        f"{m['max_over_budget']} bytes")
    assert m["n_served"] + m["n_rejected"] == m["n_requests"], label


def run(csv_rows: list, smoke: bool = False) -> dict:
    n_req = 1_500 if smoke else 10_000

    # -- workload ----------------------------------------------------------
    gen = _loadgen()
    t0 = time.perf_counter()
    arrivals = gen.arrivals(n_req)
    gen_us = (time.perf_counter() - t0) * 1e6
    ws = workload_summary(arrivals)
    csv_rows.append((
        "fleet/loadgen", gen_us,
        f"n={ws['n']};span_ticks={ws['span_ticks']};"
        f"prompt_mean={ws['prompt_mean']};prompt_p99={ws['prompt_p99']};"
        f"gen_mean={ws['gen_mean']};tokens_total={ws['tokens_total']};"
        f"latency_frac={ws['latency_frac']};rate={gen.rate}",
    ))

    # -- sharded fleet (the headline row + both SLOs) ----------------------
    planner, records, budget = _planner_and_budget()
    fleet = _fleet(planner, records, budget)
    t0 = time.perf_counter()
    m = fleet.run_arrivals(arrivals)
    wall = time.perf_counter() - t0
    _assert_invariants(m, "sharded_4x")
    assert math.isfinite(m["p99_ticks"]), "sharded_4x: no request served"
    assert m["p99_ticks"] <= SLO_P99_TICKS, (
        f"p99 latency SLO violated: {m['p99_ticks']} ticks > "
        f"{SLO_P99_TICKS} (served {m['n_served']}/{m['n_requests']})")
    assert m["rejection_rate"] <= SLO_REJECTION_RATE, (
        f"rejection-rate SLO violated: {m['rejection_rate']} > "
        f"{SLO_REJECTION_RATE} ({m['n_rejected']} rejected)")
    base_tokens = _tokens(fleet)
    csv_rows.append((
        "fleet/sharded_4x", wall * 1e6,
        _fmt(m, f"n_decode={N_DECODE};n_prefill=1;"
                f"shard_budget_bytes={budget};wall_s={wall:.3f};"
                f"slo_p99_ticks={SLO_P99_TICKS:g};"
                f"slo_rejection_rate={SLO_REJECTION_RATE:g}"),
    ))

    # -- single shard, same total budget (the DecodeServer shape) ----------
    planner1, records1, _ = _planner_and_budget()
    single = _fleet(planner1, records1, N_DECODE * budget,
                    n_decode=1, n_prefill=0)
    t0 = time.perf_counter()
    m1 = single.run_arrivals(arrivals)
    wall1 = time.perf_counter() - t0
    _assert_invariants(m1, "single_shard")
    gain = m["tok_per_tick"] / max(m1["tok_per_tick"], 1e-9)
    assert gain >= 2.5, (
        f"sharding gained only {gain:.2f}x tokens/tick over one shard "
        f"with the same total budget (need >= 2.5x)")
    csv_rows.append((
        "fleet/single_shard", wall1 * 1e6,
        _fmt(m1, f"total_budget_bytes={N_DECODE * budget};"
                 f"sharding_gain={gain:.2f};wall_s={wall1:.3f}"),
    ))

    # -- prefill/decode disaggregation A/B ---------------------------------
    # prompt_min >= the lane threshold (2 * PREFILL_CHUNK): every prompt
    # is long, so the lane absorbs all prefill and stalls drop to zero
    ab_arrivals = OpenLoopLoadGen(
        11, rate=1.0, prompt_mean=110.0, prompt_sigma=0.4, prompt_max=900,
        prompt_min=2 * PREFILL_CHUNK,
        gen_mean=5.0, gen_max=16).arrivals(300 if smoke else 1_500)
    ab = {}
    for n_prefill in (0, 1):
        p, r, b = _planner_and_budget()
        f = _fleet(p, r, b, n_prefill=n_prefill)
        t0 = time.perf_counter()
        am = f.run_arrivals(ab_arrivals)
        ab[n_prefill] = (am, _tokens(f), time.perf_counter() - t0)
        _assert_invariants(am, f"disagg n_prefill={n_prefill}")
    m0, tok0, _ = ab[0]
    mp, tokp, wallp = ab[1]
    assert m0["prefill_stall_ticks"] > 0, \
        "inline prefill should visibly stall decode ticks"
    assert mp["prefill_stall_ticks"] == 0 and mp["handoffs"] > 0, \
        "the prefill lane should remove every stall via handoffs"
    assert tok0 == tokp, "disaggregation changed a token stream"
    csv_rows.append((
        "fleet/disagg_ab", wallp * 1e6,
        f"n={m0['n_requests']};stalls_inline={m0['prefill_stall_ticks']};"
        f"stalls_disagg={mp['prefill_stall_ticks']};"
        f"handoffs={mp['handoffs']};ticks_inline={m0['ticks']};"
        f"ticks_disagg={mp['ticks']};p99_inline={m0['p99_ticks']};"
        f"p99_disagg={mp['p99_ticks']}",
    ))

    # -- chaos corpus over the sharded fleet -------------------------------
    chaos_arrivals = arrivals[: 400 if smoke else 1_200]
    pc, rc, bc = _planner_and_budget()
    twin = _fleet(pc, rc, bc)
    twin.run_arrivals(chaos_arrivals)
    twin_tokens = _tokens(twin)
    seeds = range(3 if smoke else 8)
    total_faults = preempts = 0
    t0 = time.perf_counter()
    for seed in seeds:
        plans = {sid: FaultPlan.generate(seed + 13 * sid, n_ticks=60,
                                         rate=0.15)
                 for sid in range(N_DECODE)}
        p, r, b = _planner_and_budget()
        f = _fleet(p, r, b, fault_plans=plans)
        cm = f.run_arrivals(chaos_arrivals)
        ctx = f"chaos seed={seed}"
        _assert_invariants(cm, ctx)
        for rid, toks in _tokens(f).items():
            assert toks == twin_tokens[rid], \
                f"{ctx}: rid={rid} token stream diverged from fault-free twin"
        total_faults += sum(len(pl) for pl in plans.values())
        preempts += cm["preemptions"]
    chaos_wall = time.perf_counter() - t0
    csv_rows.append((
        "fleet/chaos", chaos_wall * 1e6,
        f"n={len(chaos_arrivals)};corpus={len(list(seeds))};"
        f"faults={total_faults};preemptions={preempts};"
        f"lost=0;over_budget=0",
    ))

    return {
        "n_requests": n_req,
        "p99_ticks": m["p99_ticks"],
        "rejection_rate": m["rejection_rate"],
        "tok_per_tick": m["tok_per_tick"],
        "sharding_gain": gain,
        "stalls_removed": m0["prefill_stall_ticks"],
        "chaos_corpus": len(list(seeds)),
    }


if __name__ == "__main__":
    rows: list = []
    summary = run(rows, smoke=False)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(summary)
