"""Warn-only diff of a benchmark-smoke JSON against the committed baseline.

    python benchmarks/diff_baseline.py BENCH_baseline.json bench-smoke.json

Compares the *deterministic* derived metrics of rows present in both files
(byte counts, peaks, ratios, node/buffer counts, policies) and prints a
warning for every drift.  Timing-like keys (seconds, speedups,
microseconds) are machine-dependent and exempt from exact comparison, but
absolute durations in ``scheduling_time/`` rows are still sanity-checked:
a search that got more than 2x slower than the baseline (above a small
noise floor) warns — the tripwire for scheduling-time regressions the CI
run annotates.  ``serving/`` and ``fleet/`` rows get the same first-class
treatment: request-latency percentiles (``p50_ms``/``p99_ms``/``wall_s``)
are tripwired at >2x with the unit-aware noise floor, and the
load-dependent peak-bytes columns (``peak_reserved_bytes``) warn on a >2x
regression instead of exact-diffing (admission timing may legitimately
shift them a little; doubling means the pool stopped sharing).  Their SLO
columns are guarded explicitly: a ``rejection_rate`` that rises past both
an absolute point (+0.01) and 1.5x the (floored) baseline warns, and a
latency percentile that goes from a measured value to ``NaN`` — an
all-rejected run — or *disappears* from the smoke row entirely warns
instead of being skipped as machine-dependent timing.  ``executor/`` rows are
tripwired on every duration column (``*_us`` step times) with a lower,
per-step noise floor, while their fusion-coverage counts
(``n_regions``/``n_fused``/``max_chain``) stay exact-diffed.  ``frontier=`` values (the
latency x memory and recompute Pareto rows) are diffed *structurally*,
point by point, instead of as one opaque string: each ``lat:peak`` point's
peak bytes exact-diffs, while its latency component is compared by kind —
a unit-suffixed measured latency (``123.4ms``) gets the same >2x
unit-aware noise-floored tripwire as every other duration, and a unitless
surrogate (makespan cost, ``1.24x`` FLOPs ratio) exact-diffs because it is
deterministic.  A frontier that gained, lost, or reordered points warns
with the point counts.  Metric keys present only on one side are never treated as
value regressions: a key that *disappeared* from the smoke run warns (a
bench stopped reporting it), while a *new* column (e.g. ``realized_bytes``
on its first appearance) is a plain note until it lands in the committed
baseline.  Always exits 0 — this is a tripwire, not a hard gate: update
the baseline (``python benchmarks/run.py --smoke --json
BENCH_baseline.json``) when a change to the plans or search costs is
intentional.
"""

from __future__ import annotations

import json
import math
import re
import sys

# timing/noise keys: exempt from exact comparison
_NOISY = re.compile(
    r"(_s|_ms|_us|_sec|seconds|speedup|cold|warm|time|gflops|tok)s?$"
)
# absolute-duration keys eligible for the >2x regression check (ratios and
# speedups are excluded: a smaller speedup is not necessarily a slowdown)
_DURATION_KEY = re.compile(r"(_s|_ms|_us|seconds|cold_ms|warm_us)$")
# duration-shaped values ("0.01s", "12.3ms"): timing smuggled into an
# otherwise-deterministic key (e.g. the Table 2 ablation row)
_DURATION = re.compile(r"^\d+(\.\d+)?(s|ms|us)$")
_REL_TOL = 1e-6
# scheduling-time regression tripwire: new > 2x old, and the new value must
# be above the noise floor for its unit so microsecond jitter never warns
_REGRESSION_FACTOR = 2.0
_NOISE_FLOOR = {"s": 0.05, "ms": 50.0, "us": 50_000.0}
# executor rows measure single steps (tens of microseconds and up), so the
# scheduling-time floor would mask every real regression: use a lower one
_NOISE_FLOOR_EXEC = {"s": 0.0005, "ms": 0.5, "us": 500.0}
# serving/fleet rows: latency keys eligible for the >2x duration tripwire
# (plain `tok_per_s` etc. end in `_s` too, but are rates, not durations)
_SERVING_LAT_KEY = re.compile(r"^(p\d+_(ms|s|us)|wall_s|latency_\w+)$")
# rows that carry serving SLO metrics (latency percentiles, rejection rate)
_SLO_ROW = ("serving/", "fleet/")
# rejection-rate SLO tripwire: warn when the new rate exceeds the old by
# more than an absolute point AND by more than 1.5x (with a floor so a
# jump from 0.000 to 0.004 — a handful of requests — never warns)
_REJECT_ABS_FLOOR = 0.01
_REJECT_FACTOR = 1.5
_REJECT_BASE_FLOOR = 0.005
# serving rows: load-dependent byte watermarks — >2x threshold, not exact.
# Degraded-mode rows (DESIGN.md §13) add spill_bytes / min_budget_bytes:
# how much state the ladder preempted and how low the scripted shrink went
# both scale with load, so they get the same unit-aware treatment instead
# of an exact diff.
_SERVING_BYTES_KEY = re.compile(
    r"^(peak_\w*bytes|spill_bytes|min_budget_bytes)$")
# Pareto frontier values: '|'-separated lat:peak points.  The latency leg
# is one of: a unit-suffixed measured duration ("123.4ms"), a surrogate
# FLOPs ratio ("1.240x"), or a plain surrogate makespan integer.
_FRONTIER_KEY = re.compile(r"(^|_)frontier$")
_FRONTIER_POINT = re.compile(
    r"^(?P<lat>\d+(\.\d+)?(?P<unit>s|ms|us|x)?):(?P<peak>\d+)$")


def _duration_unit(key: str, value: str) -> str | None:
    m = _DURATION.match(value)
    if m:
        return m.group(2)
    if key.endswith(("_s", "seconds")):
        return "s"
    if key.endswith("_ms"):
        return "ms"
    if key.endswith("_us"):
        return "us"
    return None


def _check_time_regression(name: str, key: str, old: str, new: str) -> bool:
    """True (and warn) when a duration metric regressed >2x.

    Applies to every duration key of ``scheduling_time/`` and ``executor/``
    rows (the latter with a per-step noise floor) and to the
    request-latency keys (p50/p99/wall) of ``serving/`` rows.
    """
    floor = _NOISE_FLOOR
    if name.startswith("scheduling_time/"):
        if not (_DURATION_KEY.search(key) or _DURATION.match(new)):
            return False
    elif name.startswith("executor/"):
        if not (_DURATION_KEY.search(key) or _DURATION.match(new)):
            return False
        floor = _NOISE_FLOOR_EXEC
    elif name.startswith(_SLO_ROW):
        if not _SERVING_LAT_KEY.match(key):
            return False
    else:
        return False
    unit = _duration_unit(key, new)
    if unit is None or _duration_unit(key, old) != unit:
        return False
    try:
        fo = float(old.rstrip("smu"))
        fn = float(new.rstrip("smu"))
    except ValueError:
        return False
    if math.isnan(fn) and not math.isnan(fo):
        # the latency went from measured to NaN: nothing was served — a
        # vacuous-SLO regression, never a silent skip
        print(f"::warning::{name}: latency {key} became NaN "
              f"(was {old}; zero requests served?)")
        return True
    if fn <= floor[unit] or fo <= 0:
        return False
    if fn > _REGRESSION_FACTOR * fo:
        kind = "latency" if name.startswith(_SLO_ROW) else \
            "step time" if name.startswith("executor/") else "scheduling time"
        print(f"::warning::{name}: {kind} {key} regressed "
              f">{_REGRESSION_FACTOR:g}x: {old} -> {new}")
        return True
    return False


def _check_bytes_regression(name: str, key: str, old: str, new: str) -> bool:
    """True (and warn) when a serving byte watermark regressed >2x."""
    try:
        fo, fn = float(old), float(new)
    except ValueError:
        return False
    if fo <= 0 or fn <= _REGRESSION_FACTOR * fo:
        return False
    print(f"::warning::{name}: {key} regressed >{_REGRESSION_FACTOR:g}x: "
          f"{old} -> {new} bytes")
    return True


def _check_rejection_rate(name: str, old: str, new: str) -> bool:
    """True (and warn) when a serving/fleet rejection rate regressed past
    the SLO floors (see the constants above)."""
    try:
        fo, fn = float(old), float(new)
    except ValueError:
        return False
    if fn <= fo + _REJECT_ABS_FLOOR:
        return False
    if fn <= _REJECT_FACTOR * max(fo, _REJECT_BASE_FLOOR):
        return False
    print(f"::warning::{name}: rejection_rate regressed {old} -> {new} "
          f"(>{_REJECT_ABS_FLOOR:g} absolute and "
          f">{_REJECT_FACTOR:g}x the baseline)")
    return True


def _parse_frontier(value: str) -> list[re.Match] | None:
    """Parse 'lat:peak|lat:peak|...' into point matches (None = not one)."""
    pts = [_FRONTIER_POINT.match(p) for p in value.split("|")]
    if not pts or any(m is None for m in pts):
        return None
    return pts


def _check_frontier(name: str, key: str, old: str, new: str) -> int:
    """Structurally diff two frontier strings; returns warnings emitted.

    Points are positional: point i of the smoke run is compared against
    point i of the baseline.  Peaks are deterministic plan bytes and
    exact-diff; latency legs exact-diff when they are surrogate values
    (plain makespan cost, 'x'-suffixed FLOPs ratio) and get the >2x
    noise-floored duration tripwire when they carry a time unit.
    """
    po, pn = _parse_frontier(old), _parse_frontier(new)
    if po is None or pn is None:
        # not actually frontier-shaped on one side: fall back to opaque
        if _differs(old, new):
            print(f"::warning::{name}: {key} drifted {old} -> {new}")
            return 1
        return 0
    warnings = 0
    if len(po) != len(pn):
        print(f"::warning::{name}: {key} changed shape: "
              f"{len(po)} -> {len(pn)} points")
        warnings += 1
    for i, (mo, mn) in enumerate(zip(po, pn)):
        if mo.group("peak") != mn.group("peak"):
            print(f"::warning::{name}: {key} point {i} peak drifted "
                  f"{mo.group('peak')} -> {mn.group('peak')} bytes")
            warnings += 1
        lo, ln = mo.group("lat"), mn.group("lat")
        uo, un = mo.group("unit"), mn.group("unit")
        if uo != un:
            print(f"::warning::{name}: {key} point {i} latency changed "
                  f"kind: {lo} -> {ln}")
            warnings += 1
            continue
        if un in ("s", "ms", "us"):
            fo, fn = float(lo.rstrip("smu")), float(ln.rstrip("smu"))
            if fn > _NOISE_FLOOR[un] and fo > 0 \
                    and fn > _REGRESSION_FACTOR * fo:
                print(f"::warning::{name}: {key} point {i} latency "
                      f"regressed >{_REGRESSION_FACTOR:g}x: {lo} -> {ln}")
                warnings += 1
        elif _differs(lo.rstrip("x"), ln.rstrip("x")):
            # surrogate (makespan cost / FLOPs ratio): deterministic
            print(f"::warning::{name}: {key} point {i} latency drifted "
                  f"{lo} -> {ln}")
            warnings += 1
    return warnings


def _parse_derived(derived: str) -> dict[str, str]:
    out: dict[str, str] = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def _deterministic(key: str) -> bool:
    return not _NOISY.search(key)


def _differs(a: str, b: str) -> bool:
    try:
        fa, fb = float(a), float(b)
    except ValueError:
        return a != b
    if math.isnan(fa) or math.isnan(fb):
        # NaN on one side only is a drift (e.g. a latency that stopped
        # being measurable); NaN == NaN for diffing purposes
        return math.isnan(fa) != math.isnan(fb)
    if fa == fb:
        return False
    return abs(fa - fb) > _REL_TOL * max(abs(fa), abs(fb))


def main() -> None:
    if len(sys.argv) != 3:
        raise SystemExit(__doc__)
    base_path, new_path = sys.argv[1], sys.argv[2]
    with open(base_path) as f:
        base = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    base_rows = {r["name"]: _parse_derived(r["derived"])
                 for r in base.get("rows", [])}
    new_rows = {r["name"]: _parse_derived(r["derived"])
                for r in new.get("rows", [])}

    warnings = 0
    for name in sorted(base_rows.keys() & new_rows.keys()):
        b, n = base_rows[name], new_rows[name]
        for key in sorted(b.keys() & n.keys()):
            if _FRONTIER_KEY.search(key):
                # Pareto frontier: structural point-by-point diff
                warnings += _check_frontier(name, key, b[key], n[key])
                continue
            if name.startswith(_SLO_ROW) and _SERVING_BYTES_KEY.match(key):
                # load-dependent watermark: >2x threshold, not exact diff
                if _check_bytes_regression(name, key, b[key], n[key]):
                    warnings += 1
                continue
            if name.startswith(_SLO_ROW) and key == "rejection_rate":
                # SLO row: floored threshold check, not exact diff
                if _check_rejection_rate(name, b[key], n[key]):
                    warnings += 1
                elif _differs(b[key], n[key]):
                    print(f"note: {name}: rejection_rate moved "
                          f"{b[key]} -> {n[key]} (within SLO floors)")
                continue
            if not _deterministic(key) or _DURATION.match(b[key]) \
                    or _DURATION.match(n[key]):
                # timing: exempt from exact diffing, but still tripwired
                # against >2x scheduling-time / serving-latency regressions
                if _check_time_regression(name, key, b[key], n[key]):
                    warnings += 1
                continue
            if _differs(b[key], n[key]):
                warnings += 1
                print(f"::warning::{name}: {key} drifted "
                      f"{b[key]} -> {n[key]}")
        for key in sorted(b.keys() - n.keys()):
            if not _deterministic(key):
                # timing keys come and go with the machine — except the
                # serving latency SLO columns: a p50/p99 that stops being
                # reported is a bench silently dropping its gate
                if name.startswith(_SLO_ROW) \
                        and _SERVING_LAT_KEY.match(key):
                    warnings += 1
                    print(f"::warning::{name}: latency metric {key} "
                          f"disappeared from smoke run (was {b[key]})")
                continue
            warnings += 1
            print(f"::warning::{name}: metric {key} disappeared from "
                  f"smoke run (was {b[key]})")
        for key in sorted(n.keys() - b.keys()):
            # new columns are warn-only on first appearance: refresh the
            # baseline to start tracking them
            if _deterministic(key):
                print(f"note: {name}: new metric (not in baseline): "
                      f"{key}={n[key]}")
    for name in sorted(base_rows.keys() - new_rows.keys()):
        warnings += 1
        print(f"::warning::row disappeared from smoke run: {name}")
    for name in sorted(new_rows.keys() - base_rows.keys()):
        print(f"note: new row (not in baseline): {name}")

    checked = len(base_rows.keys() & new_rows.keys())
    print(f"diff_baseline: {checked} shared rows checked, "
          f"{warnings} warning(s)")
    # warn-only: never fail the build


if __name__ == "__main__":
    main()
