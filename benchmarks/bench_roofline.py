"""Roofline aggregation: read the dry-run artifacts and emit the per-cell
three-term table (§Roofline in EXPERIMENTS.md).

Derived fields missing from older records (min-bytes, fractions) are
recomputed here from the stored raw costs, so the bench is the single
source of truth for the table.
"""

from __future__ import annotations

import glob
import json
import os

import repro.configs as configs
from repro.configs.base import SHAPES
from repro.launch.dryrun import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    min_bytes_estimate,
    model_flops,
)

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load_cells(label: str | None = None) -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        rec = json.load(open(f))
        if label and rec.get("label") != label:
            continue
        cells.append(rec)
    return cells


def derive(rec: dict) -> dict | None:
    if not rec.get("applicable", True):
        return None
    cfg = configs.get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n = rec["n_chips"]
    pc = rec.get("probe_corrected")
    if pc:
        flops, bytes_, coll = pc["flops"], pc["bytes"], pc["coll_bytes"]
        corrected = True
    else:
        flops = rec["cost_analysis"].get("flops", 0.0)
        bytes_ = rec["cost_analysis"].get("bytes accessed", 0.0)
        coll = float(rec["collectives"]["total_bytes"])
        corrected = False
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = coll / LINK_BW
    t_max = max(t_c, t_m, t_x)
    mf = model_flops(cfg, shape) / n
    minb = min_bytes_estimate(cfg, shape, n)
    frac = max(mf / PEAK_FLOPS, minb / HBM_BW) / t_max if t_max else None
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "label": rec.get("label", "baseline"),
        "corrected": corrected,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom,
        "useful_flops_ratio": mf / flops if flops else None,
        "roofline_fraction": frac,
        "hbm_per_chip_gb": rec.get("memory_analysis", {}).get(
            "argument_size_in_bytes", 0) / 1e9,
        "temp_per_chip_gb": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes", 0) / 1e9,
        "compile_s": rec.get("compile_s"),
    }


def run(csv_rows: list, smoke: bool = False) -> dict:
    del smoke  # reads precomputed dry-run artifacts; already cheap
    rows = [d for d in (derive(r) for r in load_cells())
            if d is not None]
    skips = [r for r in load_cells() if not r.get("applicable", True)]
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"], d["mesh"],
                                         d["label"])):
        csv_rows.append((
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}/{d['label']}",
            0.0,
            f"tC={d['t_compute_s']:.4f};tM={d['t_memory_s']:.4f};"
            f"tX={d['t_collective_s']:.4f};dom={d['dominant']};"
            f"frac={d['roofline_fraction'] if d['roofline_fraction'] is not None else -1:.4f};"
            f"useful={d['useful_flops_ratio'] if d['useful_flops_ratio'] else -1:.3f};"
            f"corrected={int(d['corrected'])}",
        ))
    for s in skips:
        csv_rows.append((
            f"roofline/{s['arch']}/{s['shape']}/{s['mesh']}/SKIP", 0.0,
            s.get("skip_reason", ""),
        ))
    n_cells = len({(d["arch"], d["shape"]) for d in rows})
    summary = {"cells": n_cells, "rows": len(rows), "skips": len(skips)}
    csv_rows.append(("roofline/summary", 0.0,
                     ";".join(f"{k}={v}" for k, v in summary.items())))
    return summary
