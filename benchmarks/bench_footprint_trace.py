"""Paper Fig. 12: running footprint of SwiftNet Cell A, with and without
the allocator, before and after rewriting (the red-arrow reductions)."""

from __future__ import annotations

import time

from repro.core import (
    PlanConfig,
    kahn_schedule,
    plan,
    plan_arena,
    simulate_schedule,
)
from repro.graphs import swiftnet_cell


def run(csv_rows: list, smoke: bool = False) -> dict:
    del smoke  # a single 21-node cell is already smoke-sized
    g = swiftnet_cell("A")
    t0 = time.perf_counter()
    # cache=False: this row times cold scheduling — an earlier bench module
    # may already have primed the process-wide plan cache with this graph
    base = plan(g, PlanConfig(rewrite=False, state_quota=4000,
                              compute_baselines=False), cache=False)
    rew = plan(g, PlanConfig(rewrite=True, state_quota=4000,
                             compute_baselines=False), cache=False)
    kahn = kahn_schedule(g)
    dt = (time.perf_counter() - t0) * 1e6

    # Fig 12(b): footprint model (no allocator)
    tr_kahn = simulate_schedule(g, kahn.order)
    tr_dp = simulate_schedule(g, base.order)
    tr_rw = simulate_schedule(rew.graph, rew.order)
    # Fig 12(a): through the allocator
    a_kahn = plan_arena(g, kahn.order).arena_bytes
    a_dp = base.arena_bytes
    a_rw = rew.arena_bytes

    out = {
        "model_kahn_kb": tr_kahn.peak_bytes / 1024,
        "model_sched_kb": tr_dp.peak_bytes / 1024,
        "model_rewrite_kb": tr_rw.peak_bytes / 1024,
        "arena_kahn_kb": a_kahn / 1024,
        "arena_sched_kb": a_dp / 1024,
        "arena_rewrite_kb": a_rw / 1024,
    }
    csv_rows.append((
        "footprint_trace/swiftnet_a", dt,
        ";".join(f"{k}={v:.1f}" for k, v in out.items()),
    ))
    # the running trace itself (comparable to the paper's curves)
    csv_rows.append((
        "footprint_trace/swiftnet_a_curve", 0.0,
        "sched=" + ",".join(str(v // 1024) for v in tr_dp.trace)
        + "|rewrite=" + ",".join(str(v // 1024) for v in tr_rw.trace),
    ))
    return out
