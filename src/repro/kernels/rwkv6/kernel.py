"""RWKV-6 WKV Pallas TPU kernel.

Grid (B*H, T/C): the time axis is the sequential (last) grid dimension, so
the recurrent state S (N,N) lives in VMEM scratch and flows across the
chunk iterations of one (batch, head).  Within a chunk the kernel runs an
exact fori_loop over the C steps — the recurrence is inherently serial, and
the per-step work (two rank-1 outer products + a vector-matrix product on an
(N,N)=64x64 state) is VPU-shaped.  Block sizes:

  r,k,v,w chunks: (C, N) each, C=256, N=64  ->  4 x 64 KB
  state scratch:  (N, N) f32                ->  16 KB
  out block:      (C, N)                    ->  64 KB

well under the VMEM budget; the C axis is a multiple of 8 and N=64 lanes
(128 after the compiler pads) keep the layout hardware-friendly.

Validated in interpret mode against ref.wkv6_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                 s_scr, *, chunk: int, n_chunks: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = s0_ref[...].astype(jnp.float32)

    u = u_ref[...].astype(jnp.float32)            # (1, N)

    def step(t, _):
        rt = r_ref[t, :].astype(jnp.float32)[None, :]      # (1, N)
        kt = k_ref[t, :].astype(jnp.float32)[None, :]
        vt = v_ref[t, :].astype(jnp.float32)[None, :]
        wt = w_ref[t, :].astype(jnp.float32)[None, :]
        S = s_scr[...]                                     # (N, N) key x value
        inter = jax.lax.dot_general(
            rt, S, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (1, N)
        bonus = jnp.sum(rt * u * kt)                       # scalar
        o_ref[t, :] = (inter + bonus * vt)[0].astype(o_ref.dtype)
        s_scr[...] = wt.T * S + kt.T * vt                  # decay keys, rank-1
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ti == n_chunks - 1)
    def _emit_state():
        sT_ref[...] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, *, initial_state=None, chunk: int = 256,
                interpret: bool = False):
    B, T, H, N = r.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    n_chunks = T // C

    tr = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, T, N)
    rt, kt, vt, wt = tr(r), tr(k), tr(v), tr(w)
    ub = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, 1, N)
    s0 = (
        jnp.zeros((B * H, N, N), jnp.float32)
        if initial_state is None
        else initial_state.reshape(B * H, N, N).astype(jnp.float32)
    )

    kernel = functools.partial(_wkv6_kernel, chunk=C, n_chunks=n_chunks)
    out, sT = pl.pallas_call(
        kernel,
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((None, C, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((None, C, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((None, C, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((None, C, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((None, 1, N), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((None, N, N), lambda h, t: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, C, N), lambda h, t: (h, t, 0)),
            pl.BlockSpec((None, N, N), lambda h, t: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T, N), r.dtype),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, ub, s0)
    out = out.reshape(B, H, T, N).transpose(0, 2, 1, 3)
    return out, sT.reshape(B, H, N, N)
