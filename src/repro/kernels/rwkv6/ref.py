"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head (key dim N, value dim N), with data-dependent per-channel decay
w_t in (0,1)^N and bonus u in R^N (arXiv:2404.05892):

    out_t = r_t @ S_{t-1}  +  ((r_t * u) . k_t) * v_t
    S_t   = diag(w_t) @ S_{t-1} + k_t^T v_t

Shapes: r,k,v,w: (B, T, H, N); u: (H, N); state: (B, H, N, N).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def wkv6_ref(r, k, v, w, u, initial_state=None):
    B, T, H, N = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    S0 = (
        jnp.zeros((B, H, N, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(S, xs):
        rt, kt, vt, wt = xs                       # (B, H, N) each
        inter = jnp.einsum("bhn,bhnm->bhm", rt, S)
        bonus = jnp.einsum("bhn,hn,bhn->bh", rt, uf, kt)
        out = inter + bonus[..., None] * vt
        S = wt[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, out

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf))
    S, outs = lax.scan(step, S0, xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), S.astype(jnp.float32)
