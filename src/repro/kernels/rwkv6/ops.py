"""Public WKV6 op.

``impl='xla'`` is the exact per-step ``lax.scan`` recurrence (one fused HLO
while-loop; state (B,H,N,N) in registers/HBM).  A chunked linear-attention
factorization (GLA-style) was evaluated and rejected for the default path:
the factor tensors ``exp(±cumsum(log w))`` overflow f32 once the within-chunk
decay mass exceeds ~88 nats, which RWKV-6's unbounded ``w = exp(-exp(ω))``
reaches easily — the *exact* sequential update has no such failure mode.
The Pallas kernel keeps the state in VMEM scratch and serializes time within
a (B·H, T/C) grid — numerically identical to the scan.
"""

from __future__ import annotations

import jax

from repro.kernels.rwkv6.ref import wkv6_ref


def _pick_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def wkv6(r, k, v, w, u, *, initial_state=None, impl: str = "auto"):
    """r,k,v,w: (B,T,H,N); u: (H,N).  Returns (out (B,T,H,N), state (B,H,N,N))."""
    impl = _pick_impl(impl)
    if impl in ("ref", "xla"):
        return wkv6_ref(r, k, v, w, u, initial_state)
    assert impl == "pallas", impl
    from repro.kernels.rwkv6.kernel import wkv6_pallas

    return wkv6_pallas(r, k, v, w, u, initial_state=initial_state)
