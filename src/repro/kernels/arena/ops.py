"""Public arena slice ops: impl dispatch over {auto, pallas, xla, ref}.

``xla`` lowers to ``lax.dynamic_slice`` / ``dynamic_update_slice`` — the
portable path used on CPU and inside jitted executor programs.  ``pallas``
runs the explicit TPU kernels (interpret mode off-TPU, for validation).
``auto`` picks ``pallas`` on TPU backends and ``xla`` elsewhere.  All
offsets/lengths are in *elements* of the arena dtype (see
``repro.core.executor`` for the byte conversion).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.arena.kernel import (
    arena_accum_pallas,
    arena_read_pallas,
    arena_write_pallas,
)
from repro.kernels.arena.ref import (
    arena_accum_ref,
    arena_read_ref,
    arena_write_ref,
)


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla", "ref"):
        raise ValueError(f"unknown arena impl {impl!r}")
    return impl


def arena_write(arena, x, offset: int, *, impl: str = "auto",
                interpret: bool = False):
    """Write ``x`` (1-D, arena dtype) at element ``offset``; returns arena."""
    impl = _resolve(impl)
    if impl == "pallas":
        return arena_write_pallas(arena, x, offset, interpret=interpret)
    if impl == "ref":
        return jnp.asarray(arena_write_ref(arena, x, offset))
    return jax.lax.dynamic_update_slice(arena, x, (offset,))


def arena_accum(arena, x, offset: int, *, impl: str = "auto",
                interpret: bool = False):
    """Add ``x`` into ``arena[offset : offset+n]`` in place; returns arena."""
    impl = _resolve(impl)
    if impl == "pallas":
        return arena_accum_pallas(arena, x, offset, interpret=interpret)
    if impl == "ref":
        return jnp.asarray(arena_accum_ref(arena, x, offset))
    cur = jax.lax.dynamic_slice(arena, (offset,), (x.shape[0],))
    return jax.lax.dynamic_update_slice(arena, cur + x, (offset,))


def arena_read(arena, offset: int, n: int, *, impl: str = "auto",
               interpret: bool = False):
    """Materialize ``arena[offset : offset+n]`` as a fresh ``(n,)`` array."""
    impl = _resolve(impl)
    if impl == "pallas":
        return arena_read_pallas(arena, offset, n, interpret=interpret)
    if impl == "ref":
        return jnp.asarray(arena_read_ref(arena, offset, n))
    return jax.lax.dynamic_slice(arena, (offset,), (n,))
