"""Public arena slice ops: impl dispatch over {auto, pallas, xla, ref}.

``xla`` lowers to ``lax.dynamic_slice`` / ``dynamic_update_slice`` — the
portable path used on CPU and inside jitted executor programs.  ``pallas``
runs the explicit TPU kernels (interpret mode off-TPU, for validation).
``auto`` picks ``pallas`` on TPU backends and ``xla`` elsewhere, unless the
``REPRO_ARENA_IMPL`` environment variable overrides the sniff:

    REPRO_ARENA_IMPL=pallas_interpret  # force Pallas kernels, interpret mode
    REPRO_ARENA_IMPL=xla               # force the lax slice path
    REPRO_ARENA_IMPL=pallas | ref      # likewise

The override only applies to ``impl='auto'`` call sites (an explicit impl
argument always wins) and is read per call, so CI's engine matrix can force
the pallas-interpret path deterministically without touching call sites.
All offsets/lengths are in *elements* of the arena dtype (see
``repro.core.executor`` for the byte conversion).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.arena.elemwise import apply_chain
from repro.kernels.arena.kernel import (
    arena_accum_pallas,
    arena_chain_write_pallas,
    arena_read_pallas,
    arena_write_pallas,
)
from repro.kernels.arena.ref import (
    arena_accum_ref,
    arena_chain_write_ref,
    arena_read_ref,
    arena_write_ref,
)

ENV_IMPL = "REPRO_ARENA_IMPL"
_IMPLS = ("pallas", "xla", "ref")


def _resolve(impl: str, interpret: bool) -> tuple[str, bool]:
    """Resolve ``(impl, interpret)``; 'auto' honors $REPRO_ARENA_IMPL."""
    if impl == "auto":
        env = os.environ.get(ENV_IMPL, "").strip().lower()
        if env in ("pallas_interpret", "pallas-interpret"):
            return "pallas", True
        if env in _IMPLS:
            return env, interpret
        if env:
            raise ValueError(
                f"{ENV_IMPL}={env!r}: expected one of "
                f"{_IMPLS + ('pallas_interpret',)}")
        return ("pallas" if jax.default_backend() == "tpu" else "xla",
                interpret)
    if impl not in _IMPLS:
        raise ValueError(f"unknown arena impl {impl!r}")
    return impl, interpret


def arena_write(arena, x, offset: int, *, impl: str = "auto",
                interpret: bool = False):
    """Write ``x`` (1-D, arena dtype) at element ``offset``; returns arena."""
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        return arena_write_pallas(arena, x, offset, interpret=interpret)
    if impl == "ref":
        return jnp.asarray(arena_write_ref(arena, x, offset))
    if x.shape[0] == 0:
        return arena
    return jax.lax.dynamic_update_slice(arena, x, (offset,))


def arena_accum(arena, x, offset: int, *, impl: str = "auto",
                interpret: bool = False):
    """Add ``x`` into ``arena[offset : offset+n]`` in place; returns arena."""
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        return arena_accum_pallas(arena, x, offset, interpret=interpret)
    if impl == "ref":
        return jnp.asarray(arena_accum_ref(arena, x, offset))
    if x.shape[0] == 0:
        return arena
    cur = jax.lax.dynamic_slice(arena, (offset,), (x.shape[0],))
    return jax.lax.dynamic_update_slice(arena, cur + x, (offset,))


def arena_read(arena, offset: int, n: int, *, impl: str = "auto",
               interpret: bool = False):
    """Materialize ``arena[offset : offset+n]`` as a fresh ``(n,)`` array."""
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        return arena_read_pallas(arena, offset, n, interpret=interpret)
    if impl == "ref":
        return jnp.asarray(arena_read_ref(arena, offset, n))
    return jax.lax.dynamic_slice(arena, (offset,), (n,))


def arena_chain_write(arena, x, offset: int, ops=(), *, impl: str = "auto",
                      interpret: bool = False):
    """Apply the unary elementwise chain ``ops`` to ``x``, then write the
    result at element ``offset`` — the fused execution of an in-place alias
    chain (DESIGN.md §11): one launch (pallas) / one update-slice (xla)
    instead of a read+compute+write per chain member.

    ``ops`` name entries of the canonical
    :data:`~repro.kernels.arena.elemwise.ELEMWISE_FNS` table; the pallas and
    xla paths apply the *same jnp callables* the unfused executor uses.  On
    the xla path this makes fused and slice-per-node execution bit-equal
    (identical eager op sequence); inside a single pallas kernel XLA may
    contract a chain's mul+add into an fma, so that path — like the numpy
    ``ref`` oracle — is allclose, not bit-equal.
    """
    impl, interpret = _resolve(impl, interpret)
    if impl == "pallas":
        return arena_chain_write_pallas(arena, x, offset, ops,
                                        interpret=interpret)
    if impl == "ref":
        return jnp.asarray(arena_chain_write_ref(arena, x, offset, ops))
    if x.shape[0] == 0:
        return arena
    return jax.lax.dynamic_update_slice(arena, apply_chain(x, ops), (offset,))
