"""Canonical unary-elementwise op tables shared by the executor and the
fused arena-chain kernels (DESIGN.md §11).

``ELEMWISE_FNS`` is the single source of truth for the surrogate numerics of
the in-place-eligible unary ops (the same name set as
``repro.core.rewriter.INPLACE_UNARY_OPS``): the reference interpreter, the
slice-per-node executor and the fused chain kernels all apply *these exact
jnp callables*, which is what makes fused execution on the XLA path
bit-equal to the unfused path by construction — composing f(g(x)) in
registers is the same float program as writing g(x) to the arena and
reading it back for f.  (Inside a single Pallas kernel XLA may contract a
chain's mul+add into an fma, so the one-launch kernel path is last-ulp
allclose rather than bit-equal.)

``ELEMWISE_NP`` is an independent numpy twin used only by the ``ref`` oracle
(allclose ground truth for the Pallas kernels, not bit-equality — the
transcendentals differ from XLA's in the last ulp).

Kept in ``kernels.arena`` (not ``core.executor``) so the kernels never
import the executor.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

# Each fn maps an (n,) vector to an (n,) vector element-by-element, so
# aliasing the input buffer is semantics-preserving.
ELEMWISE_FNS: dict[str, Callable] = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "relu6": lambda x: jnp.clip(x, 0.0, 6.0),
    "bn": lambda x: 1.05 * x - 0.02,
    "batchnorm": lambda x: 1.05 * x - 0.02,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "bias_add": lambda x: x + 0.05,
    "scale": lambda x: 0.9 * x,
    "dropout": lambda x: x,          # deterministic (inference) semantics
    "identity": lambda x: x,
    "cast_inplace": lambda x: x,
}


def _np_sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


def _np_gelu(x):
    # tanh approximation — matches jax.nn.gelu's default (approximate=True)
    c = np.sqrt(2.0 / np.pi).astype(x.dtype) if hasattr(x, "dtype") else \
        np.sqrt(2.0 / np.pi)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x * x * x)))


ELEMWISE_NP: dict[str, Callable] = {
    "relu": lambda x: np.maximum(x, 0.0),
    "relu6": lambda x: np.clip(x, 0.0, 6.0),
    "bn": lambda x: 1.05 * x - 0.02,
    "batchnorm": lambda x: 1.05 * x - 0.02,
    "sigmoid": _np_sigmoid,
    "tanh": np.tanh,
    "gelu": _np_gelu,
    "silu": lambda x: x * _np_sigmoid(x),
    "bias_add": lambda x: x + 0.05,
    "scale": lambda x: 0.9 * x,
    "dropout": lambda x: x,
    "identity": lambda x: x,
    "cast_inplace": lambda x: x,
}


def apply_chain(x, ops):
    """Apply a named elementwise chain with the canonical jnp callables."""
    for op in ops:
        x = ELEMWISE_FNS[op](x)
    return x


def apply_chain_np(x, ops):
    """Numpy twin of :func:`apply_chain` (the ``ref`` oracle's compute)."""
    for op in ops:
        x = ELEMWISE_NP[op](x)
    return x
