"""Pallas arena slice kernels (TPU target; interpret-mode validated on CPU).

One linear arena buffer holds every intermediate activation of a scheduled
graph at the byte offsets chosen by the offset allocator (DESIGN.md §6).
Three kernels move tensors in and out of it:

  arena_write_pallas  -- copy a tensor into ``arena[offset : offset+n]``
  arena_read_pallas   -- materialize ``arena[offset : offset+n]`` as a tensor
  arena_accum_pallas  -- ``arena[offset : offset+n] += x`` (the rewriter's
                         accumulating partial-conv step, done in place)

Offsets are *static* (schedule-time constants from the ``ArenaPlan``), so
each call site compiles to a fixed slice — no scatter/gather machinery.  The
write/accum kernels alias the arena input to the output
(``input_output_aliases``), which is what makes the arena a true in-place
buffer instead of a copy-on-write value: XLA updates the donated storage.

Units: ``offset``/lengths here are *elements* of the arena's dtype, not
bytes — callers (``repro.core.executor``) convert plan byte offsets by the
element size before dispatching.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl


def _write_kernel(x_ref, arena_ref, out_ref, *, offset: int):
    # aliased arena: copy-through keeps interpret mode (no real aliasing)
    # correct; on TPU the copy is elided because in/out share storage
    out_ref[...] = arena_ref[...]
    out_ref[pl.ds(offset, x_ref.shape[0])] = x_ref[...]


def _accum_kernel(x_ref, arena_ref, out_ref, *, offset: int):
    n = x_ref.shape[0]
    out_ref[...] = arena_ref[...]
    out_ref[pl.ds(offset, n)] = arena_ref[pl.ds(offset, n)] + x_ref[...]


def _read_kernel(arena_ref, out_ref, *, offset: int):
    out_ref[...] = arena_ref[pl.ds(offset, out_ref.shape[0])]


def arena_write_pallas(arena, x, offset: int, *, interpret: bool = False):
    """Return ``arena`` with ``x`` written at element ``offset``."""
    return pl.pallas_call(
        functools.partial(_write_kernel, offset=offset),
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(x, arena)


def arena_accum_pallas(arena, x, offset: int, *, interpret: bool = False):
    """Return ``arena`` with ``x`` added into ``arena[offset : offset+n]``."""
    return pl.pallas_call(
        functools.partial(_accum_kernel, offset=offset),
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(x, arena)


def arena_read_pallas(arena, offset: int, n: int, *, interpret: bool = False):
    """Materialize ``arena[offset : offset+n]`` as a fresh ``(n,)`` tensor."""
    return pl.pallas_call(
        functools.partial(_read_kernel, offset=offset),
        out_shape=jax.ShapeDtypeStruct((n,), arena.dtype),
        interpret=interpret,
    )(arena)
