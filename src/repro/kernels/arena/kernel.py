"""Pallas arena slice kernels (TPU target; interpret-mode validated on CPU).

One linear arena buffer holds every intermediate activation of a scheduled
graph at the byte offsets chosen by the offset allocator (DESIGN.md §6).
Four kernels move tensors in and out of it:

  arena_write_pallas        -- copy a tensor into ``arena[offset : offset+n]``
  arena_read_pallas         -- materialize ``arena[offset : offset+n]``
  arena_accum_pallas        -- ``arena[offset : offset+n] += x`` (the
                               rewriter's accumulating partial-conv step,
                               done in place)
  arena_chain_write_pallas  -- apply a whole unary elementwise alias chain
                               (relu -> bn -> ...) to ``x`` *inside the
                               kernel* and write the result once — the fused
                               execution of an in-place chain in one launch
                               instead of one write per member
                               (DESIGN.md §11)

Offsets are *static* (schedule-time constants from the ``ArenaPlan``), so
each call site compiles to a fixed slice — no scatter/gather machinery.  The
write/accum kernels alias the arena input to the output
(``input_output_aliases``), which is what makes the arena a true in-place
buffer instead of a copy-on-write value: XLA updates the donated storage.

Units: ``offset``/lengths here are *elements* of the arena's dtype, not
bytes — callers (``repro.core.executor``) convert plan byte offsets by the
element size before dispatching.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from repro.kernels.arena.elemwise import ELEMWISE_FNS


def _write_kernel(x_ref, arena_ref, out_ref, *, offset: int):
    # aliased arena: copy-through keeps interpret mode (no real aliasing)
    # correct; on TPU the copy is elided because in/out share storage
    out_ref[...] = arena_ref[...]
    out_ref[pl.ds(offset, x_ref.shape[0])] = x_ref[...]


def _accum_kernel(x_ref, arena_ref, out_ref, *, offset: int):
    n = x_ref.shape[0]
    out_ref[...] = arena_ref[...]
    out_ref[pl.ds(offset, n)] = arena_ref[pl.ds(offset, n)] + x_ref[...]


def _read_kernel(arena_ref, out_ref, *, offset: int):
    out_ref[...] = arena_ref[pl.ds(offset, out_ref.shape[0])]


def _chain_write_kernel(x_ref, arena_ref, out_ref, *, offset: int, fns):
    out_ref[...] = arena_ref[...]
    x = x_ref[...]
    for fn in fns:
        x = fn(x)
    out_ref[pl.ds(offset, x_ref.shape[0])] = x


def arena_write_pallas(arena, x, offset: int, *, interpret: bool = False):
    """Return ``arena`` with ``x`` written at element ``offset``."""
    if x.shape[0] == 0:           # pl.ds(offset, 0) is not a valid slice
        return arena
    return pl.pallas_call(
        functools.partial(_write_kernel, offset=offset),
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(x, arena)


def arena_accum_pallas(arena, x, offset: int, *, interpret: bool = False):
    """Return ``arena`` with ``x`` added into ``arena[offset : offset+n]``."""
    if x.shape[0] == 0:
        return arena
    return pl.pallas_call(
        functools.partial(_accum_kernel, offset=offset),
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(x, arena)


def arena_read_pallas(arena, offset: int, n: int, *, interpret: bool = False):
    """Materialize ``arena[offset : offset+n]`` as a fresh ``(n,)`` tensor."""
    if n == 0:
        return jax.numpy.zeros((0,), arena.dtype)
    return pl.pallas_call(
        functools.partial(_read_kernel, offset=offset),
        out_shape=jax.ShapeDtypeStruct((n,), arena.dtype),
        interpret=interpret,
    )(arena)


def arena_chain_write_pallas(arena, x, offset: int, ops=(), *,
                             interpret: bool = False):
    """Apply the elementwise chain ``ops`` to ``x`` and write it at
    element ``offset`` — one launch for a whole in-place alias chain.

    ``ops`` are names from :data:`~repro.kernels.arena.elemwise.ELEMWISE_FNS`
    (unknown names raise ``KeyError`` at trace time); the chain composes in
    kernel registers, so the launch count of a fused region is 1 regardless
    of chain length.
    """
    fns = tuple(ELEMWISE_FNS[op] for op in ops)
    if x.shape[0] == 0:
        return arena
    return pl.pallas_call(
        functools.partial(_chain_write_kernel, offset=offset, fns=fns),
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(x, arena)
