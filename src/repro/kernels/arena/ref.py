"""Pure-numpy oracle for the arena slice kernels (allclose ground truth)."""

from __future__ import annotations

import numpy as np

from repro.kernels.arena.elemwise import apply_chain_np


def arena_write_ref(arena, x, offset: int):
    out = np.array(arena)
    out[offset:offset + len(x)] = np.asarray(x)
    return out


def arena_accum_ref(arena, x, offset: int):
    out = np.array(arena)
    out[offset:offset + len(x)] += np.asarray(x)
    return out


def arena_read_ref(arena, offset: int, n: int):
    return np.array(arena[offset:offset + n])


def arena_chain_write_ref(arena, x, offset: int, ops=()):
    """Apply the named elementwise chain to ``x`` (numpy twin), then write.

    Oracle for the fused alias-chain kernel: allclose ground truth only —
    the numpy transcendentals differ from XLA's in the last ulp."""
    out = np.array(arena)
    out[offset:offset + len(x)] = apply_chain_np(np.asarray(x), ops)
    return out
