"""Pure-numpy oracle for the arena slice kernels (allclose ground truth)."""

from __future__ import annotations

import numpy as np


def arena_write_ref(arena, x, offset: int):
    out = np.array(arena)
    out[offset:offset + len(x)] = np.asarray(x)
    return out


def arena_accum_ref(arena, x, offset: int):
    out = np.array(arena)
    out[offset:offset + len(x)] += np.asarray(x)
    return out


def arena_read_ref(arena, offset: int, n: int):
    return np.array(arena[offset:offset + n])
