"""Arena slice kernels: move tensors in/out of the planned linear arena.

kernel.py  -- pl.pallas_call slice read/write/accumulate (TPU; interpret on CPU)
ops.py     -- dispatching wrappers (impl in {auto, pallas, xla, ref})
ref.py     -- numpy oracle

Used by ``repro.core.executor`` to realize ``ArenaPlan`` offsets at runtime
(DESIGN.md §6).
"""

from repro.kernels.arena.ops import arena_accum, arena_read, arena_write

__all__ = ["arena_accum", "arena_read", "arena_write"]
