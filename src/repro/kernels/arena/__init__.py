"""Arena slice kernels: move tensors in/out of the planned linear arena.

kernel.py   -- pl.pallas_call slice read/write/accumulate + fused chain
               write (TPU; interpret on CPU)
ops.py      -- dispatching wrappers (impl in {auto, pallas, xla, ref};
               $REPRO_ARENA_IMPL overrides 'auto')
elemwise.py -- canonical unary elementwise tables (jnp + numpy twin)
ref.py      -- numpy oracle

Used by ``repro.core.executor`` to realize ``ArenaPlan`` offsets at runtime
(DESIGN.md §6) and to execute fused alias chains in one launch (§11).
"""

from repro.kernels.arena.ops import (
    arena_accum,
    arena_chain_write,
    arena_read,
    arena_write,
)

__all__ = ["arena_accum", "arena_chain_write", "arena_read", "arena_write"]
