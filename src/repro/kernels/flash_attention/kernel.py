"""Flash attention Pallas TPU kernel (GQA + causal + sliding window).

Tiling (MXU/VMEM aware — DESIGN.md §1 hardware-adaptation):
  * grid = (B*H, Sq/BQ, Skv/BK); the last axis is sequential on TPU, so the
    online-softmax running state (m, l, acc) lives in VMEM scratch that
    persists across the KV-block iterations of one (head, q-block).
  * BQ = BK = 128 (MXU-aligned); head_dim D is kept whole (64..256).
  * VMEM working set per step: q (BQ·D) + k,v (2·BK·D) + acc (BQ·D f32)
    + scores (BQ·BK f32) ≈ 0.3 MB at D=128 — far below the ~16 MB/core VMEM
    budget, leaving room for the compiler's double buffering.
  * fully-masked KV blocks are skipped with @pl.when (the causal/window/
    cache-length test is on block indices only).

Validated in interpret mode against ref.attention_ref (tests/test_kernels_*).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _fa_kernel(
    qs_ref,          # scalar prefetch: (1,) int32 q_start
    kvl_ref,         # scalar prefetch: (1,) int32 kv_len
    q_ref,           # (BQ, D)
    k_ref,           # (BK, D)
    v_ref,           # (BK, D)
    o_ref,           # (BQ, D)
    m_scr,           # VMEM scratch (BQ, 1) running max
    l_scr,           # VMEM scratch (BQ, 1) running denom
    acc_scr,         # VMEM scratch (BQ, D) running numerator
    *,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    n_kv_blocks: int,
    softmax_scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    q_start = qs_ref[0]
    kv_len = kvl_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level liveness test (skip fully-masked KV blocks)
    blk_q_lo = q_start + qi * bq
    blk_q_hi = blk_q_lo + bq - 1
    blk_k_lo = ki * bk
    blk_k_hi = blk_k_lo + bk - 1
    alive = blk_k_lo < kv_len
    if causal:
        alive &= blk_k_lo <= blk_q_hi
    if window is not None:
        alive &= blk_k_hi > blk_q_lo - window

    @pl.when(alive)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * softmax_scale
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (BQ, BK)
        qpos = blk_q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = blk_k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[...]                                # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                     # (BQ, 1)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softmax_scale", "bq", "bk", "interpret"
    ),
)
def flash_attention_pallas(
    q: jnp.ndarray,                 # (B, Sq, H, D)
    k: jnp.ndarray,                 # (B, Skv, KV, D)
    v: jnp.ndarray,                 # (B, Skv, KV, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_start: int | jnp.ndarray = 0,
    kv_len: int | jnp.ndarray | None = None,
    softmax_scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = float(softmax_scale if softmax_scale is not None else D ** -0.5)

    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    n_q, n_k = Sq // bq, Skv // bk

    # layout: fold heads into the leading grid axis; kv head index = h // G
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)

    qs = jnp.asarray(q_start, jnp.int32).reshape(1)
    kvl = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(
        _fa_kernel,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        n_kv_blocks=n_k,
        softmax_scale=scale,
    )
    grid = (B * H, n_q, n_k)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (None, bq, D), lambda h, qi, ki, *_: (h, qi, 0)
                ),
                pl.BlockSpec(
                    (None, bk, D), lambda h, qi, ki, *_, G=G: (h // G, ki, 0)
                ),
                pl.BlockSpec(
                    (None, bk, D), lambda h, qi, ki, *_, G=G: (h // G, ki, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (None, bq, D), lambda h, qi, ki, *_: (h, qi, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        interpret=interpret,
    )(qs, kvl, qt, kt, vt)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
