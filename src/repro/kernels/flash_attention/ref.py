"""Pure-jnp oracle for GQA flash attention (materializes full scores)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Skv, KV, D)
    v: jnp.ndarray,          # (B, Skv, KV, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_start: int | jnp.ndarray = 0,
    kv_len: int | jnp.ndarray | None = None,
    softmax_scale: float | None = None,
) -> jnp.ndarray:
    """O(S^2)-memory reference.  ``q_start``: absolute position of q[0]
    (decode: cache length).  ``kv_len``: #valid cache entries (rest masked).
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]                 # may differ from D (e.g. MLA: 192 vs 128)
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else D ** -0.5

    qh = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qh, kf) * scale

    qpos = q_start + jnp.arange(Sq)[:, None]          # (Sq, 1)
    kpos = jnp.arange(Skv)[None, :]                   # (1, Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = jnp.where(jnp.isfinite(scores), p, 0.0)
    denom = p.sum(-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)
