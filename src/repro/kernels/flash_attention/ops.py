"""Public attention op: impl dispatch + memory-bounded XLA path.

``flash_attention(..., impl=...)``:

  * ``"pallas"``  — the TPU kernel (kernel.py); interpret=True on CPU tests.
  * ``"xla"``     — chunked online-softmax scan in pure jnp: O(S·C) memory,
                    identical math; this is what the multi-pod dry-run lowers
                    (Pallas cannot lower for the CPU placeholder backend).
  * ``"ref"``     — O(S²) oracle (tests only).
  * ``"auto"``    — pallas on TPU backends, xla elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.flash_attention.ref import attention_ref

_NEG_INF = -1e30

# Cost-probe mode: unroll the KV-chunk scan so XLA cost_analysis counts every
# chunk (while-loop bodies are otherwise counted once).  Set by the dry-run's
# probe pass only — never in production paths.
_FORCE_UNROLL = False


def set_scan_unroll(v: bool) -> None:
    global _FORCE_UNROLL
    _FORCE_UNROLL = bool(v)


def _pick_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def flash_attention(
    q: jnp.ndarray,                     # (B, Sq, H, D)
    k: jnp.ndarray,                     # (B, Skv, KV, D)
    v: jnp.ndarray,                     # (B, Skv, KV, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_start: int | jnp.ndarray = 0,
    kv_len: int | jnp.ndarray | None = None,
    softmax_scale: float | None = None,
    impl: str = "auto",
    kv_chunk: int = 1024,
    skip_masked_blocks: bool = True,
) -> jnp.ndarray:
    impl = _pick_impl(impl)
    if impl == "ref":
        return attention_ref(
            q, k, v, causal=causal, window=window, q_start=q_start,
            kv_len=kv_len, softmax_scale=softmax_scale,
        )
    if impl == "pallas":
        from repro.kernels.flash_attention.kernel import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_start=q_start,
            kv_len=kv_len, softmax_scale=softmax_scale,
        )
    assert impl == "xla", impl
    return _flash_xla(
        q, k, v, causal=causal, window=window, q_start=q_start,
        kv_len=kv_len, softmax_scale=softmax_scale, kv_chunk=kv_chunk,
        skip_masked_blocks=skip_masked_blocks,
    )


def _flash_xla(
    q, k, v, *, causal, window, q_start, kv_len, softmax_scale, kv_chunk,
    skip_masked_blocks,
):
    """Online-softmax scan over KV chunks (flash algorithm in XLA).

    Fully-masked chunks are skipped with lax.cond when
    ``skip_masked_blocks`` (hot for causal prefill and short decode caches:
    only ~half / ~t/S of the chunks do work).
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]                 # may differ from D (e.g. MLA: 192 vs 128)
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    C = min(kv_chunk, Skv)
    if Skv % C:
        pad = C - Skv % C
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = Skv if kv_len is None else kv_len
        Skv = Skv + pad
    n_chunks = Skv // C

    qh = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, D)
    qpos = q_start + jnp.arange(Sq)                           # (Sq,)
    q_hi = q_start + Sq - 1

    kc = k.reshape(B, n_chunks, C, KV, D)
    vc = v.reshape(B, n_chunks, C, KV, Dv)

    def chunk_update(carry, ci):
        m, l, acc = carry
        ks = kc[:, ci].astype(jnp.float32)                    # (B, C, KV, D)
        vs = vc[:, ci].astype(jnp.float32)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qh, ks)           # (B,Sq,KV,G,C)
        kpos = ci * C + jnp.arange(C)
        mask = jnp.ones((Sq, C), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vs
        )
        return (m_new, l_new, acc_new), None

    def chunk_step(carry, ci):
        if not skip_masked_blocks:
            return chunk_update(carry, ci)
        lo = ci * C                     # first kv position in chunk
        hi = lo + C - 1
        alive = jnp.array(True)
        if causal:
            alive &= lo <= q_hi
        if window is not None:
            alive &= hi > q_start - window
        if kv_len is not None:
            alive &= lo < kv_len
        return lax.cond(
            alive, lambda c: chunk_update(c, ci), lambda c: (c, None), carry
        )

    m0 = jnp.full((B, Sq, KV, G), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, Dv), jnp.float32)
    (m, l, acc), _ = lax.scan(chunk_step, (m0, l0, a0), jnp.arange(n_chunks),
                              unroll=_FORCE_UNROLL)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)
