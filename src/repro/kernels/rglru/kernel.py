"""RG-LRU Pallas TPU kernel.

Grid (B, T/C) with the time axis sequential: the (1, D) carry lives in VMEM
scratch across chunk iterations.  Within a chunk an exact fori_loop applies
the elementwise affine recurrence — pure VPU work, D lanes wide.

  log_a, gx chunks: (C, D) each; carry scratch (1, D) f32.
  C=256, D<=2560  ->  ~2.6 MB working set, inside the VMEM budget.

Validated in interpret mode against ref.rglru_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(la_ref, gx_ref, h0_ref, h_ref, hT_ref, carry_scr,
                  *, chunk: int, n_chunks: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        carry_scr[...] = h0_ref[...].astype(jnp.float32)

    def step(t, _):
        la = la_ref[t, :].astype(jnp.float32)[None, :]
        x = gx_ref[t, :].astype(jnp.float32)[None, :]
        a = jnp.exp(la)
        b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * la), 0.0, 1.0)) * x
        h = a * carry_scr[...] + b
        carry_scr[...] = h
        h_ref[t, :] = h[0].astype(h_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ti == n_chunks - 1)
    def _emit():
        hT_ref[...] = carry_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_pallas(log_a, gx, h0=None, *, chunk: int = 256,
                 interpret: bool = False):
    B, T, D = log_a.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    n_chunks = T // C
    h0 = (
        jnp.zeros((B, 1, D), jnp.float32)
        if h0 is None
        else h0.reshape(B, 1, D).astype(jnp.float32)
    )
    kernel = functools.partial(_rglru_kernel, chunk=C, n_chunks=n_chunks)
    h, hT = pl.pallas_call(
        kernel,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((None, C, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((None, C, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((None, 1, D), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, C, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((None, 1, D), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), gx.dtype),
            jax.ShapeDtypeStruct((B, 1, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(log_a, gx, h0)
    return h, hT.reshape(B, D)
