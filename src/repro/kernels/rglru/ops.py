"""Public RG-LRU op.

``impl='xla'`` uses ``lax.associative_scan`` over the affine maps
(h -> a*h + b): combine((a1,b1),(a2,b2)) = (a1*a2, a2*b1 + b2) — O(log T)
depth, fully parallel, the right shape for XLA:TPU without a custom kernel.
The Pallas kernel instead streams time chunks through VMEM with the carry in
scratch (decode/serving shape), identical math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.rglru.ref import rglru_ref


def _pick_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def rglru(log_a, gx, h0=None, *, impl: str = "auto"):
    """log_a, gx: (B,T,D).  Returns (h (B,T,D), h_T (B,D))."""
    impl = _pick_impl(impl)
    if impl == "ref":
        return rglru_ref(log_a, gx, h0)
    if impl == "pallas":
        from repro.kernels.rglru.kernel import rglru_pallas

        return rglru_pallas(log_a, gx, h0)
    assert impl == "xla", impl
    la = log_a.astype(jnp.float32)
    a = jnp.exp(la)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * la), 0.0, 1.0)) * gx.astype(
        jnp.float32
    )
    if h0 is not None:
        # fold the initial state into step 0: b_0 <- a_0 * h0 + b_0
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(gx.dtype), h[:, -1]
