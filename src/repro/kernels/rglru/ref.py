"""Pure-jnp oracle for the RG-LRU gated linear recurrence (Griffin,
arXiv:2402.19427).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

where a_t = exp(log_a_t) is the data-dependent per-channel gate computed by
the block (log_a = -c * softplus(Lambda) * sigma(W_a x), c = 8).  The kernel
consumes precomputed ``log_a`` and gated input ``gx = i_t * x_t``.
Shapes: log_a, gx: (B, T, D); h0: (B, D).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def rglru_ref(log_a, gx, h0=None):
    B, T, D = log_a.shape
    la = log_a.astype(jnp.float32)
    x = gx.astype(jnp.float32)
    a = jnp.exp(la)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * la), 0.0, 1.0)) * x
    h = jnp.zeros((B, D), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    hT, hs = lax.scan(step, h, (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2).astype(gx.dtype), hT
