"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel is a package ``kernels/<name>/`` with:
  kernel.py  -- pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     -- jit'd public wrapper; dispatches impl in {auto, pallas, xla, ref}
               ("xla" = memory-bounded chunked pure-jnp path used on CPU and
               by the multi-pod dry-run; identical math)
  ref.py     -- pure-jnp oracle (the allclose ground truth)

Kernels: flash_attention (GQA/MQA + causal + sliding window),
rwkv6 (WKV6 recurrence), rglru (RG-LRU gated linear recurrence).

SERENITY tie-in: block sizes are chosen so each kernel's VMEM working set
stays under the per-core budget -- the same cap-and-schedule reasoning the
paper applies to edge SRAM (DESIGN.md section 1).
"""
