"""SwiftNet cells (Zhang et al., 2019) as SERENITY graphs — reconstructed.

SwiftNet's exact cell wiring is not published as a machine-readable genotype;
we reconstruct cells with the node counts the paper reports in Table 2
(62 nodes = {21, 19, 22}) and the structure its Fig. 3(a) shows: several
depthwise-separable branches with *irregular cross-branch skip wiring*, all
merged by one wide concatenation feeding a 1x1 convolution.  Absolute KB
therefore differ from the paper; the *ratios* (DP vs. Kahn/TFLite order,
rewriting delta) are the validated quantities — see EXPERIMENTS.md
§Paper-validation.

HPD input regime: 112x112 grayscale; cell A runs at 56x56 with few channels.
"""

from __future__ import annotations

from repro.core.graph import Graph


def _cell(
    name: str,
    hw: int,
    cin: int,
    branch_specs: list[list[int]],
    cross_edges: list[tuple[int, int, int, int]],
    dtype_bytes: int = 4,
) -> Graph:
    """Build one cell.

    ``branch_specs``  per-branch list of channel widths; stage = depthconv
                      followed by a 1x1 conv at that width (dw-separable).
    ``cross_edges``   (src_branch, src_stage, dst_branch, dst_stage) skip
                      links: the dst stage's dwconv additionally sums the src
                      stage's output (irregular wiring — requires matching
                      widths; the builder adds an `add` node).
    All branches merge in ONE wide concat -> 1x1 conv (the paper's memory-
    pressure pattern, Fig. 9).
    """
    specs: list[dict] = []

    def add(name_, op, size, preds=(), weight=0):
        specs.append(
            dict(name=name_, op=op, size_bytes=int(size), preds=list(preds),
                 weight_bytes=int(weight))
        )
        return len(specs) - 1

    px = hw * hw * dtype_bytes
    expand = 6  # MobileNetV2/SwiftNet inverted-residual expansion factor
    inp = add("in", "input", px * cin)
    stage_out: dict[tuple[int, int], tuple[int, int]] = {}  # (b,s) -> (id, ch)
    cross_by_dst: dict[tuple[int, int], list[tuple[int, int]]] = {}
    for sb, ss, db, ds in cross_edges:
        cross_by_dst.setdefault((db, ds), []).append((sb, ss))

    # build stages in dependency order (cross edges may point "forward"
    # between branches, so round-robin until every stage is placed)
    cursor = {b: (inp, cin, 0) for b in range(len(branch_specs))}
    remaining = sum(len(w) for w in branch_specs)
    while remaining:
        progressed = False
        for b, widths in enumerate(branch_specs):
            x, ch, s = cursor[b]
            if s >= len(widths):
                continue
            srcs = cross_by_dst.get((b, s), ())
            if any((sb, ss) not in stage_out for (sb, ss) in srcs):
                continue
            w = widths[s]
            if srcs:
                # weighted-sum join of same-resolution feature maps
                pred_ids = [x] + [stage_out[(sb, ss)][0] for (sb, ss) in srcs]
                x = add(f"b{b}.s{s}.join", "add", px * ch, pred_ids)
            # inverted residual: expand 1x1 -> depthwise -> project 1x1
            hidden = ch * expand
            e = add(f"b{b}.s{s}.expand", "conv", px * hidden, [x],
                    weight=ch * hidden * dtype_bytes)
            d = add(f"b{b}.s{s}.dw", "depthconv", px * hidden, [e],
                    weight=hidden * 9 * dtype_bytes)
            x = add(f"b{b}.s{s}.pw", "conv", px * w, [d],
                    weight=hidden * w * dtype_bytes)
            ch = w
            stage_out[(b, s)] = (x, ch)
            cursor[b] = (x, ch, s + 1)
            remaining -= 1
            progressed = True
        if not progressed:
            raise ValueError("cyclic cross_edges")
    for b, widths in enumerate(branch_specs):
        x, ch, _ = cursor[b]
        stage_out[(b, "out")] = (x, ch)

    concat_in = [stage_out[(b, "out")][0] for b in range(len(branch_specs))]
    cout = sum(stage_out[(b, "out")][1] for b in range(len(branch_specs)))
    cc = add("cell.concat", "concat", px * cout, concat_in)
    add("out.pw", "conv", px * cin, [cc], weight=cout * cin * dtype_bytes)
    return Graph.build(specs, name=name)


def swiftnet_cell(which: str = "A", dtype_bytes: int = 4) -> Graph:
    """Cells A/B/C with node counts 21/19/22 (paper Table 2)."""
    # node count = 1(in) + 3*sum(stages) + len(cross_edges) + 1(concat) + 1(out)
    if which == "A":
        # 1 + 3*5 + 3 + 2 = 21
        return _cell(
            "swiftnet_cell_a", hw=56, cin=16,
            branch_specs=[[16, 24], [16], [24], [16]],
            cross_edges=[(1, 0, 0, 0), (1, 0, 0, 1), (3, 0, 2, 0)],
            dtype_bytes=dtype_bytes,
        )
    if which == "B":
        # 1 + 3*5 + 1 + 2 = 19
        return _cell(
            "swiftnet_cell_b", hw=28, cin=32,
            branch_specs=[[32, 48], [32], [48], [32]],
            cross_edges=[(1, 0, 0, 1)],
            dtype_bytes=dtype_bytes,
        )
    if which == "C":
        # 1 + 3*6 + 1 + 2 = 22
        return _cell(
            "swiftnet_cell_c", hw=14, cin=64,
            branch_specs=[[64, 96], [64, 96], [96], [64]],
            cross_edges=[(1, 0, 0, 1)],
            dtype_bytes=dtype_bytes,
        )
    raise ValueError(which)


def swiftnet_network(dtype_bytes: int = 4) -> Graph:
    """All three cells chained (62 nodes): the Table 2 whole-network case."""
    cells = [swiftnet_cell(w, dtype_bytes) for w in ("A", "B", "C")]
    specs: list[dict] = []
    offset = 0
    prev_out: int | None = None
    for ci, cell in enumerate(cells):
        for nd in cell.nodes:
            preds = [p + offset for p in nd.preds]
            if nd.op == "input" and prev_out is not None:
                # stitch: the cell input becomes a strided depthconv of the
                # previous cell's output (downsampling transition).
                specs.append(
                    dict(name=f"c{ci}.{nd.name}", op="depthconv",
                         size_bytes=nd.size_bytes, preds=[prev_out],
                         weight_bytes=9 * dtype_bytes * 64)
                )
            else:
                specs.append(
                    dict(name=f"c{ci}.{nd.name}", op=nd.op,
                         size_bytes=nd.size_bytes, preds=preds,
                         alias_preds=set(nd.alias_preds),
                         weight_bytes=nd.weight_bytes)
                )
        prev_out = offset + len(cell) - 1
        offset += len(cell)
    return Graph.build(specs, name="swiftnet_62")
