"""Benchmark graph generators for the paper's evaluation networks.

darts     -- DARTS learned normal cell (Liu et al., 2019), ImageNet config
swiftnet  -- SwiftNet cells (Zhang et al., 2019), HPD config (reconstructed)
randwire  -- RandWire WS random graphs (Xie et al., 2019), CIFAR configs
"""

from repro.graphs.darts import darts_normal_cell
from repro.graphs.randwire import randwire_graph
from repro.graphs.swiftnet import swiftnet_cell, swiftnet_network

BENCHMARK_GRAPHS = {
    "darts_imagenet_cell": lambda: darts_normal_cell(),
    "swiftnet_cell_a": lambda: swiftnet_cell("A"),
    "swiftnet_cell_b": lambda: swiftnet_cell("B"),
    "swiftnet_cell_c": lambda: swiftnet_cell("C"),
    "randwire_cifar10": lambda: randwire_graph(seed=10),
    "randwire_cifar100": lambda: randwire_graph(seed=100),
}

__all__ = [
    "BENCHMARK_GRAPHS",
    "darts_normal_cell",
    "randwire_graph",
    "swiftnet_cell",
]
