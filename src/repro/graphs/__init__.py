"""Benchmark graph generators for the paper's evaluation networks.

darts     -- DARTS learned normal cell (Liu et al., 2019), ImageNet config
swiftnet  -- SwiftNet cells (Zhang et al., 2019), HPD config (reconstructed)
randwire  -- RandWire WS random graphs (Xie et al., 2019), CIFAR configs

``BENCHMARK_GRAPHS`` are the paper's single-cell workloads (every tier-1
engine-parity test runs the exact DP on each of them).  ``FULL_NETWORKS``
are the stacked ≥200-node deployments — RandWire with 8 repeated WS(32)
stages, DARTS with 6 repeated normal cells — that exercise the hierarchical
partition + isomorphic-cell reuse path end to end; they are benchmark-only
(a flat exact DP cannot finish them, which is the point).
"""

from repro.graphs.darts import darts_network, darts_normal_cell
from repro.graphs.randwire import randwire_graph, randwire_network
from repro.graphs.swiftnet import swiftnet_cell, swiftnet_network

BENCHMARK_GRAPHS = {
    "darts_imagenet_cell": lambda: darts_normal_cell(),
    "swiftnet_cell_a": lambda: swiftnet_cell("A"),
    "swiftnet_cell_b": lambda: swiftnet_cell("B"),
    "swiftnet_cell_c": lambda: swiftnet_cell("C"),
    "randwire_cifar10": lambda: randwire_graph(seed=10),
    "randwire_cifar100": lambda: randwire_graph(seed=100),
}

FULL_NETWORKS = {
    "randwire_net_32x8": lambda: randwire_network(n_cells=8, n=32),
    "darts_net_x6": lambda: darts_network(n_cells=6),
}

__all__ = [
    "BENCHMARK_GRAPHS",
    "FULL_NETWORKS",
    "darts_network",
    "darts_normal_cell",
    "randwire_graph",
    "randwire_network",
    "swiftnet_cell",
    "swiftnet_network",
]
