"""RandWire random network graphs (Xie et al., ICCV 2019) as SERENITY graphs.

RandWire's published recipe: Watts–Strogatz WS(N=32, K=4, P=0.75) random
graphs, converted to DAGs by orienting every edge from lower to higher node
id.  Each graph node is a ReLU -> separable-conv -> BN triplet whose inputs
are aggregated by a learned weighted sum; nodes with no in-edges read the
stage input, nodes with no out-edges average into the stage output.

CIFAR regime (the paper's RandWire rows): 32x32 images, small channel count
(C=78 for the CIFAR10 model, C=154 for CIFAR100), first stage at 16x16.
"""

from __future__ import annotations

import networkx as nx

from repro.core.graph import Graph


def randwire_graph(
    seed: int = 10,
    n: int = 32,
    k: int = 4,
    p: float = 0.75,
    hw: int = 16,
    channels: int | None = None,
    dtype_bytes: int = 4,
) -> Graph:
    if channels is None:
        channels = 78 if seed % 2 == 0 else 109
    ws = nx.connected_watts_strogatz_graph(n, k, p, seed=seed)
    dag_edges = sorted((min(u, v), max(u, v)) for u, v in ws.edges())
    preds: dict[int, list[int]] = {i: [] for i in range(n)}
    for u, v in dag_edges:
        preds[v].append(u)

    fmap = hw * hw * channels * dtype_bytes
    sep_w = (channels * 9 + channels * channels) * dtype_bytes
    specs: list[dict] = []

    def add(name, op, size, pr=(), weight=0):
        specs.append(dict(name=name, op=op, size_bytes=size, preds=list(pr),
                          weight_bytes=weight))
        return len(specs) - 1

    # One IR node per RandWire node — the paper's scheduling granularity:
    # weighted-sum + ReLU + sepconv + BN fuse into the node (the fused
    # intermediates are same-sized as the output and die within the op).
    stage_in = add("stage_in", "input", fmap)
    out_of: dict[int, int] = {}
    for v in range(n):
        srcs = [out_of[u] for u in sorted(preds[v])] or [stage_in]
        out_of[v] = add(f"n{v}.sepconv", "conv", fmap, srcs, weight=sep_w)
    # nodes with no out-edges in the DAG feed the stage output:
    has_out = {u for u, _ in dag_edges}
    sinks = [out_of[v] for v in range(n) if v not in has_out]
    mean = add("stage_out.mean", "add", fmap, sinks)
    add("stage_out.pw", "conv", fmap, [mean],
        weight=channels * channels * dtype_bytes)
    return Graph.build(specs, name=f"randwire_ws{n}_{k}_{seed}")
