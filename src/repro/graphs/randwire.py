"""RandWire random network graphs (Xie et al., ICCV 2019) as SERENITY graphs.

RandWire's published recipe: Watts–Strogatz WS(N=32, K=4, P=0.75) random
graphs, converted to DAGs by orienting every edge from lower to higher node
id.  Each graph node is a ReLU -> separable-conv -> BN triplet whose inputs
are aggregated by a learned weighted sum; nodes with no in-edges read the
stage input, nodes with no out-edges average into the stage output.

CIFAR regime (the paper's RandWire rows): 32x32 images, small channel count
(C=78 for the CIFAR10 model, C=154 for CIFAR100), first stage at 16x16.

``randwire_graph``   — one WS stage (the paper's scheduling benchmark).
``randwire_network`` — a *stacked* network of ``n_cells`` WS stages chained
through per-stage 1x1 projections, the full-network workload for the
hierarchical scheduler: each stage is a partition cell, and with a single
``seed`` every stage is structurally identical, so the isomorphic-cell plan
reuse schedules one cell and replays it (DESIGN.md §8).
"""

from __future__ import annotations

from typing import Sequence

import networkx as nx

from repro.core.graph import Graph


def _ws_dag_preds(seed: int, n: int, k: int, p: float) -> dict[int, list[int]]:
    """WS(n, k, p) oriented low->high id: per-node DAG predecessor lists."""
    ws = nx.connected_watts_strogatz_graph(n, k, p, seed=seed)
    dag_edges = sorted((min(u, v), max(u, v)) for u, v in ws.edges())
    preds: dict[int, list[int]] = {i: [] for i in range(n)}
    for u, v in dag_edges:
        preds[v].append(u)
    return preds


def _add_stage(
    specs: list[dict],
    stage_in: int,
    *,
    seed: int,
    n: int,
    k: int,
    p: float,
    fmap: int,
    sep_w: int,
    prefix: str = "",
) -> int:
    """Append one WS stage reading ``stage_in``; returns the mean node id."""
    preds = _ws_dag_preds(seed, n, k, p)

    def add(name, op, size, pr=(), weight=0):
        specs.append(dict(name=name, op=op, size_bytes=size, preds=list(pr),
                          weight_bytes=weight))
        return len(specs) - 1

    # One IR node per RandWire node — the paper's scheduling granularity:
    # weighted-sum + ReLU + sepconv + BN fuse into the node (the fused
    # intermediates are same-sized as the output and die within the op).
    out_of: dict[int, int] = {}
    for v in range(n):
        srcs = [out_of[u] for u in sorted(preds[v])] or [stage_in]
        out_of[v] = add(f"{prefix}n{v}.sepconv", "conv", fmap, srcs,
                        weight=sep_w)
    # nodes with no out-edges in the DAG feed the stage output:
    has_out = {u for v in range(n) for u in preds[v]}
    sinks = [out_of[v] for v in range(n) if v not in has_out]
    return add(f"{prefix}stage_out.mean", "add", fmap, sinks)


def randwire_graph(
    seed: int = 10,
    n: int = 32,
    k: int = 4,
    p: float = 0.75,
    hw: int = 16,
    channels: int | None = None,
    dtype_bytes: int = 4,
) -> Graph:
    if channels is None:
        channels = 78 if seed % 2 == 0 else 109
    fmap = hw * hw * channels * dtype_bytes
    sep_w = (channels * 9 + channels * channels) * dtype_bytes
    specs: list[dict] = []
    specs.append(dict(name="stage_in", op="input", size_bytes=fmap, preds=[],
                      weight_bytes=0))
    mean = _add_stage(specs, 0, seed=seed, n=n, k=k, p=p, fmap=fmap,
                      sep_w=sep_w)
    specs.append(dict(name="stage_out.pw", op="conv", size_bytes=fmap,
                      preds=[mean],
                      weight_bytes=channels * channels * dtype_bytes))
    return Graph.build(specs, name=f"randwire_ws{n}_{k}_{seed}")


def randwire_network(
    n_cells: int = 8,
    seed: int | Sequence[int] = 10,
    n: int = 32,
    k: int = 4,
    p: float = 0.75,
    hw: int = 16,
    channels: int | None = None,
    dtype_bytes: int = 4,
) -> Graph:
    """A stacked RandWire network: ``n_cells`` WS stages chained end to end.

    Each stage is the :func:`randwire_graph` cell (one WS random graph
    aggregated by a mean and projected by a 1x1 conv); stage ``i+1`` reads
    stage ``i``'s projection.  With a scalar ``seed`` every stage shares the
    wiring — the weight-shared repeated-cell deployment NAS networks use —
    so the partition tree's leaves are isomorphic and the scheduler plans
    one cell and replays it for the rest.  Pass a sequence of seeds for
    per-stage random wiring (every cell then schedules independently).

    ``n_cells=8, n=32`` gives a 274-node network — the ≥200-node
    full-network workload the scheduling-time benchmarks track.
    """
    seeds = list(seed) if isinstance(seed, (list, tuple)) else [seed] * n_cells
    if len(seeds) != n_cells:
        raise ValueError(f"need {n_cells} seeds, got {len(seeds)}")
    if channels is None:
        channels = 78 if seeds[0] % 2 == 0 else 109
    fmap = hw * hw * channels * dtype_bytes
    sep_w = (channels * 9 + channels * channels) * dtype_bytes
    specs: list[dict] = []
    specs.append(dict(name="stem", op="input", size_bytes=fmap, preds=[],
                      weight_bytes=0))
    x = 0
    for ci, s in enumerate(seeds):
        mean = _add_stage(specs, x, seed=s, n=n, k=k, p=p, fmap=fmap,
                          sep_w=sep_w, prefix=f"c{ci}.")
        specs.append(dict(name=f"c{ci}.pw", op="conv", size_bytes=fmap,
                          preds=[mean],
                          weight_bytes=channels * channels * dtype_bytes))
        x = len(specs) - 1
    specs.append(dict(name="head.pool", op="pool",
                      size_bytes=channels * dtype_bytes, preds=[x]))
    tag = f"s{seeds[0]}" if len(set(seeds)) == 1 else "mix"
    return Graph.build(specs, name=f"randwire_net_ws{n}_{k}_x{n_cells}_{tag}")
