"""DARTS learned normal cell (Liu et al., ICLR 2019) as a SERENITY graph.

Published DARTS_V2 genotype, normal cell:

    normal = [(sep_conv_3x3, 0), (sep_conv_3x3, 1),
              (sep_conv_3x3, 0), (sep_conv_3x3, 1),
              (sep_conv_3x3, 1), (skip_connect, 0),
              (skip_connect, 0), (dil_conv_3x3, 2)]
    concat = [2, 3, 4, 5]

Each intermediate node sums two operand branches; a sep_conv is the standard
ReLU-Conv(dw)-Conv(1x1)-BN stack applied twice; dil_conv applies it once.  The
paper evaluates the *first* normal cell of the ImageNet network (highest
footprint): feature maps 28x28, C=48 channels after the stem, float32.

``darts_normal_cell`` — the single-cell benchmark above.
``darts_network``     — the deployed form: one discovered cell repeated
``n_cells`` times.  ``double_skip=False`` (default) wires each cell off its
predecessor's output only, the hourglass chain the hierarchical scheduler
decomposes exactly; ``double_skip=True`` adds the genotype's ``c_{k-2}``
skip, which keeps *two* tensors live across every cell boundary — no
single-node separator exists and the search has to rely on pruning alone
(the stress case for branch and bound).
"""

from __future__ import annotations

from repro.core.graph import Graph

# (op, input_index) pairs per intermediate node; indices 0,1 are the two cell
# inputs, 2.. are previous intermediate nodes.
DARTS_V2_NORMAL = [
    [("sep_conv_3x3", 0), ("sep_conv_3x3", 1)],   # node 2
    [("sep_conv_3x3", 0), ("sep_conv_3x3", 1)],   # node 3
    [("sep_conv_3x3", 1), ("skip_connect", 0)],   # node 4
    [("skip_connect", 0), ("dil_conv_3x3", 2)],   # node 5
]
CONCAT = [2, 3, 4, 5]


def _add_cell(
    specs: list[dict],
    in0: int,
    in1: int,
    *,
    fmap: int,
    sep_w: int,
    tag: str = "",
) -> int:
    """Append one DARTS_V2 normal cell reading (in0, in1); returns the
    concat node id (the cell output before the transition conv)."""

    def add(name, op, size, preds=(), weight=0):
        specs.append(
            dict(name=name, op=op, size_bytes=size, preds=list(preds),
                 weight_bytes=weight)
        )
        return len(specs) - 1

    def sep_conv(tag_: str, src: int) -> int:
        # ReLU -> dwconv -> pwconv -> BN, twice (DARTS SepConv definition).
        x = src
        for rep in range(2):
            r = add(f"{tag_}.relu{rep}", "relu", fmap, [x])
            d = add(f"{tag_}.dw{rep}", "depthconv", fmap, [r],
                    weight=sep_w // 2)
            p = add(f"{tag_}.pw{rep}", "conv", fmap, [d], weight=sep_w // 2)
            x = add(f"{tag_}.bn{rep}", "bn", fmap, [p])
        return x

    def dil_conv(tag_: str, src: int) -> int:
        r = add(f"{tag_}.relu", "relu", fmap, [src])
        d = add(f"{tag_}.dw", "depthconv", fmap, [r], weight=sep_w // 2)
        p = add(f"{tag_}.pw", "conv", fmap, [d], weight=sep_w // 2)
        return add(f"{tag_}.bn", "bn", fmap, [p])

    node_out = {0: in0, 1: in1}
    for i, edges in enumerate(DARTS_V2_NORMAL):
        node_id = i + 2
        branch_outs = []
        for j, (op, src_idx) in enumerate(edges):
            src = node_out[src_idx]
            btag = f"{tag}n{node_id}.e{j}.{op}"
            if op == "sep_conv_3x3":
                branch_outs.append(sep_conv(btag, src))
            elif op == "dil_conv_3x3":
                branch_outs.append(dil_conv(btag, src))
            elif op == "skip_connect":
                branch_outs.append(src)
            else:
                raise ValueError(op)
        node_out[node_id] = add(f"{tag}n{node_id}.add", "add", fmap,
                                branch_outs)

    concat_in = [node_out[i] for i in CONCAT]
    return add(f"{tag}cell.concat", "concat", fmap * len(CONCAT), concat_in)


def darts_normal_cell(
    hw: int = 28, channels: int = 48, dtype_bytes: int = 4
) -> Graph:
    fmap = hw * hw * channels * dtype_bytes          # one C-channel feature map
    k = 3
    sep_w = (channels * k * k + channels * channels) * dtype_bytes  # dw + pw
    specs: list[dict] = []
    specs.append(dict(name="c_{k-2}", op="input", size_bytes=fmap, preds=[],
                      weight_bytes=0))
    specs.append(dict(name="c_{k-1}", op="input", size_bytes=fmap, preds=[],
                      weight_bytes=0))
    cc = _add_cell(specs, 0, 1, fmap=fmap, sep_w=sep_w)
    # cells are followed by a 1x1 conv when channels change; model the
    # downstream consumer so concat liveness is realistic:
    specs.append(dict(name="next.pw", op="conv", size_bytes=fmap, preds=[cc],
                      weight_bytes=4 * channels * channels * dtype_bytes))
    return Graph.build(specs, name="darts_imagenet_cell")


def darts_network(
    n_cells: int = 6,
    hw: int = 28,
    channels: int = 48,
    dtype_bytes: int = 4,
    double_skip: bool = False,
) -> Graph:
    """The deployed DARTS form: one normal cell repeated ``n_cells`` times.

    Every cell's concat feeds a 1x1 transition conv whose output is the next
    cell's input, so with ``double_skip=False`` each transition is a
    single-node separator and the partition tree reduces the network to
    ``n_cells`` isomorphic leaves — scheduled once, replayed for the rest.
    ``double_skip=True`` additionally feeds each cell its grandparent's
    transition output (the published genotype's ``c_{k-2}`` input): two
    tensors then stay live across every boundary, no separator exists, and
    the whole network is a single exact-search cell (branch-and-bound
    stress case; expect the soft-budget/beam machinery at realistic sizes).

    ``n_cells=6`` gives a 207-node chain (``double_skip`` adds no nodes,
    only edges).
    """
    fmap = hw * hw * channels * dtype_bytes
    k = 3
    sep_w = (channels * k * k + channels * channels) * dtype_bytes
    specs: list[dict] = []
    specs.append(dict(name="stem", op="input", size_bytes=fmap, preds=[],
                      weight_bytes=0))
    prev_prev = prev = 0
    for ci in range(n_cells):
        in0 = prev_prev if double_skip else prev
        cc = _add_cell(specs, in0, prev, fmap=fmap, sep_w=sep_w,
                       tag=f"c{ci}.")
        specs.append(dict(name=f"c{ci}.trans.pw", op="conv", size_bytes=fmap,
                          preds=[cc],
                          weight_bytes=4 * channels * channels * dtype_bytes))
        prev_prev, prev = prev, len(specs) - 1
    tag = "skip" if double_skip else "chain"
    return Graph.build(specs, name=f"darts_net_x{n_cells}_{tag}")
