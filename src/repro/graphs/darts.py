"""DARTS learned normal cell (Liu et al., ICLR 2019) as a SERENITY graph.

Published DARTS_V2 genotype, normal cell:

    normal = [(sep_conv_3x3, 0), (sep_conv_3x3, 1),
              (sep_conv_3x3, 0), (sep_conv_3x3, 1),
              (sep_conv_3x3, 1), (skip_connect, 0),
              (skip_connect, 0), (dil_conv_3x3, 2)]
    concat = [2, 3, 4, 5]

Each intermediate node sums two operand branches; a sep_conv is the standard
ReLU-Conv(dw)-Conv(1x1)-BN stack applied twice; dil_conv applies it once.  The
paper evaluates the *first* normal cell of the ImageNet network (highest
footprint): feature maps 28x28, C=48 channels after the stem, float32.
"""

from __future__ import annotations

from repro.core.graph import Graph

# (op, input_index) pairs per intermediate node; indices 0,1 are the two cell
# inputs, 2.. are previous intermediate nodes.
DARTS_V2_NORMAL = [
    [("sep_conv_3x3", 0), ("sep_conv_3x3", 1)],   # node 2
    [("sep_conv_3x3", 0), ("sep_conv_3x3", 1)],   # node 3
    [("sep_conv_3x3", 1), ("skip_connect", 0)],   # node 4
    [("skip_connect", 0), ("dil_conv_3x3", 2)],   # node 5
]
CONCAT = [2, 3, 4, 5]


def darts_normal_cell(
    hw: int = 28, channels: int = 48, dtype_bytes: int = 4
) -> Graph:
    fmap = hw * hw * channels * dtype_bytes          # one C-channel feature map
    specs: list[dict] = []

    def add(name, op, size, preds=(), weight=0):
        specs.append(
            dict(name=name, op=op, size_bytes=size, preds=list(preds),
                 weight_bytes=weight)
        )
        return len(specs) - 1

    k = 3
    sep_w = (channels * k * k + channels * channels) * dtype_bytes  # dw + pw
    node_out = {}
    node_out[0] = add("c_{k-2}", "input", fmap)
    node_out[1] = add("c_{k-1}", "input", fmap)

    def sep_conv(tag: str, src: int) -> int:
        # ReLU -> dwconv -> pwconv -> BN, twice (DARTS SepConv definition).
        x = src
        for rep in range(2):
            r = add(f"{tag}.relu{rep}", "relu", fmap, [x])
            d = add(f"{tag}.dw{rep}", "depthconv", fmap, [r], weight=sep_w // 2)
            p = add(f"{tag}.pw{rep}", "conv", fmap, [d], weight=sep_w // 2)
            x = add(f"{tag}.bn{rep}", "bn", fmap, [p])
        return x

    def dil_conv(tag: str, src: int) -> int:
        r = add(f"{tag}.relu", "relu", fmap, [src])
        d = add(f"{tag}.dw", "depthconv", fmap, [r], weight=sep_w // 2)
        p = add(f"{tag}.pw", "conv", fmap, [d], weight=sep_w // 2)
        return add(f"{tag}.bn", "bn", fmap, [p])

    for i, edges in enumerate(DARTS_V2_NORMAL):
        node_id = i + 2
        branch_outs = []
        for j, (op, src_idx) in enumerate(edges):
            src = node_out[src_idx]
            tag = f"n{node_id}.e{j}.{op}"
            if op == "sep_conv_3x3":
                branch_outs.append(sep_conv(tag, src))
            elif op == "dil_conv_3x3":
                branch_outs.append(dil_conv(tag, src))
            elif op == "skip_connect":
                branch_outs.append(src)
            else:
                raise ValueError(op)
        node_out[node_id] = add(f"n{node_id}.add", "add", fmap, branch_outs)

    concat_in = [node_out[i] for i in CONCAT]
    cc = add("cell.concat", "concat", fmap * len(CONCAT), concat_in)
    # cells are followed by a 1x1 conv when channels change; model the
    # downstream consumer so concat liveness is realistic:
    add("next.pw", "conv", fmap, [cc],
        weight=4 * channels * channels * dtype_bytes)
    return Graph.build(specs, name="darts_imagenet_cell")
