"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At 512+ chips the cross-pod (DCI) gradient reduction is the slowest
collective; int8 with error feedback cuts those bytes 4x vs bf16 (8x vs f32)
at negligible quality cost (1-bit/`EF-SGD` lineage: Seide'14, Karimireddy'19).

Two entry points:

  * ``compress/decompress + error feedback``: pure functions usable inside a
    pjit step (quantization noise is carried to the next step via ``err``).
  * ``compressed_psum``: the explicit shard_map collective — quantizes, sums
    int32, rescales.  Used when the pod axis is reduced manually (see
    launch/train.py's hierarchical-reduction mode and EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

f32 = jnp.float32


def quantize(x, *, bits: int = 8):
    """symmetric per-tensor int quantization; returns (q, scale)."""
    lim = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x.astype(f32)))
    scale = jnp.maximum(amax, 1e-12) / lim
    q = jnp.clip(jnp.round(x.astype(f32) / scale), -lim, lim).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(f32) * scale


def ef_compress(grads, err):
    """error-feedback: g' = Q(g + err); err' = (g + err) - g'."""
    def one(g, e):
        ge = g.astype(f32) + e
        q, s = quantize(ge)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), ge - deq

    out = jax.tree.map(one, grads, err)
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2
    g2 = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    e2 = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return g2, e2


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)


def compressed_psum(x, axis_name: str):
    """int8-payload psum for use inside shard_map.

    Scales are exchanged first (max over the axis) so all devices quantize
    onto a shared grid; int32 accumulation avoids overflow for up to 2^23
    participants."""
    amax = lax.pmax(jnp.max(jnp.abs(x.astype(f32))), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(f32) / scale), -127, 127).astype(jnp.int8)
    total = lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(f32) * scale).astype(x.dtype)
