"""AdamW with ZeRO-3-style state sharding.

State tensors inherit the parameter's sharding (same logical axes), so with
FSDP rules the optimizer state is fully sharded over the 'data' axis — the
distributed-optimizer requirement at 512+ chips.  ``state_defs`` produces the
ParamDef tree the dry-run lowers without allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class adamw:
    lr: Any = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, f32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def state_defs(self, param_defs):
        as_f32 = lambda d: ParamDef(d.shape, d.logical, init="zeros",
                                    dtype=f32)
        is_def = lambda x: isinstance(x, ParamDef)
        return {
            "step": ParamDef((), (), init="zeros", dtype=jnp.int32),
            "m": jax.tree.map(as_f32, param_defs, is_leaf=is_def),
            "v": jax.tree.map(as_f32, param_defs, is_leaf=is_def),
        }

    def update(self, grads, state, params, lr_scale=1.0):
        step = state["step"] + 1
        b1, b2 = self.b1, self.b2
        lr = jnp.asarray(self.lr, f32) * lr_scale
        bc1 = 1.0 - b1 ** step.astype(f32)
        bc2 = 1.0 - b2 ** step.astype(f32)

        def upd(g, m, v, p):
            g = g.astype(f32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + self.eps)
            upd = upd + self.weight_decay * p.astype(f32)
            return (p.astype(f32) - lr * upd).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "m": new_m, "v": new_v}
