from repro.optim.adamw import adamw
from repro.optim.adafactor import adafactor
from repro.optim.schedule import cosine_warmup

OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor}

__all__ = ["OPTIMIZERS", "adamw", "adafactor", "cosine_warmup"]
