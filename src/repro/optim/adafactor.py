"""Adafactor (Shazeer & Stern 2018) — factored second moments.

Memory per parameter: O(rows + cols) instead of O(rows*cols) for >=2-D
tensors; the reason deepseek-v3-671b fits its optimizer state on a 512-chip
v5e mesh (see configs/deepseek_v3_671b.py).  No first moment by default.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef

f32 = jnp.float32


def _factored(shape) -> bool:
    return len(shape) >= 2


def _is_vstate(x) -> bool:
    return isinstance(x, dict) and ("v" in x or "vr" in x)


@dataclasses.dataclass(frozen=True)
class adafactor:
    lr: Any = 1e-3
    decay: float = 0.8          # \hat{beta2}_t = 1 - t^{-decay}
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def init(self, params):
        def st(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], f32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], f32),
                }
            return {"v": jnp.zeros(p.shape, f32)}

        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(st, params)}

    def state_defs(self, param_defs):
        is_def = lambda x: isinstance(x, ParamDef)

        def st(d: ParamDef):
            if _factored(d.shape):
                return {
                    "vr": ParamDef(d.shape[:-1], d.logical[:-1],
                                   init="zeros", dtype=f32),
                    "vc": ParamDef(d.shape[:-2] + d.shape[-1:],
                                   d.logical[:-2] + d.logical[-1:],
                                   init="zeros", dtype=f32),
                }
            return {"v": ParamDef(d.shape, d.logical, init="zeros",
                                  dtype=f32)}

        return {"step": ParamDef((), (), init="zeros", dtype=jnp.int32),
                "v": jax.tree.map(st, param_defs, is_leaf=is_def)}

    def update(self, grads, state, params, lr_scale=1.0):
        step = state["step"] + 1
        t = step.astype(f32)
        beta2 = 1.0 - t ** (-self.decay)
        lr = jnp.asarray(self.lr, f32) * lr_scale

        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_v = jax.tree.flatten(state["v"], is_leaf=_is_vstate)[0]

        new_p, new_v = [], []
        for g, v, p in zip(flat_g, flat_v, flat_p):
            g = g.astype(f32)
            g2 = g * g + self.eps
            if _factored(p.shape):
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
                denom = jnp.maximum(vr.mean(-1, keepdims=True), self.eps)
                u = (g * jax.lax.rsqrt(vr / denom)[..., None]
                     * jax.lax.rsqrt(vc)[..., None, :])
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
                u = g * jax.lax.rsqrt(nv["v"])
            rms = jnp.sqrt(jnp.mean(u * u) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            p2 = p.astype(f32) - lr * (u + self.weight_decay * p.astype(f32))
            new_p.append(p2.astype(p.dtype))
            new_v.append(nv)

        vdef = jax.tree.structure(state["v"], is_leaf=_is_vstate)
        return (
            jax.tree.unflatten(treedef, new_p),
            {"step": step, "v": jax.tree.unflatten(vdef, new_v)},
        )
