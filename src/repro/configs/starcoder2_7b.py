"""starcoder2-7b — 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152
GQA + RoPE [arXiv:2402.19173]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49_152,
    mlp_kind="gelu",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="starcoder2-7b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )
