"""deepseek-v3-671b — 61L d_model=7168 128H, MLA, MoE 256 routed (top-8)
+ 1 shared expert (d_ff=2048), first 3 layers dense (d_ff=18432), MTP
[arXiv:2412.19437].

Adafactor optimizer: with AdamW the f32 optimizer state alone
(671e9 x 12 B / 512 chips ≈ 15.7 GB) would exhaust v5e HBM; factored second
moments bring total state to ~11 GB/chip."""

import dataclasses

from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,
    vocab_size=129_280,
    mlp_kind="swiglu",
    n_experts=256,
    n_experts_per_tok=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    n_dense_layers=3,
    mla=MLAConfig(),
    mtp=True,
    optimizer="adafactor",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-v3-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, n_experts=4,
        n_experts_per_tok=2, n_shared_experts=1, moe_d_ff=32,
        n_dense_layers=1, moe_capacity_factor=8.0,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
    )
