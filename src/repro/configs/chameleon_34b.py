"""chameleon-34b — 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
early-fusion VLM; images arrive as VQ tokens in the shared vocab, so the
modality frontend (VQ-VAE encoder) is a stub that precomputes token ids
[arXiv:2405.09818].  Chameleon uses qk-norm for stability."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65_536,
    mlp_kind="swiglu",
    qk_norm=True,
    frontend="vq_image",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="chameleon-34b-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )
