"""Config registry: ``get(name)`` -> ArchConfig, ``smoke(name)`` -> reduced.

Assigned architectures (exact published dims, see each module's citation):
  gemma-7b llama3.2-1b granite-20b starcoder2-7b chameleon-34b
  granite-moe-3b-a800m deepseek-v3-671b rwkv6-7b seamless-m4t-medium
  recurrentgemma-2b
plus the paper's own edge benchmark graphs under ``serenity_edge``.
"""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, ShardingRules

_MODULES = {
    "gemma-7b": "gemma_7b",
    "llama3.2-1b": "llama3_2_1b",
    "granite-20b": "granite_20b",
    "starcoder2-7b": "starcoder2_7b",
    "chameleon-34b": "chameleon_34b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "rwkv6-7b": "rwkv6_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_NAMES = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ArchConfig:
    return _mod(name).CONFIG


def smoke(name: str) -> ArchConfig:
    return _mod(name).smoke_config()


__all__ = [
    "ARCH_NAMES",
    "ArchConfig",
    "SHAPES",
    "ShapeConfig",
    "ShardingRules",
    "get",
    "smoke",
]
