"""seamless-m4t-medium — enc-dec 12L(+12L) d_model=1024 16H d_ff=4096
vocab=256206 [arXiv:2308.11596].  The assignment lists "12L enc-dec"; we
instantiate 12 encoder + 12 decoder layers (the published medium model's
speech-encoder/text-decoder split).  The audio frontend (fbank + conv
subsampler) is a stub: ``input_specs`` supplies precomputed frame embeddings
(B, S_enc, d_model)."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,               # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    mlp_kind="gelu",
    is_encoder_decoder=True,
    frontend="audio_frames",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="seamless-smoke", n_layers=2, encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=512,
    )
