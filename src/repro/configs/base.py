"""Architecture + run configuration for the framework.

Every assigned architecture gets a module ``src/repro/configs/<id>.py``
exporting ``CONFIG`` (exact published dims) and ``smoke_config()`` (a reduced
same-family config for CPU tests).  ``repro.configs.get(name)`` resolves both.

The sharding of every parameter/activation is expressed with *logical axis
names* resolved through ``ShardingRules`` — the MaxText-style indirection that
lets the §Perf loop re-map axes without touching model code.
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims (arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    mlp_kind: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    qk_norm: bool = False
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0          # leading dense layers (deepseek: 3)
    moe_capacity_factor: float = 1.25
    # --- MLA / MTP (deepseek) ------------------------------------------------
    mla: MLAConfig | None = None
    mtp: bool = False                # multi-token-prediction auxiliary head
    # --- attention-free / hybrid ----------------------------------------------
    attn_free: bool = False          # rwkv6
    block_pattern: tuple[str, ...] = ("attn",)   # e.g. ("rec","rec","attn")
    local_window: int = 0            # sliding-window size for local attention
    lru_width: int = 0               # RG-LRU state width (0 -> d_model)
    # --- encoder-decoder --------------------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # --- modality frontend (stubbed per assignment) ------------------------------
    frontend: Literal[None, "vq_image", "audio_frames"] = None
    # --- numerics / optimization --------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer: Literal["adamw", "adafactor"] = "adamw"
    # remat: 'block' = full-block recompute (baseline), 'dots' = selective
    # (matmul outputs saved, elementwise recomputed), 'none'
    remat: Literal["none", "block", "dots"] = "block"
    # explicit sharding constraints on MoE dispatch buffers (§Perf B1)
    moe_dispatch_sharding: bool = False
    # MoE implementation: 'scatter' = pjit-auto (baseline; the partitioner
    # replicates the scatter operands), 'ep_shardmap' = explicit expert-
    # parallel shard_map (local dispatch + ZeRO weight gather + psum combine;
    # §Perf B2)
    moe_impl: Literal["scatter", "ep_shardmap"] = "scatter"
    # XLA flash-attention KV chunk: larger chunks -> fewer online-softmax
    # accumulator rewrites (§Perf C3)
    attn_kv_chunk: int = 1024
    # subquadratic archs support the 500k decode cell
    subquadratic: bool = False
    # cost-probe mode: fully unroll layer scans so XLA cost_analysis counts
    # every layer (a while-loop body is otherwise counted ONCE — see
    # EXPERIMENTS.md §Dry-run "scan-body undercount")
    scan_unroll: bool = False

    # ---- derived -------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        V, D, F, H = self.vocab_size, self.d_model, self.d_ff, self.n_heads
        hd, kvh = self.head_dim, self.n_kv_heads
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            attn = (
                D * m.q_lora_rank
                + m.q_lora_rank * H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + D * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                + H * m.v_head_dim * D
            )
        elif self.attn_free:
            attn = 6 * D * D + 2 * D  # rwkv6 token-mix approx (r,k,v,g,o + decay)
        else:
            attn = D * H * hd + 2 * D * kvh * hd + H * hd * D
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        dense_mlp = gates * D * F
        if self.n_experts:
            moe_mlp = gates * D * self.moe_d_ff * (
                self.n_experts + self.n_shared_experts
            ) + D * self.n_experts
            n_moe = self.n_layers - self.n_dense_layers
            blocks = self.n_layers * attn + self.n_dense_layers * dense_mlp \
                + n_moe * moe_mlp
        else:
            blocks = self.n_layers * (attn + dense_mlp)
        if self.is_encoder_decoder:
            # encoder blocks + decoder cross-attention
            blocks += self.encoder_layers * (attn + dense_mlp)
            blocks += self.n_layers * attn      # cross-attn per decoder layer
        return emb + blocks

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k), for 6·N_active·D."""
        if not self.n_experts:
            return self.param_count()
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        full_moe = gates * self.d_model * self.moe_d_ff * (
            self.n_experts + self.n_shared_experts
        )
        act_moe = gates * self.d_model * self.moe_d_ff * (
            self.n_experts_per_tok + self.n_shared_experts
        )
        n_moe = self.n_layers - self.n_dense_layers
        return self.param_count() - n_moe * (full_moe - act_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the evaluation matrix."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of axes, or None = replicated)."""
    batch: tuple[str, ...] = ("pod", "data")
    fsdp: str | None = "data"        # non-TP param axis sharding (ZeRO-3)
    tensor: str | None = "model"     # heads / mlp / vocab
    expert: str | None = "model"     # MoE expert axis (EP)
    sequence: str | None = None      # SP for long-context activations
    act_embed: str | None = None     # shard activations' d_model axis
    mesh: object = dataclasses.field(default=None, compare=False,
                                     repr=False)  # for shard_map paths

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        table = {
            "batch": self.batch,
            "fsdp": self.fsdp,
            "tensor": self.tensor,
            "expert": self.expert,
            "sequence": self.sequence,
            "act_embed": self.act_embed,
        }
        return table[logical]


DEFAULT_RULES = ShardingRules()
