"""granite-20b — 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152
llama-style code model [arXiv:2405.04324]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49_152,
    mlp_kind="gelu",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="granite-20b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
    )
