"""granite-moe-3b-a800m — 32L d_model=1536 24H (GQA kv=8) vocab=49155,
MoE 40 experts top-8, expert d_ff=512
[hf:ibm-granite/granite-3.0-*-base family]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    mlp_kind="swiglu",
    n_experts=40,
    n_experts_per_tok=8,
    moe_d_ff=512,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=512, n_experts=4,
        n_experts_per_tok=2, moe_d_ff=64, moe_capacity_factor=8.0,
    )
