"""gemma-7b — 28L d_model=3072 16H (GQA kv=16, i.e. MHA) d_ff=24576
vocab=256000, GeGLU, head_dim=256, tied embeddings [arXiv:2403.08295]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    mlp_kind="geglu",
    tie_embeddings=True,
    optimizer="adamw",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="gemma-7b-smoke", n_layers=2, d_model=64, n_heads=2,
        n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=512,
    )
