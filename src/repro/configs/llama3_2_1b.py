"""llama3.2-1b — 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-1B], rope theta 500k, tied embeddings."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128_256,
    mlp_kind="swiglu",
    rope_theta=500_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="llama3.2-1b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )
