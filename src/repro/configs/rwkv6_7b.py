"""rwkv6-7b (Finch) — 32L d_model=4096, attention-free, d_ff=14336
vocab=65536, data-dependent decay, head size 64 [arXiv:2404.05892]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # wkv heads = d_model / head_dim
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65_536,
    mlp_kind="gelu",       # unused: rwkv channel-mix has its own form
    attn_free=True,
    subquadratic=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="rwkv6-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
    )
