"""recurrentgemma-2b — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention (window 2048) in a (rec, rec, attn)
pattern, GeGLU, head_dim=256, lru_width=2560 [arXiv:2402.19427]."""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    mlp_kind="geglu",
    tie_embeddings=True,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=2560,
    subquadratic=True,
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-smoke", n_layers=4, d_model=64,
        n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128, vocab_size=512,
        local_window=16, lru_width=64, block_pattern=("rec", "rec", "attn"),
    )
