"""Activation sharding constraints + batch/cache specs.

Parameters get their shardings from ParamDef logical axes; *activations* get
theirs from the helpers here.  All are no-ops when ``rules is None`` (single-
device smoke tests).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShardingRules


def _flatten(axis):
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis
    if len(axis) == 0:
        return None
    return tuple(axis) if len(axis) > 1 else axis[0]


def act_spec(rules: ShardingRules, kind: str) -> P:
    """kind: per-dim letters — b(atch) s(equence) d/e(mbed) h(eads) v(ocab)
    n(one).  A mesh axis is used at most once (first dim wins)."""
    table = {
        "b": rules.batch,
        "s": rules.sequence,
        "d": rules.act_embed,
        "e": rules.act_embed,
        "h": rules.tensor,
        "v": rules.tensor,
        "x": rules.expert,
        "n": None,
    }
    used: set = set()
    axes = []
    for c in kind:
        ax = _flatten(table[c])
        flat = () if ax is None else ((ax,) if isinstance(ax, str)
                                      else tuple(ax))
        free = tuple(a for a in flat if a not in used)
        used.update(free)
        axes.append(free[0] if len(free) == 1
                    else (free if free else None))
    return P(*axes)


def shard_act(x, rules: ShardingRules | None, kind: str):
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, act_spec(rules, kind))
