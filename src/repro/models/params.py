"""Parameter-definition mini-framework (keeps init and sharding in sync).

Every module describes its parameters as a pytree of :class:`ParamDef`
(shape + per-dimension *logical* axis names + initializer).  From one
definition tree we derive:

  * ``init_params``   — materialized arrays (real training / smoke tests)
  * ``abstract_params`` — ShapeDtypeStructs with NamedSharding attached
                          (the dry-run path: zero allocation)
  * ``param_pspecs``  — PartitionSpec tree via :class:`ShardingRules`

Logical axis vocabulary (resolved by ShardingRules):
  "batch" "fsdp" "tensor" "expert" "sequence" — see configs/base.py.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShardingRules


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[Any, ...]            # one logical name (or None) per dim
    init: str = "normal"                # normal | zeros | ones | embed
    dtype: Any = jnp.bfloat16
    scale_axis: int = 0                 # fan-in axis for init scaling

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _init_leaf(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    fan_in = d.shape[d.scale_axis] if d.shape else 1
    std = 1.0 / math.sqrt(max(fan_in, 1))
    if d.init == "embed":
        std = 0.02          # GPT-style: keeps tied-logit scales sane
    x = jax.random.normal(key, d.shape, jnp.float32) * std
    return x.astype(d.dtype)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(d, k) for d, k in zip(leaves, keys)]
    )


def param_pspecs(defs, rules: ShardingRules, mesh: Mesh | None = None):
    """Resolve logical axes -> PartitionSpecs.

    When ``mesh`` is given, any mesh axis whose size does not evenly divide
    the tensor dimension is dropped (replicated) — e.g. 8 GQA KV heads under
    16-way TP stay replicated rather than failing to shard.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}

    def spec(d: ParamDef) -> P:
        axes: list = [None] * len(d.shape)
        used: set = set()

        def claim(i: int, dim: int, name) -> None:
            mesh_axis = rules.resolve(name)
            if mesh_axis is None:
                return
            flat = (mesh_axis,) if isinstance(mesh_axis, str) \
                else tuple(mesh_axis)
            free = []
            rem = dim
            for a in flat:
                if a in used:
                    continue
                sz = sizes.get(a)
                if sz is not None and rem % sz != 0:
                    continue                      # indivisible -> replicate
                free.append(a)
                if sz:
                    rem //= sz
            if not free:
                return
            used.update(free)
            axes[i] = tuple(free) if len(free) > 1 else free[0]

        # two passes: 'sequence' is the fallback axis — it only takes mesh
        # axes left over by the primary (tensor/expert/fsdp/batch) dims, so
        # e.g. a 16-KV-head cache shards heads over 'model' while an 8-KV-head
        # cache (indivisible by 16) shards its sequence dim instead.
        for i, (dim, name) in enumerate(zip(d.shape, d.logical)):
            if name != "sequence":
                claim(i, dim, name)
        for i, (dim, name) in enumerate(zip(d.shape, d.logical)):
            if name == "sequence":
                claim(i, dim, name)
        while axes and axes[-1] is None:
            axes.pop()
        return P(*axes)

    return jax.tree.map(spec, defs, is_leaf=_is_def)


def abstract_params(defs, rules: ShardingRules, mesh: Mesh):
    """ShapeDtypeStruct tree with shardings — for .lower() without allocation."""
    specs = param_pspecs(defs, rules, mesh)

    def mk(d: ParamDef, s: P):
        return jax.ShapeDtypeStruct(
            d.shape, d.dtype, sharding=NamedSharding(mesh, s)
        )

    return jax.tree.map(mk, defs, specs, is_leaf=_is_def)


def stack_defs(defs, n: int):
    """Add a leading scan-layer axis of size ``n`` to every ParamDef."""
    def st(d: ParamDef) -> ParamDef:
        return ParamDef(
            shape=(n, *d.shape),
            logical=(None, *d.logical),
            init=d.init,
            dtype=d.dtype,
            scale_axis=d.scale_axis + 1,
        )
    return jax.tree.map(st, defs, is_leaf=_is_def)


def init_stacked(defs, key, n: int):
    """Init ``n`` layers with independent keys, stacked on axis 0."""
    keys = jax.random.split(key, n)
    per_layer = [init_params(defs, k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *per_layer)


def leaf_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return sum(math.prod(d.shape) for d in leaves)
