"""Model zoo: assemble per-family models from blocks.

``build_model(cfg) -> Model`` with:
    defs        ParamDef tree (scan-stacked layers)
    init(key)   materialized params
    loss_fn(params, batch, *, impl, rules)            -> (loss, metrics)
    make_cache_defs(batch_size, max_len)              -> ParamDef tree (decode state)
    init_cache(batch_size, max_len)                   -> zeroed decode state
    prefill_fn(params, cache, batch, *, impl, rules)  -> (logits_last, cache)
    decode_fn(params, cache, tokens, t, *, impl, rules) -> (logits, cache)

Layers are stacked and scanned (one HLO while loop per homogeneous stack) to
keep compile time and HLO size bounded at 61-layer/512-device scale.
``cfg.remat == 'block'`` wraps each scanned block in jax.checkpoint.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, ShardingRules
from repro.models import blocks as B
from repro.models.layers import (
    Ctx, embed_apply, embed_defs, logits_apply, norm_defs, rms_norm,
)
from repro.models.params import ParamDef, init_params, stack_defs
from repro.parallel.sharding import shard_act

f32 = jnp.float32


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    defs: Any
    init: Callable
    loss_fn: Callable
    make_cache_defs: Callable
    init_cache: Callable
    prefill_fn: Callable
    decode_fn: Callable


# ----------------------------------------------------------------- helpers

def _stacked_init(defs_one, key, n):
    keys = jax.random.split(key, n)
    outs = [init_params(defs_one, k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *outs)


def _maybe_remat(body, remat):
    """remat policy: False/'none' -> off; True/'block' -> full recompute;
    'dots' -> selective (save matmul outputs, recompute elementwise)."""
    if not remat or remat == "none":
        return body
    if remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    return jax.checkpoint(body)


def _scan_stack(apply_one, stacked_p, x, ctx, caches, remat, unroll=False):
    """Scan ``apply_one(p_layer, x, ctx, cache_layer)`` over the layer axis."""
    has_cache = caches is not None

    def body(x, layer):
        if has_cache:
            p, c = layer
            x2, c2, aux = apply_one(p, x, ctx, c)
            return x2, (c2, aux)
        (p,) = layer
        x2, _, aux = apply_one(p, x, ctx, None)
        return x2, aux

    body = _maybe_remat(body, remat)
    xs = (stacked_p, caches) if has_cache else (stacked_p,)
    x, ys = lax.scan(body, x, xs, unroll=bool(unroll))
    if has_cache:
        new_caches, auxs = ys
        return x, new_caches, jnp.sum(auxs)
    return x, None, jnp.sum(ys)


def _xent(logits, targets, mask):
    lz = jax.nn.log_softmax(logits.astype(f32), axis=-1)
    ll = jnp.take_along_axis(lz, targets[..., None], axis=-1)[..., 0]
    n = jnp.maximum(mask.sum(), 1)
    return -(ll * mask).sum() / n


def _lm_loss(logits, tokens):
    """next-token CE: logits[:, :-1] predicts tokens[:, 1:]."""
    return _xent(logits[:, :-1], tokens[:, 1:], jnp.ones_like(tokens[:, 1:]))


def _kv_cache_defs(cfg: ArchConfig, n_layers, bsz, smax, window=None):
    eff = min(smax, window) if window else smax
    shape = (n_layers, bsz, eff, cfg.n_kv_heads, cfg.head_dim)
    # NOTE (§Perf A2, refuted): sharding head_dim over 'model' when the KV
    # heads don't divide the axis cuts cache/chip 16x, but XLA answers with
    # per-layer K all-gathers (9.2 GB/chip/step) -- 2x slower end to end.
    # A split-K distributed flash-decode (shard_map) is the right fix; the
    # linear layout stays the default.
    logical = (None, "batch", "sequence", "tensor", None)
    return {
        "k": ParamDef(shape, logical, init="zeros"),
        "v": ParamDef(shape, logical, init="zeros"),
    }


# ----------------------------------------------------------------- decoder LM
# (dense: gemma / llama / granite / starcoder / chameleon;
#  moe: granite-moe / deepseek-v3 with MLA + optional MTP)

def build_decoder_lm(cfg: ArchConfig) -> Model:
    n_dense = cfg.n_dense_layers if cfg.n_experts else cfg.n_layers
    n_moe = cfg.n_layers - n_dense

    block_defs_dense = B.transformer_block_defs(cfg, moe=False)
    block_defs_moe = B.transformer_block_defs(cfg, moe=True) if n_moe else None

    defs = {"embed": embed_defs(cfg), "ln_f": norm_defs(cfg.d_model)}
    if n_dense:
        defs["dense"] = stack_defs(block_defs_dense, n_dense)
    if n_moe:
        defs["moe"] = stack_defs(block_defs_moe, n_moe)
    if cfg.mtp:
        defs["mtp"] = {
            "proj": ParamDef((2 * cfg.d_model, cfg.d_model),
                             ("fsdp", "tensor")),
            "block": B.transformer_block_defs(cfg, moe=False),
            "ln": norm_defs(cfg.d_model),
        }

    def init(key):
        ks = jax.random.split(key, 4)
        p = {"embed": init_params(defs["embed"], ks[0]),
             "ln_f": init_params(defs["ln_f"], ks[1])}
        if n_dense:
            p["dense"] = _stacked_init(block_defs_dense, ks[2], n_dense)
        if n_moe:
            p["moe"] = _stacked_init(block_defs_moe, ks[3], n_moe)
        if cfg.mtp:
            p["mtp"] = init_params(defs["mtp"], jax.random.fold_in(key, 9))
        return p

    dense_apply = functools.partial(B.transformer_block_apply, moe=False)
    moe_apply_ = functools.partial(B.transformer_block_apply, moe=True)

    def backbone(params, x, ctx, caches, rules):
        remat = cfg.remat if not ctx.decode else "none"
        aux = jnp.zeros((), f32)
        nc = {}
        x = shard_act(x, rules, "bsd")
        if n_dense:
            c = caches.get("dense") if caches else None
            x, c2, a = _scan_stack(dense_apply, params["dense"], x, ctx, c,
                                   remat, unroll=cfg.scan_unroll)
            aux += a
            if caches:
                nc["dense"] = c2
        if n_moe:
            c = caches.get("moe") if caches else None
            x, c2, a = _scan_stack(moe_apply_, params["moe"], x, ctx, c,
                                   remat, unroll=cfg.scan_unroll)
            aux += a
            if caches:
                nc["moe"] = c2
        x = shard_act(x, rules, "bsd")
        return x, (nc if caches else None), aux

    def loss_fn(params, batch, *, impl="xla", rules=None):
        tokens = batch["tokens"]
        Bz, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (Bz, S))
        ctx = Ctx(cfg=cfg, impl=impl, positions=pos, rules=rules)
        x = embed_apply(params["embed"], tokens, cfg)
        x, _, aux = backbone(params, x, ctx, None, rules)
        h = rms_norm(x, params["ln_f"])
        logits = logits_apply(params["embed"], h, cfg)
        loss = _lm_loss(logits, tokens)
        metrics = {"lm_loss": loss, "aux_loss": aux}
        if cfg.n_experts:
            loss = loss + 0.01 * aux
        if cfg.mtp:
            # DeepSeek-V3 multi-token prediction: combine h_t with emb of
            # token t+1, run one extra block, predict token t+2.
            emb_next = embed_apply(params["embed"], tokens, cfg)
            cat = jnp.concatenate(
                [rms_norm(h[:, :-1], params["mtp"]["ln"]),
                 emb_next[:, 1:]], -1,
            )
            xm = jnp.einsum("bsd,de->bse", cat, params["mtp"]["proj"])
            ctx_m = Ctx(cfg=cfg, impl=impl, positions=pos[:, :-1])
            xm, _, _ = B.transformer_block_apply(
                params["mtp"]["block"], xm, ctx_m, None, moe=False
            )
            lg = logits_apply(params["embed"],
                              rms_norm(xm, params["ln_f"]), cfg)
            mtp_loss = _xent(lg[:, :-1], tokens[:, 2:],
                             jnp.ones_like(tokens[:, 2:]))
            metrics["mtp_loss"] = mtp_loss
            loss = loss + 0.3 * mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    # ---- serving ---------------------------------------------------------
    def make_cache_defs(bsz, smax):
        c = {}
        if cfg.mla is not None:
            m = cfg.mla
            if n_dense:
                c["dense"] = {
                    "ckv": ParamDef((n_dense, bsz, smax, m.kv_lora_rank),
                                    (None, "batch", "sequence", "tensor"),
                                    init="zeros"),
                    "krope": ParamDef((n_dense, bsz, smax, m.qk_rope_head_dim),
                                      (None, "batch", "sequence", None),
                                      init="zeros"),
                }
            if n_moe:
                c["moe"] = {
                    "ckv": ParamDef((n_moe, bsz, smax, m.kv_lora_rank),
                                    (None, "batch", "sequence", "tensor"),
                                    init="zeros"),
                    "krope": ParamDef((n_moe, bsz, smax, m.qk_rope_head_dim),
                                      (None, "batch", "sequence", None),
                                      init="zeros"),
                }
        else:
            if n_dense:
                c["dense"] = _kv_cache_defs(cfg, n_dense, bsz, smax)
            if n_moe:
                c["moe"] = _kv_cache_defs(cfg, n_moe, bsz, smax)
        return c

    def init_cache(bsz, smax):
        return jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype),
            make_cache_defs(bsz, smax),
            is_leaf=lambda x: isinstance(x, ParamDef),
        )

    def _fwd_cached(params, cache, tokens, t, *, impl, rules, decode):
        Bz, S = tokens.shape
        if decode:
            pos = jnp.broadcast_to(t + jnp.arange(S)[None], (Bz, S))
        else:
            pos = jnp.broadcast_to(jnp.arange(S)[None], (Bz, S))
        ctx = Ctx(cfg=cfg, impl=impl, positions=pos, decode=decode,
                  cache_len=t, rules=rules)
        x = embed_apply(params["embed"], tokens, cfg)
        x, nc, _ = backbone(params, x, ctx, cache, rules)
        h = rms_norm(x[:, -1:], params["ln_f"])
        logits = logits_apply(params["embed"], h, cfg)
        return logits[:, 0], nc

    def prefill_fn(params, cache, batch, *, impl="xla", rules=None):
        return _fwd_cached(params, cache, batch["tokens"], 0,
                           impl=impl, rules=rules, decode=False)

    def decode_fn(params, cache, tokens, t, *, impl="xla", rules=None):
        return _fwd_cached(params, cache, tokens, t,
                           impl=impl, rules=rules, decode=True)

    return Model(cfg, defs, init, loss_fn, make_cache_defs, init_cache,
                 prefill_fn, decode_fn)


# ----------------------------------------------------------------- RWKV-6 LM

def build_rwkv_lm(cfg: ArchConfig) -> Model:
    block_defs = B.rwkv6_block_defs(cfg)
    defs = {
        "embed": embed_defs(cfg),
        "blocks": stack_defs(block_defs, cfg.n_layers),
        "ln_f": norm_defs(cfg.d_model),
    }

    def init(key):
        ks = jax.random.split(key, 3)
        return {
            "embed": init_params(defs["embed"], ks[0]),
            "blocks": _stacked_init(block_defs, ks[1], cfg.n_layers),
            "ln_f": init_params(defs["ln_f"], ks[2]),
        }

    def loss_fn(params, batch, *, impl="xla", rules=None):
        tokens = batch["tokens"]
        ctx = Ctx(cfg=cfg, impl=impl, rules=rules)
        x = embed_apply(params["embed"], tokens, cfg)
        x = shard_act(x, rules, "bsd")
        x, _, _ = _scan_stack(B.rwkv6_block_apply, params["blocks"], x, ctx,
                              None, cfg.remat, unroll=cfg.scan_unroll)
        logits = logits_apply(params["embed"], rms_norm(x, params["ln_f"]),
                              cfg)
        loss = _lm_loss(logits, tokens)
        return loss, {"loss": loss, "lm_loss": loss}

    H, N = cfg.d_model // cfg.head_dim, cfg.head_dim

    def make_cache_defs(bsz, smax):
        L, D = cfg.n_layers, cfg.d_model
        return {
            "tm_x": ParamDef((L, bsz, D), (None, "batch", None),
                             init="zeros"),
            "cm_x": ParamDef((L, bsz, D), (None, "batch", None),
                             init="zeros"),
            "wkv": ParamDef((L, bsz, H, N, N),
                            (None, "batch", "tensor", None, None),
                            init="zeros", dtype=f32),
        }

    def init_cache(bsz, smax):
        return jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype), make_cache_defs(bsz, smax),
            is_leaf=lambda x: isinstance(x, ParamDef),
        )

    def _fwd(params, cache, tokens, t, *, impl, rules, decode):
        ctx = Ctx(cfg=cfg, impl=impl, decode=decode, cache_len=t, rules=rules)
        x = embed_apply(params["embed"], tokens, cfg)
        x = shard_act(x, rules, "bsd")
        x, nc, _ = _scan_stack(B.rwkv6_block_apply, params["blocks"], x, ctx,
                               cache, False, unroll=cfg.scan_unroll)
        logits = logits_apply(
            params["embed"], rms_norm(x[:, -1:], params["ln_f"]), cfg
        )
        return logits[:, 0], nc

    def prefill_fn(params, cache, batch, *, impl="xla", rules=None):
        return _fwd(params, cache, batch["tokens"], 0, impl=impl,
                    rules=rules, decode=False)

    def decode_fn(params, cache, tokens, t, *, impl="xla", rules=None):
        return _fwd(params, cache, tokens, t, impl=impl, rules=rules,
                    decode=True)

    return Model(cfg, defs, init, loss_fn, make_cache_defs, init_cache,
                 prefill_fn, decode_fn)


# ----------------------------------------------------------------- Griffin

def build_griffin_lm(cfg: ArchConfig) -> Model:
    """recurrentgemma: pattern (rec, rec, attn) repeating over n_layers."""
    pattern = cfg.block_pattern            # e.g. ("rec", "rec", "attn")
    period = len(pattern)
    n_groups = cfg.n_layers // period
    n_tail = cfg.n_layers - n_groups * period
    tail_pattern = pattern[:n_tail]
    n_rec_g = sum(1 for b in pattern if b == "rec")
    n_attn_g = period - n_rec_g

    rec_defs = B.griffin_rec_block_defs(cfg)
    attn_defs_ = B.griffin_attn_block_defs(cfg)

    group_defs = {
        "rec": stack_defs(rec_defs, n_groups * n_rec_g),
        "attn": stack_defs(attn_defs_, n_groups * n_attn_g),
    }
    defs = {
        "embed": embed_defs(cfg),
        "groups": group_defs,
        "tail": [
            (rec_defs if b == "rec" else attn_defs_) for b in tail_pattern
        ],
        "ln_f": norm_defs(cfg.d_model),
    }

    def init(key):
        ks = jax.random.split(key, 4 + n_tail)
        return {
            "embed": init_params(defs["embed"], ks[0]),
            "groups": {
                "rec": _stacked_init(rec_defs, ks[1], n_groups * n_rec_g),
                "attn": _stacked_init(attn_defs_, ks[2], n_groups * n_attn_g),
            },
            "tail": [
                init_params(d, ks[4 + i]) for i, d in enumerate(defs["tail"])
            ],
            "ln_f": init_params(defs["ln_f"], ks[3]),
        }

    def group_view(p, caches):
        """reshape stacks into per-group leading axis for scan."""
        rec = jax.tree.map(
            lambda a: a.reshape(n_groups, n_rec_g, *a.shape[1:]), p["rec"]
        )
        attn = jax.tree.map(
            lambda a: a.reshape(n_groups, n_attn_g, *a.shape[1:]), p["attn"]
        )
        if caches is None:
            return (rec, attn), None
        crec = jax.tree.map(
            lambda a: a.reshape(n_groups, n_rec_g, *a.shape[1:]),
            caches["rec"],
        )
        cattn = jax.tree.map(
            lambda a: a.reshape(n_groups, n_attn_g, *a.shape[1:]),
            caches["attn"],
        )
        return (rec, attn), (crec, cattn)

    def backbone(params, x, ctx, caches, rules):
        (rec, attn), gc = group_view(params["groups"], caches)
        remat = cfg.remat if not ctx.decode else "none"

        def group_body(x, layer):
            if gc is not None:
                (pr, pa), (cr, ca) = layer
            else:
                (pr, pa) = layer
                cr = ca = None
            ncr, nca, ri, ai = [], [], 0, 0
            for b in pattern:
                if b == "rec":
                    pl = jax.tree.map(lambda t: t[ri], pr)
                    cl = jax.tree.map(lambda t: t[ri], cr) if cr is not None \
                        else None
                    x, c2, _ = B.griffin_rec_block_apply(pl, x, ctx, cl)
                    ncr.append(c2)
                    ri += 1
                else:
                    pl = jax.tree.map(lambda t: t[ai], pa)
                    cl = jax.tree.map(lambda t: t[ai], ca) if ca is not None \
                        else None
                    x, c2, _ = B.griffin_attn_block_apply(pl, x, ctx, cl)
                    nca.append(c2)
                    ai += 1
            if gc is None:
                return x, 0.0
            stk = lambda lst: jax.tree.map(lambda *ts: jnp.stack(ts), *lst)
            return x, (stk(ncr), stk(nca))

        group_body = _maybe_remat(group_body, remat)
        xs = ((rec, attn), gc) if gc is not None else ((rec, attn),)
        if gc is not None:
            x, (ncr, nca) = lax.scan(
                lambda c, l: group_body(c, (l[0], l[1])), x, xs,
                unroll=bool(cfg.scan_unroll),
            )
        else:
            x, _ = lax.scan(lambda c, l: group_body(c, l[0]), x, xs,
                            unroll=bool(cfg.scan_unroll))

        new_caches = None
        if gc is not None:
            new_caches = {
                "rec": jax.tree.map(
                    lambda a: a.reshape(n_groups * n_rec_g, *a.shape[2:]), ncr
                ),
                "attn": jax.tree.map(
                    lambda a: a.reshape(n_groups * n_attn_g, *a.shape[2:]),
                    nca,
                ),
            }
        # tail layers (unrolled)
        new_tail = []
        for i, b in enumerate(tail_pattern):
            pl = params["tail"][i]
            cl = caches["tail"][i] if caches is not None else None
            fn = (B.griffin_rec_block_apply if b == "rec"
                  else B.griffin_attn_block_apply)
            x, c2, _ = fn(pl, x, ctx, cl)
            new_tail.append(c2)
        if caches is not None:
            new_caches["tail"] = new_tail
        return x, new_caches

    def loss_fn(params, batch, *, impl="xla", rules=None):
        tokens = batch["tokens"]
        Bz, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (Bz, S))
        ctx = Ctx(cfg=cfg, impl=impl, positions=pos, rules=rules)
        x = embed_apply(params["embed"], tokens, cfg)
        x = shard_act(x, rules, "bsd")
        x, _ = backbone(params, x, ctx, None, rules)
        logits = logits_apply(params["embed"], rms_norm(x, params["ln_f"]),
                              cfg)
        loss = _lm_loss(logits, tokens)
        return loss, {"loss": loss, "lm_loss": loss}

    W = cfg.lru_width or cfg.d_model

    def make_cache_defs(bsz, smax):
        # NOTE: local attention only reads the trailing `local_window`
        # entries, but the buffer is linear-indexed by absolute position —
        # a ring buffer is the production optimization (EXPERIMENTS.md §Perf
        # evaluates it); correctness first.
        eff = smax
        n_rec = n_groups * n_rec_g
        n_attn = n_groups * n_attn_g
        c = {
            "rec": {
                "conv": ParamDef((n_rec, bsz, B._CONV_W - 1, W),
                                 (None, "batch", None, "tensor"),
                                 init="zeros"),
                "h": ParamDef((n_rec, bsz, W), (None, "batch", "tensor"),
                              init="zeros", dtype=f32),
            },
            "attn": _kv_cache_defs(cfg, n_attn, bsz, eff),
            "tail": [
                {
                    "conv": ParamDef((bsz, B._CONV_W - 1, W),
                                     ("batch", None, "tensor"), init="zeros"),
                    "h": ParamDef((bsz, W), ("batch", "tensor"),
                                  init="zeros", dtype=f32),
                }
                if b == "rec"
                else {
                    "k": ParamDef((bsz, eff, cfg.n_kv_heads, cfg.head_dim),
                                  ("batch", None, "tensor", None),
                                  init="zeros"),
                    "v": ParamDef((bsz, eff, cfg.n_kv_heads, cfg.head_dim),
                                  ("batch", None, "tensor", None),
                                  init="zeros"),
                }
                for b in tail_pattern
            ],
        }
        return c

    def init_cache(bsz, smax):
        return jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype), make_cache_defs(bsz, smax),
            is_leaf=lambda x: isinstance(x, ParamDef),
        )

    def _fwd(params, cache, tokens, t, *, impl, rules, decode):
        Bz, S = tokens.shape
        pos = jnp.broadcast_to(
            (t + jnp.arange(S))[None] if decode else jnp.arange(S)[None],
            (Bz, S),
        )
        ctx = Ctx(cfg=cfg, impl=impl, positions=pos, decode=decode,
                  cache_len=t, rules=rules)
        x = embed_apply(params["embed"], tokens, cfg)
        x = shard_act(x, rules, "bsd")
        x, nc = backbone(params, x, ctx, cache, rules)
        logits = logits_apply(
            params["embed"], rms_norm(x[:, -1:], params["ln_f"]), cfg
        )
        return logits[:, 0], nc

    def prefill_fn(params, cache, batch, *, impl="xla", rules=None):
        return _fwd(params, cache, batch["tokens"], 0, impl=impl,
                    rules=rules, decode=False)

    def decode_fn(params, cache, tokens, t, *, impl="xla", rules=None):
        return _fwd(params, cache, tokens, t, impl=impl, rules=rules,
                    decode=True)

    return Model(cfg, defs, init, loss_fn, make_cache_defs, init_cache,
                 prefill_fn, decode_fn)


# ----------------------------------------------------------------- enc-dec

def build_encdec(cfg: ArchConfig) -> Model:
    """seamless-m4t backbone: audio-frame encoder (frontend stub supplies
    frame embeddings) + text decoder with cross-attention."""
    enc_defs_one = B.encoder_block_defs(cfg)
    dec_defs_one = B.decoder_block_defs(cfg)
    defs = {
        "embed": embed_defs(cfg),
        "enc": stack_defs(enc_defs_one, cfg.encoder_layers),
        "dec": stack_defs(dec_defs_one, cfg.n_layers),
        "ln_enc": norm_defs(cfg.d_model),
        "ln_f": norm_defs(cfg.d_model),
    }

    def init(key):
        ks = jax.random.split(key, 5)
        return {
            "embed": init_params(defs["embed"], ks[0]),
            "enc": _stacked_init(enc_defs_one, ks[1], cfg.encoder_layers),
            "dec": _stacked_init(dec_defs_one, ks[2], cfg.n_layers),
            "ln_enc": init_params(defs["ln_enc"], ks[3]),
            "ln_f": init_params(defs["ln_f"], ks[4]),
        }

    def encode(params, frames, ctx, rules):
        x = shard_act(frames.astype(jnp.bfloat16), rules, "bsd")

        def body(x, p):
            return B.encoder_block_apply(p, x, ctx), None

        body = _maybe_remat(body, cfg.remat)
        x, _ = lax.scan(body, x, params["enc"], unroll=bool(cfg.scan_unroll))
        return rms_norm(x, params["ln_enc"])

    def run_decoder(params, x, enc_out, ctx, caches, remat, enc_len=None):
        def body(x, layer):
            if caches is not None:
                p, c = layer
                x2, c2, _ = B.decoder_block_apply(p, x, ctx, enc_out, c,
                                                  enc_len=enc_len)
                return x2, c2
            (p,) = layer
            x2, _, _ = B.decoder_block_apply(p, x, ctx, enc_out, None,
                                             enc_len=enc_len)
            return x2, 0.0

        body = _maybe_remat(body, remat)
        xs = (params["dec"], caches) if caches is not None else (params["dec"],)
        x, nc = lax.scan(body, x, xs, unroll=bool(cfg.scan_unroll))
        return x, (nc if caches is not None else None)

    def loss_fn(params, batch, *, impl="xla", rules=None):
        frames, tokens = batch["frames"], batch["tokens"]
        Bz, S = tokens.shape
        Se = frames.shape[1]
        enc_ctx = Ctx(cfg=cfg, impl=impl,
                      positions=jnp.broadcast_to(jnp.arange(Se)[None],
                                                 (Bz, Se)))
        enc_out = encode(params, frames, enc_ctx, rules)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (Bz, S))
        ctx = Ctx(cfg=cfg, impl=impl, positions=pos, rules=rules)
        x = embed_apply(params["embed"], tokens, cfg)
        x, _ = run_decoder(params, x, enc_out, ctx, None, cfg.remat)
        logits = logits_apply(params["embed"], rms_norm(x, params["ln_f"]),
                              cfg)
        loss = _lm_loss(logits, tokens)
        return loss, {"loss": loss, "lm_loss": loss}

    def make_cache_defs(bsz, smax):
        return {
            "self": {
                "self": _kv_cache_defs(cfg, cfg.n_layers, bsz, smax)
            }["self"],
            "enc_out": ParamDef((bsz, smax, cfg.d_model),
                                ("batch", None, None), init="zeros"),
            "enc_len": ParamDef((), (), init="zeros", dtype=jnp.int32),
        }

    def init_cache(bsz, smax):
        return jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype), make_cache_defs(bsz, smax),
            is_leaf=lambda x: isinstance(x, ParamDef),
        )

    def prefill_fn(params, cache, batch, *, impl="xla", rules=None):
        frames, tokens = batch["frames"], batch["tokens"]
        Bz, S = tokens.shape
        Se = frames.shape[1]
        enc_ctx = Ctx(cfg=cfg, impl=impl,
                      positions=jnp.broadcast_to(jnp.arange(Se)[None],
                                                 (Bz, Se)))
        enc_out = encode(params, frames, enc_ctx, rules)
        enc_buf = jax.lax.dynamic_update_slice_in_dim(
            cache["enc_out"], enc_out.astype(cache["enc_out"].dtype), 0, 1
        )
        pos = jnp.broadcast_to(jnp.arange(S)[None], (Bz, S))
        ctx = Ctx(cfg=cfg, impl=impl, positions=pos, cache_len=0)
        x = embed_apply(params["embed"], tokens, cfg)
        x, nc = run_decoder(params, x, enc_out, ctx,
                            _wrap_dec_cache(cache["self"]), False)
        logits = logits_apply(
            params["embed"], rms_norm(x[:, -1:], params["ln_f"]), cfg
        )
        return logits[:, 0], {"self": _unwrap_dec_cache(nc),
                              "enc_out": enc_buf,
                              "enc_len": jnp.int32(Se)}

    def _wrap_dec_cache(kv):
        return {"self": kv}

    def _unwrap_dec_cache(nc):
        return nc["self"]

    def decode_fn(params, cache, tokens, t, *, impl="xla", rules=None):
        Bz, S = tokens.shape
        pos = jnp.broadcast_to(t + jnp.arange(S)[None], (Bz, S))
        ctx = Ctx(cfg=cfg, impl=impl, positions=pos, decode=True, cache_len=t)
        x = embed_apply(params["embed"], tokens, cfg)
        enc_out = cache["enc_out"]
        x, nc = run_decoder(params, x, enc_out, ctx,
                            _wrap_dec_cache(cache["self"]), False,
                            enc_len=cache["enc_len"])
        logits = logits_apply(
            params["embed"], rms_norm(x[:, -1:], params["ln_f"]), cfg
        )
        return logits[:, 0], {"self": _unwrap_dec_cache(nc),
                              "enc_out": enc_out,
                              "enc_len": cache["enc_len"]}

    return Model(cfg, defs, init, loss_fn, make_cache_defs, init_cache,
                 prefill_fn, decode_fn)


# ----------------------------------------------------------------- registry

def build_model(cfg: ArchConfig) -> Model:
    if cfg.attn_free:
        return build_rwkv_lm(cfg)
    if cfg.family == "hybrid":
        return build_griffin_lm(cfg)
    if cfg.is_encoder_decoder:
        return build_encdec(cfg)
    return build_decoder_lm(cfg)
