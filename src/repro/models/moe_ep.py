"""Expert-parallel MoE via shard_map (§Perf B2).

Why: the pjit-auto (scatter) formulation lets the SPMD partitioner decide
how to shard the dispatch scatter, and it decides badly — it replicates the
(E·cap, D) operands (1.37 TB temp/chip on deepseek-v3, see EXPERIMENTS.md).
This module writes the communication schedule explicitly:

  * activations are data-sharded and *replicated over the model axis*, so
    every device already holds the tokens of its data shard: building the
    per-expert dispatch buffer is a purely local scatter, and each device
    simply *slices out* its own experts — dispatch needs **zero** collective
    bytes;
  * expert weights are sharded (expert -> model, fsdp -> data); the data-axis
    shards are all-gathered per layer exactly like ZeRO-3 does for dense
    weights (explicit, overlappable by the scheduler);
  * each device computes its E/tp experts over its local capacity slots;
  * combine: local scatter-add back to the data shard's tokens, then one
    bf16 psum over the model axis.

Per-layer collective bytes (deepseek-v3, 16x16): ~1.2 GB weight gather +
~0.9 GB combine psum per device — vs ~5.3 GB/layer with the auto partitioner
(and none of the replicated temps).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShardingRules

f32 = jnp.float32


def _flat(ax):
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


def moe_apply_ep(p, x, cfg: ArchConfig, rules: ShardingRules):
    """shard_map expert-parallel MoE.  Requires rules.mesh."""
    assert rules is not None and rules.mesh is not None, "EP needs a mesh"
    mesh = rules.mesh
    data_axes = _flat(rules.batch)
    ep_axis = rules.expert
    fsdp_axis = rules.fsdp
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    cf = cfg.moe_capacity_factor

    wspec_i = P(ep_axis, fsdp_axis, None)      # (E, D, F)
    wspec_o = P(ep_axis, None, fsdp_axis)      # (E, F, D)
    xspec = P(data_axes if data_axes else None, None, None)

    def local_fn(x_l, router, wg_l, wu_l, wo_l):
        B_l, S_l, D = x_l.shape
        N_l = B_l * S_l
        xt = x_l.reshape(N_l, D)

        logits = (xt.astype(f32) @ router).astype(f32)        # (N_l, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )
        me = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=f32), 0)
        ce = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(me * ce)
        if data_axes:
            aux = lax.pmean(aux, data_axes)

        flat_e = expert_idx.reshape(-1)
        sort_idx = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[sort_idx]
        tok_of_slot = sort_idx // K
        gate_of_slot = gate_vals.reshape(-1)[sort_idx]
        counts = jnp.bincount(flat_e, length=E)
        group_start = jnp.cumsum(counts) - counts
        rank = jnp.arange(N_l * K) - group_start[sorted_e]
        cap = max(8, int(round(N_l * K / E * cf / 8)) * 8)
        cap = min(cap, N_l)
        keep = rank < cap
        dest = jnp.where(keep, sorted_e * cap + rank, E * cap)

        gathered = jnp.where(keep[:, None], xt[tok_of_slot], 0.0)
        buf = jnp.zeros((E * cap + 1, D), x_l.dtype).at[dest].set(gathered)
        buf = buf[:-1].reshape(E, cap, D)

        # ---- expert-parallel slice: my experts only (no comms) ----------
        tp = lax.axis_size(ep_axis) if ep_axis else 1
        e_loc = E // tp
        if ep_axis:
            m = lax.axis_index(ep_axis)
            buf_e = lax.dynamic_slice_in_dim(buf, m * e_loc, e_loc, 0)
        else:
            buf_e = buf

        # ---- ZeRO-3: gather my experts' weights over the fsdp axis ------
        wg = lax.all_gather(wg_l, fsdp_axis, axis=1, tiled=True) \
            if fsdp_axis else wg_l
        wu = lax.all_gather(wu_l, fsdp_axis, axis=1, tiled=True) \
            if fsdp_axis else wu_l
        wo = lax.all_gather(wo_l, fsdp_axis, axis=2, tiled=True) \
            if fsdp_axis else wo_l

        g = jnp.einsum("ecd,edf->ecf", buf_e, wg)
        h = jax.nn.silu(g) * jnp.einsum("ecd,edf->ecf", buf_e, wu)
        yb = jnp.einsum("ecf,efd->ecd", h, wo)                # (e_loc,cap,D)

        # ---- combine: local scatter-add for my experts, psum over EP ----
        yb_flat = jnp.zeros((E * cap, D), x_l.dtype)
        if ep_axis:
            yb_flat = lax.dynamic_update_slice_in_dim(
                yb_flat.reshape(E, cap, D), yb, m * e_loc, 0
            ).reshape(E * cap, D)
        else:
            yb_flat = yb.reshape(E * cap, D)
        y_slot = jnp.where(
            keep[:, None], yb_flat[jnp.clip(dest, 0, E * cap - 1)], 0.0
        )
        y = jnp.zeros((N_l, D), x_l.dtype).at[tok_of_slot].add(
            y_slot * gate_of_slot[:, None].astype(x_l.dtype)
        )
        if ep_axis:
            y = lax.psum(y, ep_axis)
        return y.reshape(B_l, S_l, D), aux

    in_specs = (xspec, P(), wspec_i, wspec_i, wspec_o)
    out_specs = (xspec, P())
    fn = jax.shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
    y, aux = fn(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    if cfg.n_shared_experts:
        from repro.models.layers import mlp_apply

        y = y + mlp_apply(p["shared"], x, cfg)
    return y, aux
