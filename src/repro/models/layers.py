"""Shared model primitives: norms, RoPE, attention, MLP, MoE, MLA.

Pure-functional: each sub-module exposes ``<name>_defs(cfg) -> ParamDef tree``
and ``<name>_apply(params, ...) -> outputs``.  Sharding comes exclusively from
the logical axis names inside the defs (resolved by ShardingRules).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.models.params import ParamDef

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Per-call context threaded through blocks."""
    cfg: ArchConfig
    impl: str = "xla"                 # attention/kernel implementation
    decode: bool = False
    positions: Any = None             # (B, S) absolute positions
    cache_len: Any = None             # traced scalar: #valid cache entries
    rules: Any = None                 # ShardingRules for act constraints


# ---------------------------------------------------------------- norms/rope

def norm_defs(d: int) -> dict:
    return {"scale": ParamDef((d,), (None,), init="zeros")}  # (1+s) parametrization


def rms_norm(x, p, eps: float = 1e-6):
    xf = x.astype(f32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(f32))).astype(x.dtype)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D) with D even; positions: (B, S)."""
    B, S, H, D = x.shape
    half = D // 2
    freq = jnp.exp(
        -jnp.log(theta) * jnp.arange(half, dtype=f32) / half
    )                                                    # (half,)
    ang = positions.astype(f32)[..., None] * freq        # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(f32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention

def attn_defs(cfg: ArchConfig, *, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDef((D, H, hd), ("fsdp", "tensor", None)),
        "wk": ParamDef((D, KV, hd), ("fsdp", "tensor", None)),
        "wv": ParamDef((D, KV, hd), ("fsdp", "tensor", None)),
        "wo": ParamDef((H, hd, D), ("tensor", None, "fsdp")),
    }
    if cfg.qk_norm:
        d["qnorm"] = norm_defs(hd)
        d["knorm"] = norm_defs(hd)
    return d


def attn_apply(
    p, x, ctx: Ctx, *,
    window: int | None = None,
    cache: dict | None = None,
    kv_src=None,                # cross-attention: encoder output
    kv_src_len=None,            # #valid rows of kv_src (padded buffers)
    causal: bool = True,
    use_rope: bool = True,
):
    """Returns (y, new_cache).  Cache: {'k','v'}: (B, Smax, KV, hd)."""
    cfg = ctx.cfg
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = x if kv_src is None else kv_src
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"])
        k = rms_norm(k, p["knorm"])
    if use_rope and kv_src is None:
        q = apply_rope(q, ctx.positions, cfg.rope_theta)
        k = apply_rope(k, ctx.positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and not ctx.decode:
        # prefill: write k/v into the cache buffer starting at 0
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
        }
        q_start, kv_len, ks, vs = 0, None, k, v
    elif cache is not None:
        # decode: append at cache_len, attend over the whole buffer masked
        t = ctx.cache_len
        ks = jax.lax.dynamic_update_slice(cache["k"], k, (0, t, 0, 0))
        vs = jax.lax.dynamic_update_slice(cache["v"], v, (0, t, 0, 0))
        new_cache = {"k": ks, "v": vs}
        q_start, kv_len = t, t + S
    else:
        q_start, kv_len, ks, vs = 0, kv_src_len, k, v

    y = flash_attention(
        q, ks, vs,
        causal=causal and kv_src is None,
        window=window,
        q_start=q_start,
        kv_len=kv_len,
        impl=ctx.impl,
        kv_chunk=cfg.attn_kv_chunk,
    )
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    return out, new_cache


# ---------------------------------------------------------------- MLP / MoE

def mlp_defs(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamDef((D, F), ("fsdp", "tensor")),
            "wi_up": ParamDef((D, F), ("fsdp", "tensor")),
            "wo": ParamDef((F, D), ("tensor", "fsdp")),
        }
    return {
        "wi": ParamDef((D, F), ("fsdp", "tensor")),
        "wo": ParamDef((F, D), ("tensor", "fsdp")),
    }


def mlp_apply(p, x, cfg: ArchConfig):
    if cfg.mlp_kind in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else (
            lambda z: jax.nn.gelu(z, approximate=True)
        )
        h = act(g) * jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]),
                        approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def moe_defs(cfg: ArchConfig) -> dict:
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    d = {
        "router": ParamDef((D, E), (None, None), dtype=f32),
        "wi_gate": ParamDef((E, D, F), ("expert", "fsdp", None)),
        "wi_up": ParamDef((E, D, F), ("expert", "fsdp", None)),
        "wo": ParamDef((E, F, D), ("expert", None, "fsdp")),
    }
    if cfg.n_shared_experts:
        d["shared"] = mlp_defs(cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return d


def moe_apply(p, x, cfg: ArchConfig, capacity_factor: float | None = None,
              rules=None):
    """Sort-based top-k dispatch with per-expert capacity (GShard-style drop).

    Returns (y, aux_loss).  Expert axis shards over the 'expert' logical axis
    (EP); the dispatch buffer reshape induces the all-to-all under pjit.
    ``cfg.moe_dispatch_sharding`` pins the dispatch buffers with explicit
    constraints (EXPERIMENTS.md §Perf: without them XLA replicates the
    (E, cap, D) buffers — 150 GB/chip on deepseek-v3).
    """
    from repro.parallel.sharding import shard_act

    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    constrain = cfg.moe_dispatch_sharding and rules is not None
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    N = B * S
    xt = x.reshape(N, D)
    if constrain:
        xt = shard_act(xt, rules, "bn")

    logits = (xt.astype(f32) @ p["router"]).astype(f32)          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)              # (N, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=f32), axis=0
    )
    ce = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(me * ce)

    flat_e = expert_idx.reshape(-1)                               # (N*K,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    tok_of_slot = sort_idx // K
    gate_of_slot = gate_vals.reshape(-1)[sort_idx]

    counts = jnp.bincount(flat_e, length=E)
    group_start = jnp.cumsum(counts) - counts                     # (E,)
    rank = jnp.arange(N * K) - group_start[sorted_e]

    cap = max(8, int(round(N * K / E * capacity_factor / 8)) * 8)
    cap = min(cap, N)
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, E * cap)        # drop slot

    gathered = jnp.where(keep[:, None], xt[tok_of_slot], 0.0)
    buf = jnp.zeros((E * cap + 1, D), x.dtype).at[dest].set(gathered)
    buf = buf[:-1].reshape(E, cap, D)
    if constrain:
        buf = shard_act(buf, rules, "xbn")   # experts x EP, capacity x DP

    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"])
    h = jax.nn.silu(g) * jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    if constrain:
        h = shard_act(h, rules, "xbn")
    yb = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * cap, D)
    if constrain:
        yb = shard_act(yb, rules, "bn")

    y_slot = jnp.where(keep[:, None], yb[jnp.clip(dest, 0, E * cap - 1)], 0.0)
    y = jnp.zeros((N, D), x.dtype).at[tok_of_slot].add(
        y_slot * gate_of_slot[:, None].astype(x.dtype)
    )
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg).reshape(N, D)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------- MLA

def mla_defs(cfg: ArchConfig) -> dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": ParamDef((D, m.q_lora_rank), ("fsdp", None)),
        "q_norm": norm_defs(m.q_lora_rank),
        "w_uq": ParamDef((m.q_lora_rank, H, qk), (None, "tensor", None)),
        "w_dkv": ParamDef(
            (D, m.kv_lora_rank + m.qk_rope_head_dim), ("fsdp", None)
        ),
        "kv_norm": norm_defs(m.kv_lora_rank),
        "w_uk": ParamDef(
            (m.kv_lora_rank, H, m.qk_nope_head_dim), (None, "tensor", None)
        ),
        "w_uv": ParamDef(
            (m.kv_lora_rank, H, m.v_head_dim), (None, "tensor", None)
        ),
        "wo": ParamDef((H, m.v_head_dim, D), ("tensor", None, "fsdp")),
    }


def mla_apply(p, x, ctx: Ctx, cache: dict | None = None):
    """Multi-head latent attention.  Cache stores the *latent* c_kv + shared
    k_rope (the paper-aligned memory win: 576 vs 2·H·hd floats per token).

    Prefill/train: expanded MHA.  Decode: absorbed form (q projected into the
    latent space; never materializes per-head K/V).
    """
    cfg = ctx.cfg
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(
        q[..., m.qk_nope_head_dim:], ctx.positions, cfg.rope_theta
    )

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rms_norm(dkv[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(
        dkv[..., m.kv_lora_rank:][:, :, None, :], ctx.positions,
        cfg.rope_theta,
    )[:, :, 0]                                            # (B,S,rope)

    new_cache = None
    if cache is not None and not ctx.decode:
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], c_kv, 0, 1
            ),
            "krope": jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], k_rope, 0, 1
            ),
        }
    if cache is None or not ctx.decode:
        # expanded attention (training / prefill)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhv->bshv", c_kv, p["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim)
            )], -1,
        )
        qq = jnp.concatenate([q_nope, q_rope], -1)
        y = flash_attention(
            qq, k, v, causal=True, impl=ctx.impl, softmax_scale=scale
        )
    else:
        # absorbed decode: score via latent space
        t = ctx.cache_len
        ckv_s = jax.lax.dynamic_update_slice(cache["ckv"], c_kv, (0, t, 0))
        krope_s = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope, (0, t, 0)
        )
        new_cache = {"ckv": ckv_s, "krope": krope_s}
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
        scores = (
            jnp.einsum("bshr,btr->bhst", q_lat.astype(f32),
                       ckv_s.astype(f32))
            + jnp.einsum("bshk,btk->bhst", q_rope.astype(f32),
                         krope_s.astype(f32))
        ) * scale
        Smax = ckv_s.shape[1]
        kpos = jnp.arange(Smax)[None, None, None, :]
        qpos = (t + jnp.arange(S))[None, None, :, None]
        scores = jnp.where(kpos <= qpos, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhst,btr->bshr", w, ckv_s.astype(f32))
        y = jnp.einsum("bshr,rhv->bshv", ctx_lat.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bshv,hvd->bsd", y, p["wo"])
    return out, new_cache


# ---------------------------------------------------------------- embedding

def embed_defs(cfg: ArchConfig) -> dict:
    d = {"tok": ParamDef((cfg.vocab_size, cfg.d_model), ("tensor", "fsdp"),
                         init="embed")}
    if not cfg.tie_embeddings:
        d["out"] = ParamDef((cfg.d_model, cfg.vocab_size), ("fsdp", "tensor"))
    return d


def embed_apply(p, tokens, cfg: ArchConfig):
    x = p["tok"][tokens]
    if cfg.name.startswith("gemma") or cfg.name.startswith("recurrentgemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def logits_apply(p, x, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["tok"]).astype(f32)
    return jnp.einsum("bsd,dv->bsv", x, p["out"]).astype(f32)
