"""Per-family residual blocks: dense/MoE transformer, RWKV-6, RG-LRU hybrid.

Block contract (scan-compatible):
    defs  = <family>_block_defs(cfg)                  # one layer's ParamDefs
    x, cache' , aux = <family>_block_apply(p, x, ctx, cache)
``cache`` is the layer's decode-state pytree (None during training).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.rglru.ops import rglru
from repro.kernels.rwkv6.ops import wkv6
from repro.models.layers import (
    Ctx,
    attn_apply,
    attn_defs,
    mla_apply,
    mla_defs,
    mlp_apply,
    mlp_defs,
    moe_apply,
    moe_defs,
    norm_defs,
    rms_norm,
)
from repro.models.params import ParamDef

f32 = jnp.float32


# ------------------------------------------------------------ dense / MoE

def transformer_block_defs(cfg: ArchConfig, *, moe: bool = False) -> dict:
    attn = mla_defs(cfg) if cfg.mla is not None else attn_defs(cfg)
    return {
        "ln1": norm_defs(cfg.d_model),
        "attn": attn,
        "ln2": norm_defs(cfg.d_model),
        "mlp": moe_defs(cfg) if moe else mlp_defs(cfg),
    }


def transformer_block_apply(p, x, ctx: Ctx, cache=None, *, moe: bool = False,
                            window: int | None = None):
    h = rms_norm(x, p["ln1"])
    if ctx.cfg.mla is not None:
        a, new_cache = mla_apply(p["attn"], h, ctx, cache)
    else:
        a, new_cache = attn_apply(p["attn"], h, ctx, cache=cache,
                                  window=window)
    x = x + a
    h = rms_norm(x, p["ln2"])
    if moe:
        if ctx.cfg.moe_impl == "ep_shardmap" and ctx.rules is not None \
                and getattr(ctx.rules, "mesh", None) is not None:
            from repro.models.moe_ep import moe_apply_ep

            m, aux = moe_apply_ep(p["mlp"], h, ctx.cfg, ctx.rules)
        else:
            m, aux = moe_apply(p["mlp"], h, ctx.cfg, rules=ctx.rules)
    else:
        m, aux = mlp_apply(p["mlp"], h, ctx.cfg), jnp.zeros((), f32)
    return x + m, new_cache, aux


# ------------------------------------------------------------ RWKV-6

_RWKV_LORA = 32
_RWKV_DECAY_LORA = 64


def rwkv6_block_defs(cfg: ArchConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H = D // cfg.head_dim
    N = cfg.head_dim
    return {
        "ln1": norm_defs(D),
        "tmix": {
            "mu_x": ParamDef((D,), (None,), init="zeros"),
            "mu": ParamDef((5, D), (None, None), init="zeros"),
            "lora_a": ParamDef((D, 5 * _RWKV_LORA), ("fsdp", None)),
            "lora_b": ParamDef((5, _RWKV_LORA, D), (None, None, None),
                               init="zeros"),
            "w0": ParamDef((D,), (None,), init="zeros"),
            "wa": ParamDef((D, _RWKV_DECAY_LORA), ("fsdp", None)),
            "wb": ParamDef((_RWKV_DECAY_LORA, D), (None, None), init="zeros"),
            "wr": ParamDef((D, D), ("fsdp", "tensor")),
            "wk": ParamDef((D, D), ("fsdp", "tensor")),
            "wv": ParamDef((D, D), ("fsdp", "tensor")),
            "wg": ParamDef((D, D), ("fsdp", "tensor")),
            "wo": ParamDef((D, D), ("tensor", "fsdp")),
            "u": ParamDef((H, N), (None, None), init="zeros"),
            "gn": norm_defs(D),
        },
        "ln2": norm_defs(D),
        "cmix": {
            "mu_k": ParamDef((D,), (None,), init="zeros"),
            "mu_r": ParamDef((D,), (None,), init="zeros"),
            "wk": ParamDef((D, F), ("fsdp", "tensor")),
            "wv": ParamDef((F, D), ("tensor", "fsdp")),
            "wr": ParamDef((D, D), ("fsdp", None)),
        },
    }


def _token_shift(x, last_x):
    """shift right by one; first position comes from the decode state."""
    prev = jnp.concatenate([last_x[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def rwkv6_block_apply(p, x, ctx: Ctx, cache=None):
    """cache = {'tm_x','cm_x': (B,D), 'wkv': (B,H,N,N)} or None."""
    cfg = ctx.cfg
    B, T, D = x.shape
    H, N = D // cfg.head_dim, cfg.head_dim

    # ---- time mix -----------------------------------------------------------
    tm = p["tmix"]
    h = rms_norm(x, p["ln1"])
    last = cache["tm_x"] if cache is not None else jnp.zeros((B, D), h.dtype)
    prev = _token_shift(h, last)
    xx = prev - h
    xxx = h + xx * tm["mu_x"]
    lo = jnp.tanh(jnp.einsum("btd,dr->btr", xxx, tm["lora_a"]))
    lo = lo.reshape(B, T, 5, _RWKV_LORA)
    mix = tm["mu"][None, None] + jnp.einsum(
        "btfr,frd->btfd", lo, tm["lora_b"]
    )
    xr, xk, xv, xw, xg = [h + xx * mix[:, :, i] for i in range(5)]

    r = jnp.einsum("btd,de->bte", xr, tm["wr"]).reshape(B, T, H, N)
    k = jnp.einsum("btd,de->bte", xk, tm["wk"]).reshape(B, T, H, N)
    v = jnp.einsum("btd,de->bte", xv, tm["wv"]).reshape(B, T, H, N)
    g = jnp.einsum("btd,de->bte", xg, tm["wg"])
    logw = -jnp.exp(
        tm["w0"].astype(f32)
        + jnp.einsum("btd,dr->btr", xw.astype(f32), tm["wa"].astype(f32))
        @ tm["wb"].astype(f32)
    )
    w = jnp.exp(logw).reshape(B, T, H, N)

    s0 = cache["wkv"] if cache is not None else None
    o, sT = wkv6(r, k, v, w.astype(r.dtype), tm["u"], initial_state=s0,
                 impl=ctx.impl if ctx.impl != "pallas" else "pallas")
    o = o.reshape(B, T, D)
    o = rms_norm(o, tm["gn"]) * jax.nn.silu(g)
    x = x + jnp.einsum("btd,de->bte", o, tm["wo"])

    # ---- channel mix ---------------------------------------------------------
    cm = p["cmix"]
    h2 = rms_norm(x, p["ln2"])
    last2 = cache["cm_x"] if cache is not None else jnp.zeros((B, D), h2.dtype)
    prev2 = _token_shift(h2, last2)
    xx2 = prev2 - h2
    hk = h2 + xx2 * cm["mu_k"]
    hr = h2 + xx2 * cm["mu_r"]
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", hk, cm["wk"])))
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", hr, cm["wr"])) * jnp.einsum(
        "btf,fd->btd", kk, cm["wv"]
    )
    x = x + out

    new_cache = None
    if cache is not None:
        new_cache = {"tm_x": h[:, -1], "cm_x": h2[:, -1], "wkv": sT}
    return x, new_cache, jnp.zeros((), f32)


# ------------------------------------------------------------ RG-LRU (Griffin)

_CONV_W = 4
_LRU_C = 8.0


def griffin_rec_block_defs(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    W = cfg.lru_width or D
    return {
        "ln1": norm_defs(D),
        "rec": {
            "wx": ParamDef((D, W), ("fsdp", "tensor")),
            "wy": ParamDef((D, W), ("fsdp", "tensor")),
            "conv_w": ParamDef((_CONV_W, W), (None, "tensor"), init="zeros"),
            "conv_b": ParamDef((W,), ("tensor",), init="zeros"),
            "wa_gate": ParamDef((W, W), ("tensor", None)),
            "wx_gate": ParamDef((W, W), ("tensor", None)),
            "lam": ParamDef((W,), ("tensor",), init="ones"),
            "wo": ParamDef((W, D), ("tensor", "fsdp")),
        },
        "ln2": norm_defs(D),
        "mlp": mlp_defs(cfg),
    }


def griffin_rec_block_apply(p, x, ctx: Ctx, cache=None):
    """cache = {'conv': (B, CONV_W-1, W), 'h': (B, W)} or None."""
    cfg = ctx.cfg
    B, T, D = x.shape
    W = cfg.lru_width or D
    rec = p["rec"]
    h = rms_norm(x, p["ln1"])
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", h, rec["wy"]),
                       approximate=True)
    u = jnp.einsum("btd,dw->btw", h, rec["wx"])

    # causal depthwise temporal conv, width 4
    prev = (
        cache["conv"] if cache is not None
        else jnp.zeros((B, _CONV_W - 1, W), u.dtype)
    )
    upad = jnp.concatenate([prev, u], axis=1)             # (B, T+3, W)
    conv = sum(
        upad[:, i : i + T, :] * rec["conv_w"][i][None, None]
        for i in range(_CONV_W)
    ) + rec["conv_b"]

    # RG-LRU gates
    ra = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", conv, rec["wa_gate"]))
    ix = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", conv, rec["wx_gate"]))
    log_a = (-_LRU_C * jax.nn.softplus(rec["lam"].astype(f32)))[None, None] \
        * ra.astype(f32)
    gx = ix * conv
    h0 = cache["h"] if cache is not None else None
    hs, hT = rglru(log_a, gx, h0,
                   impl=ctx.impl if ctx.impl != "pallas" else "pallas")

    y = hs * gate
    x = x + jnp.einsum("btw,wd->btd", y, rec["wo"])
    h2 = rms_norm(x, p["ln2"])
    x = x + mlp_apply(p["mlp"], h2, cfg)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": upad[:, -(_CONV_W - 1):, :], "h": hT}
    return x, new_cache, jnp.zeros((), f32)


def griffin_attn_block_defs(cfg: ArchConfig) -> dict:
    return {
        "ln1": norm_defs(cfg.d_model),
        "attn": attn_defs(cfg),
        "ln2": norm_defs(cfg.d_model),
        "mlp": mlp_defs(cfg),
    }


def griffin_attn_block_apply(p, x, ctx: Ctx, cache=None):
    h = rms_norm(x, p["ln1"])
    a, new_cache = attn_apply(p["attn"], h, ctx, cache=cache,
                              window=ctx.cfg.local_window)
    x = x + a
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]), ctx.cfg)
    return x, new_cache, jnp.zeros((), f32)


# ------------------------------------------------------------ encoder (bidi)

def encoder_block_defs(cfg: ArchConfig) -> dict:
    return {
        "ln1": norm_defs(cfg.d_model),
        "attn": attn_defs(cfg),
        "ln2": norm_defs(cfg.d_model),
        "mlp": mlp_defs(cfg),
    }


def encoder_block_apply(p, x, ctx: Ctx):
    h = rms_norm(x, p["ln1"])
    a, _ = attn_apply(p["attn"], h, ctx, causal=False)
    x = x + a
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]), ctx.cfg)
    return x


def decoder_block_defs(cfg: ArchConfig) -> dict:
    return {
        "ln1": norm_defs(cfg.d_model),
        "self_attn": attn_defs(cfg),
        "ln_x": norm_defs(cfg.d_model),
        "cross_attn": attn_defs(cfg, cross=True),
        "ln2": norm_defs(cfg.d_model),
        "mlp": mlp_defs(cfg),
    }


def decoder_block_apply(p, x, ctx: Ctx, enc_out, cache=None, enc_len=None):
    """cache = {'self': kv-cache}; enc_len masks padded enc_out buffers."""
    h = rms_norm(x, p["ln1"])
    a, new_self = attn_apply(
        p["self_attn"], h, ctx,
        cache=None if cache is None else cache["self"],
    )
    x = x + a
    h = rms_norm(x, p["ln_x"])
    c, _ = attn_apply(p["cross_attn"], h, ctx, kv_src=enc_out,
                      kv_src_len=enc_len, causal=False, use_rope=False)
    x = x + c
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]), ctx.cfg)
    new_cache = None if cache is None else {"self": new_self}
    return x, new_cache, jnp.zeros((), f32)
