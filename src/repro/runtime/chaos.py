"""Deterministic fault injection for the serving runtime (DESIGN.md §13).

SERENITY's contract is that plans fit a hard byte budget; this module
exercises the runtime that must keep honoring it when the world
misbehaves.  A :class:`FaultPlan` is a seeded, fully deterministic script
of faults — *which* fault, *at which* server tick — and a
:class:`ChaosController` turns it into the hook callables the runtime
already exposes (``ArenaPool.admission_hook``, the ``DecodeServer``
``chaos=`` parameter, ``PlanCache(blob_hook=...)``).  Nothing is
monkeypatched: every injection point is a first-class seam of the object
it perturbs.

Fault kinds:

  ``budget_shrink``      the server calls ``set_budget(budget * factor)``
                         at the tick — the degradation-ladder trigger.
  ``admission_failure``  every pool admission attempt during the tick
                         fails transiently (the queue holds; a later
                         drain retries).
  ``executor_error``     one :class:`TransientExecutorError` raised at
                         the top of the tick's decode phase, before any
                         request state is touched — the server's bounded
                         retry path.
  ``cache_corrupt``      the next plan-cache disk read returns a
                         bit-flipped blob; the CRC frame must catch it
                         (``CacheStats.corrupt``) and evict the entry.

The chaos differential suite (``tests/test_chaos.py``) replays a seeded
corpus of these plans against both a simulated and the real decode server
and asserts the three invariants: no request lost (every submit completes
or is rejected with a machine-readable ``reason_code``), the realized
arena bytes never exceed the *instantaneous* budget, and the token
streams of surviving requests are bit-equal to the fault-free run.
"""

from __future__ import annotations

import dataclasses
import random

FAULT_KINDS = (
    "budget_shrink",
    "admission_failure",
    "executor_error",
    "cache_corrupt",
)


class TransientExecutorError(RuntimeError):
    """An injected (or real) transient failure of one decode step; request
    state is untouched, so the step is safely retryable."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault: ``kind`` fires at server tick ``tick`` (1-based).

    ``factor`` is the budget multiplier for ``budget_shrink`` (0.5 = the
    classic mid-run 2x shrink) and ignored by the other kinds.
    """

    kind: str
    tick: int
    factor: float = 0.5

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        if self.tick < 1:
            raise ValueError(f"fault tick must be >= 1, got {self.tick}")
        if self.kind == "budget_shrink" and not 0.0 < self.factor <= 1.0:
            raise ValueError(f"budget_shrink factor must be in (0, 1], "
                             f"got {self.factor}")


class FaultPlan:
    """An ordered, deterministic script of :class:`FaultSpec` events."""

    def __init__(self, specs=()):
        self.specs: tuple[FaultSpec, ...] = tuple(sorted(
            specs, key=lambda s: (s.tick, FAULT_KINDS.index(s.kind))))

    @classmethod
    def generate(cls, seed: int, *, n_ticks: int = 24,
                 kinds=FAULT_KINDS, rate: float = 0.2,
                 max_shrinks: int = 2,
                 min_shrink_factor: float = 0.45) -> "FaultPlan":
        """A seeded random fault script — the chaos corpus generator.

        Same ``(seed, kwargs)`` -> same plan, always (``random.Random``,
        no global state).  At most ``max_shrinks`` budget shrinks are
        emitted and each keeps at least ``min_shrink_factor`` of the
        budget, so a corpus plan degrades the pool without zeroing it —
        requests the *initial* budget admitted stay representable, which
        is what makes the no-request-lost invariant interesting rather
        than vacuous (a rejected-everything run asserts nothing).
        """
        rng = random.Random(seed)
        specs = []
        shrinks = 0
        for tick in range(1, n_ticks + 1):
            if rng.random() >= rate:
                continue
            kind = kinds[rng.randrange(len(kinds))]
            if kind == "budget_shrink":
                if shrinks >= max_shrinks:
                    continue
                shrinks += 1
                factor = round(rng.uniform(min_shrink_factor, 0.8), 3)
                specs.append(FaultSpec(kind, tick, factor))
            else:
                specs.append(FaultSpec(kind, tick))
        return cls(specs)

    def at(self, tick: int) -> tuple[FaultSpec, ...]:
        return tuple(s for s in self.specs if s.tick == tick)

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def describe(self) -> str:
        if not self.specs:
            return "fault-free"
        return ", ".join(
            f"{s.kind}@{s.tick}" + (f"x{s.factor}"
                                    if s.kind == "budget_shrink" else "")
            for s in self.specs)


class ChaosController:
    """Drives a :class:`FaultPlan` through the runtime's injection hooks.

    The tick-driven protocol: the serving loop calls :meth:`begin_tick`
    at the top of every tick and acts on the returned specs itself
    (``budget_shrink`` -> ``server.set_budget``); the hook-shaped kinds
    latch inside the controller and fire when the instrumented object
    consults its hook (``admission_should_fail`` from ``ArenaPool``,
    ``maybe_executor_error`` from the server's decode phase,
    ``corrupt_blob`` from ``PlanCache``).  ``fired`` is the audit log of
    every fault that actually landed.
    """

    #: kinds begin_tick returns for the driver to act on directly
    _DRIVER_KINDS = ("budget_shrink",)

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.tick = 0
        self.fired: list[FaultSpec] = []
        self._adm_fail: FaultSpec | None = None
        self._exec_err: FaultSpec | None = None
        self._pending_corrupt: list[FaultSpec] = []

    def begin_tick(self, tick: int) -> tuple[FaultSpec, ...]:
        """Arm this tick's faults; returns the driver-handled specs."""
        self.tick = tick
        specs = self.plan.at(tick)
        self._adm_fail = next(
            (s for s in specs if s.kind == "admission_failure"), None)
        self._exec_err = next(
            (s for s in specs if s.kind == "executor_error"), None)
        self._pending_corrupt.extend(
            s for s in specs if s.kind == "cache_corrupt")
        driver = tuple(s for s in specs if s.kind in self._DRIVER_KINDS)
        self.fired.extend(driver)
        return driver

    # -- ArenaPool.admission_hook ------------------------------------------

    def admission_should_fail(self) -> bool:
        """True for every admission attempt during an armed tick."""
        if self._adm_fail is None:
            return False
        self.fired.append(self._adm_fail)
        return True

    # -- DecodeServer decode-phase hook ------------------------------------

    def maybe_executor_error(self) -> None:
        """Raise the tick's armed transient error exactly once."""
        if self._exec_err is None:
            return
        spec, self._exec_err = self._exec_err, None
        self.fired.append(spec)
        raise TransientExecutorError(
            f"injected transient executor error at tick {spec.tick}")

    # -- PlanCache blob_hook ------------------------------------------------

    def corrupt_blob(self, blob: bytes) -> bytes:
        """Bit-flip a pending corruption into the next disk read."""
        if not self._pending_corrupt or not blob:
            return blob
        spec = self._pending_corrupt.pop(0)
        self.fired.append(spec)
        pos = (spec.tick * 2654435761) % len(blob)
        return blob[:pos] + bytes([blob[pos] ^ 0xFF]) + blob[pos + 1:]

    @property
    def n_fired(self) -> int:
        return len(self.fired)


def seeded_corpus(n: int, *, base_seed: int = 0, **kwargs) -> list[FaultPlan]:
    """``n`` deterministic fault plans — the chaos corpus the CI job and
    the nightly ``--runslow`` sweep replay (see ``tests/test_chaos.py``)."""
    return [FaultPlan.generate(base_seed + i, **kwargs) for i in range(n)]
