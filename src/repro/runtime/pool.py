"""Multi-tenant arena pool: budgeted leases of pre-planned serving arenas.

One edge device, one byte budget, many concurrent requests — the regime
where per-inference footprint is the binding constraint.  The pool turns
the single-request plan machinery (scheduler → arena offsets) into
admission control (DESIGN.md §9):

  * every request *leases* a pre-planned arena for its (graph-hash, shape);
    repeat shapes skip planning (plan LRU) *and* allocation (warm-buffer
    LRU);
  * admission charges the request's plan against the global budget via
    :func:`~repro.core.allocator.plan_shared_arena`: with the default
    ``overlap='serial'`` the joint extent overlaps the members'
    non-concurrent transient slack, so K requests reserve far less than K
    standalone arenas;
  * a request that fits is **admitted**, one that would overflow is
    **queued** (FIFO, head-of-line order preserved), and one whose own
    arena can never fit the budget is **rejected** outright;
  * a key may carry several *request-class* plans — distinct points of the
    latency x memory Pareto frontier (DESIGN.md §12) registered via
    ``register_pareto`` — and ``submit(..., klass=...)`` leases the class's
    plan: a memory-starved request takes the min-peak point, a
    latency-sensitive one the min-makespan point with its transients
    pinned (no buffer-reuse hazards between co-issued ops).

The pool is a synchronous scheduler-side object: one serving loop drives
``submit`` / ``poll`` / ``release``; it is not thread-safe by design.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import warnings
from typing import Callable, Sequence

import numpy as np

from repro.core.allocator import (
    ArenaPlan,
    SharedArenaPlan,
    pin_transients,
    plan_arena_best,
    plan_shared_arena,
    resident_bytes,
)
from repro.core.graph import Graph
from repro.core.plancache import labeled_fingerprint
from repro.core.serenity import PlanConfig, plan as serenity_plan

# Default lease planning: pack the caller's order (or the deterministic topo
# order) as-is — pool members arrive pre-scheduled, so the pool only needs
# arena offsets, not a DP search.
_LEASE_CONFIG = PlanConfig(rewrite=False, inplace=False,
                           compute_baselines=False)


class PoolError(RuntimeError):
    """Pool misuse or admission impossibility, with structured context.

    Besides the formatted message, every raise site attaches the numbers it
    was formatted from as attributes — ``code`` (a stable machine-readable
    cause tag), ``requested_bytes``, ``budget_bytes``, ``reserved_bytes``,
    ``queue_depth`` — so the degradation ladder and tests branch on cause
    instead of regex-matching messages (DESIGN.md §13).  ``context`` is the
    dict of every non-``None`` attribute.
    """

    def __init__(self, message: str, *, code: str | None = None,
                 requested_bytes: int | None = None,
                 budget_bytes: int | None = None,
                 reserved_bytes: int | None = None,
                 queue_depth: int | None = None):
        super().__init__(message)
        self.code = code
        self.requested_bytes = requested_bytes
        self.budget_bytes = budget_bytes
        self.reserved_bytes = reserved_bytes
        self.queue_depth = queue_depth

    @property
    def context(self) -> dict:
        return {k: v for k, v in (
            ("code", self.code),
            ("requested_bytes", self.requested_bytes),
            ("budget_bytes", self.budget_bytes),
            ("reserved_bytes", self.reserved_bytes),
            ("queue_depth", self.queue_depth),
        ) if v is not None}


def pareto_class_plans(graph, frontier) -> dict[str, ArenaPlan]:
    """Arena plans for the two canonical request classes of a frontier.

    Maps a :class:`~repro.core.scheduler.ParetoFrontier` (DESIGN.md §12)
    onto the admission classes the pool serves:

      ``'memory'``   the min-peak point's arena — the smallest footprint
                     the schedule space offers, for memory-starved
                     admission (maximum co-residency).
      ``'latency'``  the min-makespan point's arena with every transient
                     pinned (:func:`~repro.core.allocator.pin_transients`)
                     — a latency-sensitive request trades bytes for a
                     layout with no buffer-reuse hazards to wait on.

    Both plans are packed with the point's co-issue steps, so the planned
    peak is exactly the frontier point's ``peak_bytes``.  Register the
    result with :meth:`ArenaPool.register_pareto`.
    """
    if not frontier.points:
        raise PoolError("cannot build class plans from an empty frontier")
    mem_pt = frontier.min_peak
    lat_pt = frontier.min_makespan
    mem_plan = plan_arena_best(graph, mem_pt.order, steps=mem_pt.steps)
    lat_plan = plan_arena_best(graph, lat_pt.order, steps=lat_pt.steps)
    return {"memory": mem_plan, "latency": pin_transients(lat_plan)}


class LeaseError(PoolError):
    """Lease lifecycle misuse (double release, foreign lease)."""


@dataclasses.dataclass
class PoolStats:
    """Counters over the pool's lifetime (bytes fields in bytes)."""

    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    released: int = 0
    plan_hits: int = 0           # planning skipped (plan LRU)
    warm_hits: int = 0           # buffer allocation skipped (warm LRU)
    evictions: int = 0           # warm buffers dropped by the LRU cap
    peak_reserved_bytes: int = 0
    max_concurrent: int = 0
    peak_queued: int = 0
    # admissions per request class (DESIGN.md §12); classless admissions
    # are not counted here
    admitted_by_class: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PreemptionStats:
    """Preemption / spill / re-admission counters (DESIGN.md §13)."""

    preemptions: int = 0
    spilled_bytes: int = 0       # total host bytes written by preempt()
    readmit_attempts: int = 0
    readmitted: int = 0
    readmit_rejections: int = 0  # re-admissions the shrunk budget can never fit
    admission_faults: int = 0    # admissions suppressed by the fault hook
    budget_shrinks: int = 0
    budget_evictions: int = 0    # queued tickets rejected by a shrink sweep

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Lease:
    """An admitted request's hold on planned arena bytes.

    ``plan`` is the standalone member plan (offsets local to this lease's
    own address space); ``buffer``, when the pool allocates physical
    buffers, covers ``resident_extent`` bytes — the persistent (state)
    region of the plan, which is what must survive between steps.  The
    transient region above it is accounted (and shared across members by
    admission) but never materialized per lease.
    """

    rid: int
    key: str
    plan: ArenaPlan
    arena_bytes: int             # standalone extent (naive reserve)
    persistent_bytes: int
    resident_extent: int
    buffer: object | None = None
    priority: int = 0            # higher = more important; preempt picks min
    tenant: str | None = None
    _released: bool = dataclasses.field(default=False, repr=False)


@dataclasses.dataclass
class Ticket:
    """Tracks one submitted request through admit / queue / reject.

    ``reason_code`` is the machine-readable rejection cause (stable tags:
    ``'budget'``, ``'tenant_quota'``, ``'budget_shrunk'``,
    ``'readmit_exhausted'``); ``reason`` the human-formatted counterpart.
    """

    rid: int
    key: str
    lease: Lease | None = None
    rejected: bool = False
    reason: str = ""
    reason_code: str = ""
    klass: str | None = None     # Pareto request class, when submitted with one
    priority: int = 0
    tenant: str | None = None

    @property
    def admitted(self) -> bool:
        return self.lease is not None


@dataclasses.dataclass
class SpilledLease:
    """A preempted lease's movable state, waiting to be re-admitted.

    ``host_state`` holds the lease's resident bytes copied off the device
    (the ``pack_decode_state`` round-trip makes them self-contained: the
    plan's offsets are buffer-relative, so any future buffer can host them
    verbatim).  ``attempts`` / ``next_tick`` are the re-admission backoff
    bookkeeping the serving loop drives (bounded retry, exponential
    backoff — DESIGN.md §13).
    """

    rid: int
    key: str
    plan: ArenaPlan
    spill_bytes: int
    host_state: object | None = None   # np.uint8 copy of the resident bytes
    klass: str | None = None
    priority: int = 0
    tenant: str | None = None
    attempts: int = 0
    next_tick: int = 0

    def backoff(self, tick: int) -> None:
        """Record a failed re-admission attempt; next try after 2^attempts
        ticks counting the attempt just recorded (2, 4, 8, ... —
        exponential)."""
        self.attempts += 1
        self.next_tick = tick + (1 << self.attempts)

    def due(self, tick: int) -> bool:
        return tick >= self.next_tick


@dataclasses.dataclass
class ScratchReservation:
    """A handle on transient scratch bytes charged against a pool's budget.

    Returned by :meth:`ArenaPool.reserve_scratch`; each reservation is
    independent — two reservers (a vmap padding step and a prefill lane,
    say) each hold their own token and release only their own bytes, so
    neither can clobber the other.  Release via :meth:`release` (or
    :meth:`ArenaPool.release_scratch`); releasing twice raises
    :class:`PoolError` with ``code='scratch_double_release'``.
    """

    sid: int
    nbytes: int
    _pool: "ArenaPool" = dataclasses.field(repr=False)
    released: bool = dataclasses.field(default=False, repr=False)

    def release(self) -> None:
        self._pool.release_scratch(self)


class ArenaPool:
    """Budgeted pool of pre-planned arena leases (DESIGN.md §9).

    Args:
      budget_bytes: the global device-memory budget all admitted leases
        must fit under (joint extent, not naive sum — see ``overlap``).
      overlap: admission accounting mode.  ``'serial'`` (default) charges
        the :func:`plan_shared_arena` joint extent — members' transient
        slack is shared, matching a runtime that executes admitted steps
        back-to-back on one stream.  ``'none'`` charges the naive sum of
        standalone extents (one arena per request) — the baseline an
        execution mode that materializes every member's transients at once
        must use.
      max_warm: released lease buffers kept warm per pool (LRU); a repeat
        shape leases without planning or allocating.
      planner: ``planner(graph, order) -> ArenaPlan``; defaults to
        :func:`repro.core.serenity.plan` packing the graph's deterministic
        topo order (arena offsets only — no DP search).
      alloc_fn: ``alloc_fn(nbytes) -> buffer`` for physical lease buffers
        (the serving driver passes a jnp uint8 allocator).  ``None`` keeps
        the pool accounting-only (``Lease.buffer is None``).
      tenant_quotas: optional per-tenant byte caps: a tenant's admitted
        leases may never jointly charge more than its quota (each lease is
        charged its standalone joint extent).  Tenants absent from the map
        are unconstrained.
      admission_hook: fault-injection point (DESIGN.md §13): called with no
        arguments immediately before each admission attempt; returning
        truthy makes that attempt fail transiently (the request stays
        queued, ``preemption_stats.admission_faults`` counts it, and a
        later :meth:`kick` / release retries).  ``None`` disables.
    """

    def __init__(
        self,
        budget_bytes: int,
        *,
        overlap: str = "serial",
        max_warm: int = 4,
        max_plans: int = 64,
        planner: Callable[[Graph, Sequence[int] | None], ArenaPlan] | None = None,
        alloc_fn: Callable[[int], object] | None = None,
        tenant_quotas: dict[str, int] | None = None,
        admission_hook: Callable[[], bool] | None = None,
    ):
        if overlap not in ("serial", "none"):
            raise PoolError(f"unknown overlap mode {overlap!r}",
                            code="bad_overlap")
        self.budget_bytes = int(budget_bytes)
        self.overlap = overlap
        self.max_warm = max_warm
        self.tenant_quotas = dict(tenant_quotas or {})
        self.admission_hook = admission_hook
        self._planner = planner
        self._alloc_fn = alloc_fn
        self._plans: collections.OrderedDict[str, ArenaPlan] = \
            collections.OrderedDict()
        self._max_plans = max_plans
        self._warm: collections.OrderedDict[int, tuple[str, object]] = \
            collections.OrderedDict()          # wid -> (key, buffer)
        self._wid = itertools.count()
        self._rid = itertools.count()
        self._members: list[Lease] = []
        self._queue: collections.deque[tuple[Ticket, ArenaPlan]] = \
            collections.deque()
        self._admitted_since_poll: list[Ticket] = []
        self._rejected_since_poll: list[Ticket] = []
        self._scratch: dict[int, ScratchReservation] = {}
        self._scratch_sid = itertools.count()
        self._scratch_bytes = 0              # running sum over _scratch
        self._legacy_scratch: ScratchReservation | None = None
        self._pareto: dict[str, dict[str, ArenaPlan]] = {}
        self.stats = PoolStats()
        self.preemption_stats = PreemptionStats()

    # -- planning ----------------------------------------------------------

    def plan(self, graph: Graph, order: Sequence[int] | None = None,
             *, key: str | None = None,
             plan: ArenaPlan | None = None) -> tuple[str, ArenaPlan]:
        """Plan (or fetch) the arena for ``graph``; returns ``(key, plan)``.

        ``key`` defaults to the graph's labeled content fingerprint, so two
        byte-identical decode-state graphs share one plan.  Pass ``plan``
        to register a pre-built plan under the key (the serving driver
        hands in its regions-layout decode plan, so the pool's accounting,
        the lease buffers and the state pack/unpack all address the *same*
        offsets).
        """
        if key is None:
            key = labeled_fingerprint(graph)
        cached = self._plans.get(key)
        if cached is not None:
            self._plans.move_to_end(key)
            self.stats.plan_hits += 1
            return key, cached
        if plan is None:
            if self._planner is not None:
                plan = self._planner(graph, order)
            else:
                plan = serenity_plan(
                    graph, _LEASE_CONFIG,
                    order=graph.topo_order() if order is None else order,
                    cache=False).arena
        self._plans[key] = plan
        while len(self._plans) > self._max_plans:
            self._plans.popitem(last=False)
        return key, plan

    def register_pareto(self, key: str,
                        plans_by_class: dict[str, ArenaPlan]) -> None:
        """Register per-request-class Pareto plans under ``key``.

        ``plans_by_class`` maps class names (e.g. ``'latency'``,
        ``'memory'`` — see :func:`pareto_class_plans`) to the arena plans
        of the frontier points those classes should lease.  A later
        ``submit(..., klass=k)`` for ``key`` leases ``plans_by_class[k]``,
        cached (and warm-buffered) under the derived key ``f"{key}@{k}"``
        so differently sized class arenas never share warm buffers.
        """
        if not plans_by_class:
            raise PoolError(f"register_pareto({key!r}): no class plans")
        for klass, plan in plans_by_class.items():
            if not klass or not isinstance(klass, str):
                raise PoolError(
                    f"register_pareto({key!r}): bad class name {klass!r}")
            if not isinstance(plan, ArenaPlan):
                raise PoolError(
                    f"register_pareto({key!r}): class {klass!r} plan is "
                    f"{type(plan).__name__}, not ArenaPlan")
        self._pareto[key] = dict(plans_by_class)

    def pareto_classes(self, key: str) -> tuple[str, ...]:
        """Class names registered for ``key`` ('' when none)."""
        return tuple(self._pareto.get(key, ()))

    def warm(self, graph: Graph, order: Sequence[int] | None = None,
             *, key: str | None = None, plan: ArenaPlan | None = None) -> str:
        """Pre-plan ``graph`` and pre-allocate a warm buffer for its shape.

        Startup warming: a later ``submit`` for the same key skips both the
        planning and the allocation.  Returns the plan key.
        """
        key, plan = self.plan(graph, order, key=key, plan=plan)
        if self._alloc_fn is not None:
            _, extent = resident_bytes(plan)
            self._put_warm(key, self._alloc_fn(extent))
        return key

    # -- admission ---------------------------------------------------------

    def submit(self, graph: Graph, order: Sequence[int] | None = None,
               *, key: str | None = None,
               plan: ArenaPlan | None = None,
               klass: str | None = None,
               priority: int = 0,
               tenant: str | None = None) -> Ticket:
        """Request a lease: admit now, queue, or reject outright.

        Returns a :class:`Ticket`; ``ticket.lease`` is set immediately when
        the request fits the remaining budget and nothing is queued ahead
        of it, ``ticket.rejected`` when the plan alone can never fit (the
        global budget or the tenant's quota — ``reason_code`` says which).

        ``klass`` selects a request class previously registered for the
        key via :meth:`register_pareto` — the lease then covers that
        class's Pareto-point plan instead of the base plan.  Submitting an
        unregistered class (or a class for an unregistered key) raises
        :class:`PoolError` rather than silently downgrading the request.

        ``priority`` orders preemption, not admission: the queue stays
        FIFO, but when the degradation ladder must evict a lease it picks
        the lowest-priority one (:meth:`preempt_candidate`).  ``tenant``
        charges the lease against that tenant's byte quota when one is
        configured.
        """
        self.stats.submitted += 1
        if klass is not None:
            if plan is not None:
                raise PoolError("submit: pass either plan= or klass=, "
                                "not both", code="bad_args")
            if key is None:
                key = labeled_fingerprint(graph)
            by_class = self._pareto.get(key)
            if by_class is None:
                raise PoolError(
                    f"submit: no Pareto classes registered for key "
                    f"{key!r} (call register_pareto first)",
                    code="no_pareto_classes")
            if klass not in by_class:
                raise PoolError(
                    f"submit: unknown request class {klass!r} for key "
                    f"{key!r}; registered: {sorted(by_class)}",
                    code="unknown_class")
            plan = by_class[klass]
            key = f"{key}@{klass}"
        key, plan = self.plan(graph, order, key=key, plan=plan)
        ticket = Ticket(rid=next(self._rid), key=key, klass=klass,
                        priority=priority, tenant=tenant)
        # reject iff the request could not be admitted even into an EMPTY
        # pool — evaluated with the same accounting `_fits` uses, so a
        # queued request is always eventually admissible (no queue deadlock)
        if self._reject_never_fits(ticket, plan):
            return ticket
        self._queue.append((ticket, plan))
        self.stats.peak_queued = max(self.stats.peak_queued, len(self._queue))
        self._drain()
        return ticket

    def _reject_never_fits(self, ticket: Ticket, plan: ArenaPlan) -> bool:
        """Mark ``ticket`` rejected when ``plan`` can never be admitted —
        even into an empty pool — under the current budget/quotas."""
        alone = self._joint_extent([plan])
        if alone > self.budget_bytes:
            ticket.rejected = True
            ticket.reason_code = "budget"
            ticket.reason = (
                f"plan needs {alone} bytes alone; budget is "
                f"{self.budget_bytes}")
            self.stats.rejected += 1
            return True
        quota = self.tenant_quotas.get(ticket.tenant)
        if quota is not None and alone > quota:
            ticket.rejected = True
            ticket.reason_code = "tenant_quota"
            ticket.reason = (
                f"plan needs {alone} bytes alone; tenant "
                f"{ticket.tenant!r} quota is {quota}")
            self.stats.rejected += 1
            return True
        return False

    def release(self, lease: Lease) -> None:
        """Return a lease's bytes to the pool and drain the queue."""
        if lease._released:
            raise LeaseError(f"lease {lease.rid} ({lease.key}) already "
                             f"released (double free)", code="double_free")
        try:
            self._members.remove(lease)
        except ValueError:
            raise LeaseError(
                f"lease {lease.rid} ({lease.key}) is not held by this pool",
                code="foreign_lease") from None
        lease._released = True
        self.stats.released += 1
        if lease.buffer is not None:
            self._put_warm(lease.key, lease.buffer)
            lease.buffer = None
        self._drain()

    def poll(self) -> list[Ticket]:
        """Tickets newly admitted since the last poll, in FIFO order."""
        out = self._admitted_since_poll
        self._admitted_since_poll = []
        return out

    def poll_rejected(self) -> list[Ticket]:
        """Queued tickets rejected *after* submit (a budget-shrink sweep);
        submit-time rejections are returned on the ticket itself."""
        out = self._rejected_since_poll
        self._rejected_since_poll = []
        return out

    @property
    def pending_admissions(self) -> int:
        """Admitted tickets not yet collected by :meth:`poll`."""
        return len(self._admitted_since_poll)

    @property
    def queued_tickets(self) -> tuple[Ticket, ...]:
        """The waiting queue, head first (tickets only, FIFO order)."""
        return tuple(t for t, _ in self._queue)

    def queue_report(self) -> list[dict]:
        """Structured per-queued-request diagnostics (DESIGN.md §13):
        rid, class, priority, tenant and the current ``_fits`` failure
        reason — what the serving watchdog logs on stall escalation."""
        return [
            {"rid": t.rid, "klass": t.klass, "priority": t.priority,
             "tenant": t.tenant,
             "why": self.why_not_admitted(p, t.tenant) or "admissible"}
            for t, p in self._queue
        ]

    # -- budget + preemption (DESIGN.md §13) --------------------------------

    def set_budget(self, nbytes: int) -> int:
        """Change the global budget mid-flight; returns the overflow bytes.

        On a *grow* (or no-op) the queue simply re-drains.  On a *shrink*
        the queue is swept first: waiting tickets the new budget (or the
        tenant quota) can never fit are rejected with
        ``reason_code='budget_shrunk'`` and surface through
        :meth:`poll_rejected` — otherwise they would deadlock the FIFO
        head.  The returned overflow (``reserved - budget``, floored at 0)
        is what the caller's degradation ladder must recover by
        preemption; the pool never evicts admitted leases on its own.
        """
        nbytes = int(nbytes)
        if nbytes < 0:
            raise PoolError(f"negative budget {nbytes}", code="bad_budget",
                            requested_bytes=nbytes)
        shrink = nbytes < self.budget_bytes
        self.budget_bytes = nbytes
        if shrink:
            self.preemption_stats.budget_shrinks += 1
            keep: collections.deque = collections.deque()
            for ticket, plan in self._queue:
                alone = self._joint_extent([plan])
                quota = self.tenant_quotas.get(ticket.tenant)
                if alone > nbytes or (quota is not None and alone > quota):
                    ticket.rejected = True
                    ticket.reason_code = "budget_shrunk"
                    ticket.reason = (
                        f"budget shrank to {nbytes} bytes; queued plan "
                        f"needs {alone} alone")
                    self.stats.rejected += 1
                    self.preemption_stats.budget_evictions += 1
                    self._rejected_since_poll.append(ticket)
                else:
                    keep.append((ticket, plan))
            self._queue = keep
        over = self.reserved_bytes - nbytes
        if over <= 0:
            self._drain()
        return max(0, over)

    def preempt_candidate(self) -> Lease | None:
        """The lease preemption should evict next: lowest priority first,
        youngest (highest rid) among ties — the least-progressed work of
        the least-important class.  ``None`` when the pool holds nothing."""
        if not self._members:
            return None
        return min(self._members, key=lambda m: (m.priority, -m.rid))

    def preempt(self, lease: Lease, state: object | None = None) -> SpilledLease:
        """Evict ``lease``: spill its resident bytes to host, free its
        arena bytes, and return a :class:`SpilledLease` for later
        :meth:`readmit`.

        ``state`` is the buffer currently holding the lease's packed
        resident state (the serving loop moves buffer ownership onto the
        request after admission, so it must hand the live arena back);
        when ``None`` the lease's own ``buffer`` is spilled, and when that
        is also ``None`` (accounting-only pools) the spill carries no
        bytes, just the admission slot.  The freed bytes drain the queue
        immediately.
        """
        if lease._released:
            raise LeaseError(
                f"lease {lease.rid} ({lease.key}) already released "
                f"(double free)", code="double_free")
        try:
            self._members.remove(lease)
        except ValueError:
            raise LeaseError(
                f"lease {lease.rid} ({lease.key}) is not held by this pool",
                code="foreign_lease") from None
        lease._released = True
        src = state if state is not None else lease.buffer
        host = None
        if src is not None:
            host = np.array(np.asarray(src), dtype=np.uint8, copy=True)
        lease.buffer = None
        spill_bytes = int(host.nbytes) if host is not None \
            else lease.resident_extent
        ps = self.preemption_stats
        ps.preemptions += 1
        ps.spilled_bytes += spill_bytes
        self._drain()
        return SpilledLease(
            rid=lease.rid, key=lease.key, plan=lease.plan,
            spill_bytes=spill_bytes, host_state=host,
            klass=lease.key.rsplit("@", 1)[1] if "@" in lease.key else None,
            priority=lease.priority, tenant=lease.tenant)

    def downgrade(self, spilled: SpilledLease, klass: str) -> None:
        """Re-point a spilled lease at another registered Pareto class —
        the ladder's rung-1 move: a preempted ``latency`` request re-admits
        at its ``memory``-optimal point (same offsets layout, smaller
        admission charge)."""
        base = spilled.key.rsplit("@", 1)[0]
        by_class = self._pareto.get(base)
        if by_class is None or klass not in by_class:
            raise PoolError(
                f"downgrade: no class {klass!r} registered for {base!r}",
                code="unknown_class")
        spilled.plan = by_class[klass]
        spilled.key = f"{base}@{klass}"
        spilled.klass = klass

    def readmit(self, spilled: SpilledLease) -> Ticket:
        """One re-admission attempt for a spilled lease.

        Unlike :meth:`submit` this does **not** join the FIFO queue: a
        preempted request was admitted before anything now waiting, so it
        re-enters ahead of the queue iff its bytes fit *right now* —
        otherwise the returned ticket is neither admitted nor queued and
        the caller backs off (:meth:`SpilledLease.backoff`) and retries.
        A spill the shrunk budget/quota can never fit again is rejected
        outright (``reason_code='budget'``/``'tenant_quota'``).  The
        caller rebuilds the request's device state from
        ``spilled.host_state`` once the returned ticket admits.
        """
        ps = self.preemption_stats
        ps.readmit_attempts += 1
        ticket = Ticket(rid=next(self._rid), key=spilled.key,
                        klass=spilled.klass, priority=spilled.priority,
                        tenant=spilled.tenant)
        if self._reject_never_fits(ticket, spilled.plan):
            ps.readmit_rejections += 1
            return ticket
        if self.admission_hook is not None and self.admission_hook():
            ps.admission_faults += 1
            return ticket                       # transient: retry later
        if not self._fits(spilled.plan, spilled.tenant):
            return ticket                       # no bytes yet: retry later
        self._admit(ticket, spilled.plan)
        ps.readmitted += 1
        return ticket

    # -- accounting --------------------------------------------------------

    @property
    def leases(self) -> tuple[Lease, ...]:
        return tuple(self._members)

    @property
    def queue_len(self) -> int:
        return len(self._queue)

    @property
    def queued_bytes(self) -> int:
        """Standalone bytes the waiting queue will eventually charge — the
        load a router should count against this pool beyond
        ``reserved_bytes`` when ranking shards by projected occupancy."""
        return sum(self._joint_extent([p]) for _, p in self._queue)

    @property
    def reserved_bytes(self) -> int:
        """Joint bytes the current admitted set (plus any transient scratch
        reservation) charges to the budget."""
        return self._joint_extent([m.plan for m in self._members]) \
            + self._scratch_bytes

    @property
    def scratch_bytes(self) -> int:
        return self._scratch_bytes

    def reserve_scratch(self, nbytes: int) -> ScratchReservation:
        """Reserve transient scratch bytes; returns a release token.

        For execution-side allocations that are not leases but still occupy
        device memory alongside the admitted set — e.g. the padding rows a
        bucketed vmap decode materializes beyond the active batch, or a
        prefill chunk's workspace.  Each call is an *independent*
        reservation: the returned :class:`ScratchReservation` releases only
        its own bytes (``token.release()`` or :meth:`release_scratch`), so
        two concurrent reservers never clobber each other.  All live
        reservations are charged by ``_fits``, so queued requests cannot be
        admitted into bytes scratch is using.  Raises :class:`PoolError`
        when the new reservation does not fit over the current members plus
        existing scratch; releasing always succeeds — the degradation
        ladder depends on shedding scratch even after a budget shrink has
        left the members alone over budget.
        """
        nbytes = int(nbytes)
        if nbytes < 0:
            raise PoolError(f"negative scratch reservation {nbytes}",
                            code="bad_scratch", requested_bytes=nbytes)
        if nbytes > 0:
            joint = self._joint_extent([m.plan for m in self._members])
            held = self._scratch_bytes
            if joint + held + nbytes > self.budget_bytes:
                raise PoolError(
                    f"scratch reservation of {nbytes} bytes does not fit: "
                    f"members reserve {joint} (+{held} scratch) of "
                    f"{self.budget_bytes} budget bytes",
                    code="scratch_overflow", requested_bytes=nbytes,
                    budget_bytes=self.budget_bytes, reserved_bytes=joint + held,
                    queue_depth=len(self._queue))
        token = ScratchReservation(sid=next(self._scratch_sid),
                                   nbytes=nbytes, _pool=self)
        self._scratch[token.sid] = token
        self._scratch_bytes += nbytes
        self.stats.peak_reserved_bytes = max(self.stats.peak_reserved_bytes,
                                             self.reserved_bytes)
        return token

    def release_scratch(self, token: ScratchReservation) -> None:
        """Release one scratch reservation and drain the queue.

        Always succeeds for a live token of this pool (shedding scratch
        must work even when a budget shrink left the pool over budget).
        Raises :class:`PoolError` on a double release
        (``code='scratch_double_release'``) or a token from another pool
        (``code='foreign_scratch'``).
        """
        if token.released:
            raise PoolError(
                f"scratch reservation {token.sid} ({token.nbytes} bytes) "
                f"already released (double free)",
                code="scratch_double_release", requested_bytes=token.nbytes)
        if token._pool is not self or self._scratch.pop(token.sid, None) is None:
            raise PoolError(
                f"scratch reservation {token.sid} is not held by this pool",
                code="foreign_scratch", requested_bytes=token.nbytes)
        token.released = True
        if self._legacy_scratch is token:
            self._legacy_scratch = None
        self._scratch_bytes -= token.nbytes
        self._drain()

    def reserve_scratch_absolute(self, nbytes: int) -> None:
        """Deprecated absolute-valued scratch API (pre-token shim).

        Replaces any previous *absolute* reservation with ``nbytes`` (pass
        0 to release), exactly like the old ``reserve_scratch`` — but
        implemented as a single pool-owned token, so it composes with (and
        cannot clobber) token-based reservations held by other callers.
        Migrate to ``token = reserve_scratch(n)`` / ``token.release()``.
        """
        warnings.warn(
            "reserve_scratch_absolute is deprecated; use "
            "reserve_scratch(n) -> token and token.release()",
            DeprecationWarning, stacklevel=2)
        nbytes = int(nbytes)
        if nbytes < 0:
            raise PoolError(f"negative scratch reservation {nbytes}",
                            code="bad_scratch", requested_bytes=nbytes)
        prev = self._legacy_scratch
        prev_bytes = prev.nbytes if prev is not None else 0
        if nbytes > prev_bytes:
            joint = self._joint_extent([m.plan for m in self._members])
            others = self._scratch_bytes - prev_bytes
            if joint + others + nbytes > self.budget_bytes:
                raise PoolError(
                    f"scratch reservation of {nbytes} bytes does not fit: "
                    f"members reserve {joint} (+{others} scratch) of "
                    f"{self.budget_bytes} budget bytes",
                    code="scratch_overflow", requested_bytes=nbytes,
                    budget_bytes=self.budget_bytes,
                    reserved_bytes=joint + others,
                    queue_depth=len(self._queue))
        if prev is not None:
            del self._scratch[prev.sid]
            prev.released = True
            self._scratch_bytes -= prev_bytes
            self._legacy_scratch = None
        if nbytes > 0:
            token = ScratchReservation(sid=next(self._scratch_sid),
                                       nbytes=nbytes, _pool=self)
            self._scratch[token.sid] = token
            self._scratch_bytes += nbytes
            self._legacy_scratch = token
        self.stats.peak_reserved_bytes = max(self.stats.peak_reserved_bytes,
                                             self.reserved_bytes)
        if nbytes < prev_bytes:
            self._drain()

    def shared_plan(self) -> SharedArenaPlan:
        """Co-residency plan of the currently admitted members."""
        return plan_shared_arena([m.plan for m in self._members],
                                 serialize=self.overlap == "serial")

    def _joint_extent(self, plans: list[ArenaPlan]) -> int:
        if not plans:
            return 0
        if self.overlap == "none":
            return sum(p.arena_bytes for p in plans)
        return plan_shared_arena(plans).arena_bytes

    def tenant_usage(self, tenant: str | None) -> int:
        """Joint-alone bytes ``tenant``'s admitted leases charge its quota."""
        return sum(self._joint_extent([m.plan]) for m in self._members
                   if m.tenant == tenant)

    def _fits(self, plan: ArenaPlan, tenant: str | None = None) -> bool:
        joint = self._joint_extent([m.plan for m in self._members] + [plan])
        if joint + self._scratch_bytes > self.budget_bytes:
            return False
        quota = self.tenant_quotas.get(tenant)
        if quota is not None and \
                self.tenant_usage(tenant) + self._joint_extent([plan]) > quota:
            return False
        return True

    def why_not_admitted(self, plan: ArenaPlan,
                         tenant: str | None = None) -> str:
        """Human-readable reason :meth:`_fits` currently fails for ``plan``
        ('' when it would fit) — the per-request diagnostic the serving
        watchdog puts in its stall report (DESIGN.md §13)."""
        joint = self._joint_extent([m.plan for m in self._members] + [plan])
        if joint + self._scratch_bytes > self.budget_bytes:
            return (f"needs {joint} joint bytes"
                    + (f" (+{self._scratch_bytes} scratch)"
                       if self._scratch_bytes else "")
                    + f" over {self.budget_bytes} budget")
        quota = self.tenant_quotas.get(tenant)
        if quota is not None:
            used = self.tenant_usage(tenant)
            charge = self._joint_extent([plan])
            if used + charge > quota:
                return (f"tenant {tenant!r} at {used} of {quota} quota "
                        f"bytes; lease charges {charge}")
        return ""

    def _fits_globally(self, plan: ArenaPlan) -> bool:
        joint = self._joint_extent([m.plan for m in self._members] + [plan])
        return joint + self._scratch_bytes <= self.budget_bytes

    def _drain(self) -> None:
        # FIFO with head-of-line blocking on *bytes*: later (smaller)
        # requests never jump an earlier one still waiting for budget
        # bytes.  An entry waiting only on its own tenant's quota does NOT
        # block other tenants behind it — quota exhaustion is private to
        # the tenant, so the drain skips it and keeps scanning.
        progressed = True
        while progressed:
            progressed = False
            for i, (ticket, plan) in enumerate(self._queue):
                if not self._fits_globally(plan):
                    return                     # head-of-line on bytes
                if not self._fits(plan, ticket.tenant):
                    continue                   # tenant-quota blocked: skip
                if self.admission_hook is not None and self.admission_hook():
                    # injected transient admission failure: leave the
                    # entry queued; a later kick()/release retries
                    self.preemption_stats.admission_faults += 1
                    return
                del self._queue[i]
                self._admit(ticket, plan)
                progressed = True
                break

    def kick(self) -> None:
        """Retry queued admissions (e.g. after a transient admission fault
        suppressed a drain, or a budget grow)."""
        self._drain()

    def _admit(self, ticket: Ticket, plan: ArenaPlan) -> None:
        pbytes, extent = resident_bytes(plan)
        buffer = self._take_warm(ticket.key)
        if buffer is None and self._alloc_fn is not None:
            buffer = self._alloc_fn(extent)
        lease = Lease(
            rid=ticket.rid,
            key=ticket.key,
            plan=plan,
            arena_bytes=plan.arena_bytes,
            persistent_bytes=pbytes,
            resident_extent=extent,
            buffer=buffer,
            priority=ticket.priority,
            tenant=ticket.tenant,
        )
        self._members.append(lease)
        ticket.lease = lease
        self._admitted_since_poll.append(ticket)
        self.stats.admitted += 1
        if ticket.klass is not None:
            self.stats.admitted_by_class[ticket.klass] = \
                self.stats.admitted_by_class.get(ticket.klass, 0) + 1
        self.stats.max_concurrent = max(self.stats.max_concurrent,
                                        len(self._members))
        self.stats.peak_reserved_bytes = max(self.stats.peak_reserved_bytes,
                                             self.reserved_bytes)

    # -- warm-buffer LRU ---------------------------------------------------

    def _put_warm(self, key: str, buffer: object) -> None:
        if buffer is None:
            return
        self._warm[next(self._wid)] = (key, buffer)
        while len(self._warm) > self.max_warm:
            self._warm.popitem(last=False)
            self.stats.evictions += 1

    def _take_warm(self, key: str):
        for wid, (k, buf) in self._warm.items():
            if k == key:
                del self._warm[wid]
                self.stats.warm_hits += 1
                return buf
        return None
