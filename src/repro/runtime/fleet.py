"""Sharded async serving fleet: planner service, router, worker shards.

DESIGN.md §14.  `DecodeServer` (launch/serve.py) is one device, one
`ArenaPool`, one tick loop.  This module scales the same byte-exact
admission story out to N simulated device workers:

  * :class:`PlannerService` — the fleet's only planner.  It wraps the
    content-addressed :class:`~repro.core.plancache.PlanCache` as the
    shared tier: a graph is planned (or registered pre-built) once,
    keyed by its labeled fingerprint, together with its Pareto class
    plans; every worker fetches :class:`PlanRecord`\\ s by fingerprint and
    **never plans locally** — worker pools are constructed with a planner
    callback that raises, so any local-planning path is a hard error,
    not a silent slow path.
  * :class:`WorkerShard` — one simulated device: its own
    :class:`~repro.runtime.pool.ArenaPool` shard (``overlap='none'``:
    every member's transients are live at once under the vmap-style
    batched step, so naive-sum accounting is the honest charge), a
    per-shard tick loop with a decode lane (up to ``max_batch`` requests
    advance one token per tick) and a chunked prefill lane, plus a
    per-shard :class:`~repro.runtime.chaos.ChaosController` seam.
  * :class:`FleetRouter` — places each request by *planned bytes*:
    among the lane's shards whose budget (and tenant quota) can ever fit
    the request's class plan, pick the least-loaded by projected
    occupancy ``(reserved + queued + charge) / budget``.  A request no
    shard can ever fit is rejected at the router, with the same
    machine-readable reason codes the pool uses.
  * **prefill/decode disaggregation** — prompts at least
    ``prefill_threshold`` tokens long are placed on dedicated prefill
    shards; when prefill completes, the request's resident state is
    spilled to host (:meth:`ArenaPool.preempt`) and re-admitted on a
    decode shard (:meth:`ArenaPool.readmit`) — the *same* host-spill
    round trip preemption uses, so the handoff is bit-exact.  Without a
    prefill lane, prefill runs inline on decode shards and visibly
    stalls decode ticks (``prefill_stall_ticks``) — the cost the lane
    removes.
  * **cross-shard migration** — a lease preempted on one shard (budget
    shrink enforcement) re-enters through the fleet's spill list and may
    be re-admitted on *any* decode shard with bytes free; exponential
    backoff rides on the existing
    :class:`~repro.runtime.pool.SpilledLease` bookkeeping.  A spill that
    keeps losing the fits-now race against the shards' FIFO queues is
    *requeued* instead: re-submitted into the least-loaded shard's queue
    with its host-spilled state riding along, restored verbatim at
    admission.

The device work itself is simulated (the deterministic byte-arithmetic
decode of ``tests/test_chaos.py``'s SimServer, promoted to a fleet-wide
convention): state evolution is a pure function of ``(rid, prompt_len,
resident extent, step)``, so token streams are bit-comparable across
placements, migrations and fault scripts — which is what lets the chaos
invariants (no request lost, every shard within its instantaneous
budget, surviving tokens bit-equal the fault-free twin) be asserted at
fleet scale.  No jax anywhere: the module exercises scheduling policy,
not kernels.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time

import numpy as np

from repro.core.allocator import ArenaPlan, pin_transients, resident_bytes
from repro.core.graph import Graph
from repro.core.plancache import PlanCache, labeled_fingerprint
from repro.core.serenity import PlanConfig, plan as serenity_plan
from repro.runtime.chaos import ChaosController, TransientExecutorError
from repro.runtime.loadgen import Arrival
from repro.runtime.pool import ArenaPool, PoolError, SpilledLease, Ticket

# Fleet plans pack the graph's deterministic topo order as-is (arena
# offsets only) — same convention as the pool's default lease planner.
_PLANNER_CONFIG = PlanConfig(rewrite=False, inplace=False,
                             compute_baselines=False)
# Options tuple keying planner payloads in the shared PlanCache tier.
_CACHE_OPTS = ("fleet.planner", 1)


class FleetStallError(RuntimeError):
    """The fleet stopped making progress (tick guard exceeded); carries a
    structured per-shard report like ServingStallError does."""

    def __init__(self, message: str, report: dict | None = None):
        super().__init__(message)
        self.report = report or {}


def _no_local_planning(graph, order):
    raise PoolError(
        "fleet workers never plan locally — plans come from the "
        "PlannerService by fingerprint", code="no_local_planning")


# ---------------------------------------------------------------------------
# Planner service
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlanRecord:
    """One planned graph, as the fleet shares it: fingerprint key, base
    plan, Pareto class plans, and the byte numbers routing needs."""

    key: str
    graph: Graph
    plan: ArenaPlan
    classes: dict[str, ArenaPlan]
    alone_bytes: int             # standalone extent: the routing charge
    persistent_bytes: int
    resident_extent: int

    def plan_for(self, klass: str | None) -> ArenaPlan:
        if klass is None:
            return self.plan
        try:
            return self.classes[klass]
        except KeyError:
            raise PoolError(
                f"record {self.key!r} has no class {klass!r}; registered: "
                f"{sorted(self.classes)}", code="unknown_class") from None

    def charge_bytes(self, klass: str | None) -> int:
        """Bytes the router charges a shard for this record's class plan
        (standalone extent — the ``overlap='none'`` admission charge)."""
        return self.plan_for(klass).arena_bytes


@dataclasses.dataclass
class PlannerStats:
    requests: int = 0            # record lookups served to workers
    record_hits: int = 0         # served from the in-process record map
    shared_hits: int = 0         # rebuilt from the shared PlanCache tier
    planned: int = 0             # actually planned by this service
    registered: int = 0          # pre-built plans handed in

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlannerService:
    """The fleet's single planning authority over a shared `PlanCache`.

    Workers hold fingerprints, not graphs: they call :meth:`record` and
    get back a :class:`PlanRecord` (or a hard KeyError — there is no
    plan-it-yourself fallback).  :meth:`plan_graph` is the ingest side:
    it consults the content-addressed cache first (two services sharing
    one `PlanCache` — or one service across restarts with a disk tier —
    plan each graph exactly once fleet-wide), and plans only on a full
    miss.  :meth:`register` ingests a pre-built plan (the serve driver
    hands in its regions-layout decode plans, so fleet accounting and
    state packing address the same offsets).
    """

    def __init__(self, cache: PlanCache | None = None):
        self.cache = cache if cache is not None else PlanCache()
        self._records: dict[str, PlanRecord] = {}
        self.stats = PlannerStats()

    def _make_record(self, key: str, graph: Graph, plan: ArenaPlan,
                     classes: dict[str, ArenaPlan]) -> PlanRecord:
        pbytes, extent = resident_bytes(plan)
        rec = PlanRecord(key=key, graph=graph, plan=plan,
                         classes=dict(classes),
                         alone_bytes=plan.arena_bytes,
                         persistent_bytes=pbytes, resident_extent=extent)
        self._records[key] = rec
        return rec

    def register(self, graph: Graph, *, plan: ArenaPlan,
                 classes: dict[str, ArenaPlan] | None = None,
                 key: str | None = None) -> PlanRecord:
        """Ingest a pre-built plan (+ optional class plans) under the
        graph's fingerprint; the shared cache tier gets a copy."""
        if key is None:
            key = labeled_fingerprint(graph)
        self.stats.registered += 1
        classes = dict(classes or {})
        self.cache.put(graph, _CACHE_OPTS,
                       {"plan": plan, "classes": classes})
        return self._make_record(key, graph, plan, classes)

    def plan_graph(self, graph: Graph, *, key: str | None = None,
                   with_classes: bool = True) -> PlanRecord:
        """Plan ``graph`` (shared-cache-first) and return its record.

        ``with_classes`` also derives the two canonical Pareto class
        plans: ``'memory'`` = the base min-footprint plan, ``'latency'``
        = the same layout with transients pinned
        (:func:`~repro.core.allocator.pin_transients`).
        """
        if key is None:
            key = labeled_fingerprint(graph)
        self.stats.requests += 1
        rec = self._records.get(key)
        if rec is not None:
            self.stats.record_hits += 1
            return rec
        payload = self.cache.get(graph, _CACHE_OPTS)
        if payload is not None:
            self.stats.shared_hits += 1
            return self._make_record(key, graph, payload["plan"],
                                     payload["classes"])
        plan = serenity_plan(graph, _PLANNER_CONFIG,
                             order=graph.topo_order(), cache=False).arena
        classes = {"memory": plan, "latency": pin_transients(plan)} \
            if with_classes else {}
        self.stats.planned += 1
        self.cache.put(graph, _CACHE_OPTS, {"plan": plan, "classes": classes})
        return self._make_record(key, graph, plan, classes)

    def record(self, key: str) -> PlanRecord:
        """The record for ``key`` — the only call workers make.  Raises
        ``KeyError`` for an unknown fingerprint: a worker holding a key
        the planner never saw is a routing bug, not a planning request."""
        self.stats.requests += 1
        try:
            rec = self._records[key]
        except KeyError:
            raise KeyError(
                f"planner has no record for fingerprint {key!r}; workers "
                f"never plan locally — register/plan_graph it first"
            ) from None
        self.stats.record_hits += 1
        return rec

    def keys(self) -> tuple[str, ...]:
        return tuple(self._records)


# ---------------------------------------------------------------------------
# Requests and the simulated device step
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetRequest:
    """One request's life across the fleet (identity + audit trail)."""

    rid: int
    key: str                     # PlanRecord fingerprint
    prompt_len: int
    gen_len: int
    klass: str | None = None
    priority: int = 0
    tenant: str | None = None
    arrival_tick: int = 0
    # -- outcome ------------------------------------------------------------
    tokens: list = dataclasses.field(default_factory=list)
    rejected: bool = False
    reject_code: str = ""
    reject_reason: str = ""
    submit_tick: int = -1
    admit_tick: int = -1
    done_tick: int = -1
    shards: list = dataclasses.field(default_factory=list)  # placement trail
    preemptions: int = 0
    migrations: int = 0          # re-admissions on a *different* shard
    # -- live state (device-side, simulated) --------------------------------
    lease: object = dataclasses.field(default=None, repr=False)
    spill: SpilledLease | None = dataclasses.field(default=None, repr=False)
    state: np.ndarray | None = dataclasses.field(default=None, repr=False)
    prefilled: int = 0           # prompt tokens prefilled so far

    @classmethod
    def from_arrival(cls, a: Arrival, key: str) -> "FleetRequest":
        return cls(rid=a.rid, key=key, prompt_len=a.prompt_len,
                   gen_len=a.gen_len, klass=a.klass, priority=a.priority,
                   tenant=a.tenant, arrival_tick=a.tick)

    @property
    def done(self) -> bool:
        return self.done_tick >= 0

    @property
    def latency_ticks(self) -> int:
        return self.done_tick - self.arrival_tick


def _prefill_state(rid: int, prompt_len: int, extent: int) -> np.ndarray:
    """Deterministic post-prefill resident state: a pure function of the
    request identity, prompt length and plan extent — independent of
    *where* (which shard, which lane) the prefill ran, which is what
    makes prefill-handoff and migration bit-exactness testable."""
    idx = np.arange(extent, dtype=np.int64)
    return ((idx * (rid % 251 + 3) + prompt_len) % 251).astype(np.uint8)


def _advance_state(state: np.ndarray, rid: int, step: int) -> np.ndarray:
    """One simulated decode step (same arithmetic as the chaos SimServer)."""
    return ((state.astype(np.int64) * 33 + rid + step) % 256).astype(np.uint8)


def _emit_token(state: np.ndarray, step: int) -> int:
    return int(state[: min(64, state.size)].sum()) + step


# ---------------------------------------------------------------------------
# Worker shard
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardStats:
    submitted: int = 0
    admitted: int = 0
    served: int = 0
    decode_ticks: int = 0
    prefill_ticks: int = 0
    idle_ticks: int = 0
    prefill_stall_ticks: int = 0   # decode work displaced by inline prefill
    tokens: int = 0                # decode tokens emitted
    prefill_tokens: int = 0        # prompt tokens prefilled
    handoffs_out: int = 0          # prefill-complete spills handed to fleet
    migrations_in: int = 0         # spills re-admitted from another shard
    transient_errors: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class WorkerShard:
    """One simulated device worker: an `ArenaPool` shard + tick loop.

    ``role='decode'`` shards run the decode lane (≤ ``max_batch``
    requests advance one token per tick; latency-class requests are
    served first when the batch is oversubscribed) and prefill inline on
    alternating ticks when no prefill lane exists.  ``role='prefill'``
    shards only prefill (``prefill_chunk`` prompt tokens per request per
    tick) and hand completed state to the fleet as a host spill for
    decode-shard re-admission.
    """

    def __init__(self, sid: int, budget_bytes: int, *, role: str = "decode",
                 max_batch: int = 8, prefill_chunk: int = 32,
                 tenant_quotas: dict[str, int] | None = None,
                 chaos: ChaosController | None = None):
        if role not in ("decode", "prefill"):
            raise ValueError(f"unknown shard role {role!r}")
        self.sid = sid
        self.role = role
        self.max_batch = int(max_batch)
        self.prefill_chunk = int(prefill_chunk)
        self.chaos = chaos
        self.pool = ArenaPool(
            budget_bytes, overlap="none", planner=_no_local_planning,
            tenant_quotas=tenant_quotas,
            admission_hook=chaos.admission_should_fail if chaos else None)
        self._known: set[str] = set()
        self.tickets: dict[int, FleetRequest] = {}   # pool rid -> request
        self.active: list[FleetRequest] = []
        self.stats = ShardStats()
        self.max_over_budget = 0   # worst observed reserved - budget (<=0 ok)

    # -- placement-side API -------------------------------------------------

    def ensure(self, record: PlanRecord) -> None:
        """Install ``record``'s plans in this shard's pool (idempotent)."""
        if record.key in self._known:
            return
        self.pool.plan(record.graph, key=record.key, plan=record.plan)
        if record.classes:
            self.pool.register_pareto(record.key, record.classes)
        self._known.add(record.key)

    def load_fraction(self, extra_bytes: int = 0) -> float:
        """Projected occupancy: admitted + queued (+ a candidate charge)
        over this shard's budget — the router's ranking key."""
        budget = max(1, self.pool.budget_bytes)
        return (self.pool.reserved_bytes + self.pool.queued_bytes
                + extra_bytes) / budget

    def can_ever_fit(self, charge: int, tenant: str | None) -> bool:
        if charge > self.pool.budget_bytes:
            return False
        quota = self.pool.tenant_quotas.get(tenant)
        return quota is None or charge <= quota

    def fits_now(self, plan: ArenaPlan, tenant: str | None) -> bool:
        return self.pool.why_not_admitted(plan, tenant) == ""

    def submit(self, req: FleetRequest, record: PlanRecord) -> Ticket:
        self.ensure(record)
        self.stats.submitted += 1
        ticket = self.pool.submit(record.graph, key=record.key,
                                  klass=req.klass, priority=req.priority,
                                  tenant=req.tenant)
        if not ticket.rejected:
            self.tickets[ticket.rid] = req
        return ticket

    def readmit(self, req: FleetRequest) -> Ticket:
        """One re-admission attempt for a spilled request (queue-bypass)."""
        ticket = self.pool.readmit(req.spill)
        if ticket.admitted:
            self.tickets[ticket.rid] = req
        return ticket

    @property
    def busy(self) -> bool:
        return bool(self.active or self.tickets or self.pool.queue_len
                    or self.pool.pending_admissions)

    # -- the tick loop ------------------------------------------------------

    def tick(self, now: int, fleet: "Fleet") -> None:
        if self.chaos is not None:
            for spec in self.chaos.begin_tick(now):
                if spec.kind == "budget_shrink":
                    self.set_budget(
                        int(self.pool.budget_bytes * spec.factor),
                        fleet, now)
        self.pool.kick()
        self._collect(now, fleet)
        prefill = [r for r in self.active if r.prefilled < r.prompt_len]
        decode = [r for r in self.active if r.prefilled >= r.prompt_len]
        try:
            # the injected transient fires *before* any state is touched,
            # so a skipped tick is safely retryable (bit-equality holds)
            if self.chaos is not None:
                self.chaos.maybe_executor_error()
            if self.role == "prefill":
                if prefill:
                    self._prefill_tick(now, fleet, prefill)
                else:
                    self.stats.idle_ticks += 1
            elif prefill and (not decode or now % 2 == 0):
                # inline prefill: no dedicated lane, so prefilling consumes
                # the device tick and the decode batch waits — the stall
                # disaggregation exists to remove
                if decode:
                    self.stats.prefill_stall_ticks += 1
                self._prefill_tick(now, fleet, prefill)
            elif decode:
                self._decode_tick(now, fleet, decode)
            else:
                self.stats.idle_ticks += 1
        except TransientExecutorError:
            self.stats.transient_errors += 1
        over = self.pool.reserved_bytes - self.pool.budget_bytes
        self.max_over_budget = max(self.max_over_budget, over)

    def _collect(self, now: int, fleet: "Fleet") -> None:
        for ticket in self.pool.poll():
            req = self.tickets.pop(ticket.rid, None)
            if req is None:
                continue     # preempted by a budget shrink before collection
            req.lease = ticket.lease
            if req.admit_tick < 0:
                req.admit_tick = now
            if req.spill is not None:
                # spill round trip completes: restore device state verbatim
                if req.spill.host_state is not None:
                    req.state = np.array(req.spill.host_state,
                                         dtype=np.uint8, copy=True)
                if req.shards and req.shards[-1] != self.sid:
                    req.migrations += 1
                    self.stats.migrations_in += 1
                    # classify the crossing once, at restore time, so both
                    # re-admitted and queue-migrated spills are counted
                    if fleet.shard_by_sid(req.shards[-1]).role == "prefill":
                        fleet.stats.handoffs += 1
                    else:
                        fleet.stats.migrations += 1
                req.spill = None
            if not req.shards or req.shards[-1] != self.sid:
                req.shards.append(self.sid)
            self.active.append(req)
            self.stats.admitted += 1
        for ticket in self.pool.poll_rejected():
            # a budget-shrink sweep evicted a queued ticket: the fleet may
            # still place it on another shard
            req = self.tickets.pop(ticket.rid, None)
            if req is not None:
                fleet.reroute_or_reject(req, ticket, now)

    def _prefill_tick(self, now: int, fleet: "Fleet",
                      jobs: list[FleetRequest]) -> None:
        self.stats.prefill_ticks += 1
        for req in jobs[: self.max_batch]:
            step = min(self.prefill_chunk, req.prompt_len - req.prefilled)
            req.prefilled += step
            self.stats.prefill_tokens += step
            if req.prefilled >= req.prompt_len:
                req.state = _prefill_state(req.rid, req.prompt_len,
                                           req.lease.resident_extent)
                if self.role == "prefill":
                    # disaggregation handoff: spill the fresh state to host
                    # and let the fleet re-admit it on a decode shard —
                    # the same round trip preemption uses
                    self._spill_out(req, now, fleet, handoff=True)

    def _decode_tick(self, now: int, fleet: "Fleet",
                     jobs: list[FleetRequest]) -> None:
        self.stats.decode_ticks += 1
        # latency-class requests get batch slots first; then higher
        # priority, then oldest
        jobs = sorted(jobs, key=lambda r: (r.klass != "latency",
                                           -r.priority, r.rid))
        for req in jobs[: self.max_batch]:
            step = req.prompt_len + len(req.tokens)
            req.state = _advance_state(req.state, req.rid, step)
            req.tokens.append(_emit_token(req.state, len(req.tokens)))
            self.stats.tokens += 1
            if len(req.tokens) >= req.gen_len:
                self.pool.release(req.lease)
                req.lease = None
                req.state = None
                req.done_tick = now
                self.active.remove(req)
                self.stats.served += 1
                fleet.retire(req)

    def _spill_out(self, req: FleetRequest, now: int, fleet: "Fleet",
                   handoff: bool = False) -> None:
        spill = self.pool.preempt(req.lease, state=req.state)
        req.lease = None
        req.state = None
        req.spill = spill
        self.active.remove(req)
        if handoff:
            self.stats.handoffs_out += 1
            spill.next_tick = now + 1      # due immediately, no backoff
        else:
            req.preemptions += 1
        fleet.add_spilled(req)

    def set_budget(self, nbytes: int, fleet: "Fleet", now: int) -> None:
        """Shrink/grow this shard's budget and enforce it: over-budget
        bytes are recovered by preempting lowest-priority members, whose
        spills the fleet re-places (possibly on other shards)."""
        over = self.pool.set_budget(nbytes)
        while over > 0:
            victim = self.pool.preempt_candidate()
            if victim is None:
                break
            req = next((r for r in self.active if r.lease is victim), None)
            if req is not None:
                self._spill_out(req, now, fleet)
            else:
                # admitted this very tick, not yet collected: the uncounted
                # ticket still maps the lease rid to its request
                req = self.tickets.pop(victim.rid, None)
                if req is None:      # orphan member (should not happen)
                    self.pool.preempt(victim)
                else:
                    # a requeued spill may be admitted but uncollected: its
                    # device state still lives on the *old* spill record —
                    # carry it over, never clobber it with None
                    state = req.state
                    if state is None and req.spill is not None:
                        state = req.spill.host_state
                    spill = self.pool.preempt(victim, state=state)
                    req.lease = None
                    req.state = None
                    req.spill = spill
                    req.preemptions += 1
                    fleet.add_spilled(req)
            over = self.pool.reserved_bytes - self.pool.budget_bytes

    def report(self) -> dict:
        return {
            "sid": self.sid, "role": self.role,
            "budget_bytes": self.pool.budget_bytes,
            "reserved_bytes": self.pool.reserved_bytes,
            "queue_len": self.pool.queue_len,
            "active": len(self.active),
            "max_over_budget": self.max_over_budget,
            **self.stats.as_dict(),
        }


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


class FleetRouter:
    """Byte-aware placement over the fleet's shards.

    Placement rule (DESIGN.md §14): a request is charged its class
    plan's standalone extent.  Among the lane's shards whose budget and
    tenant quota could *ever* fit that charge, pick the lowest projected
    occupancy ``(reserved + queued + charge) / budget`` (ties to the
    lowest shard id — deterministic).  No candidate → reject with
    ``'budget'`` / ``'tenant_quota'``.  The router never places a charge
    above a shard's budget, so a shard can only exceed its budget if its
    *own* pool accounting does — which the per-shard
    ``max_over_budget`` watermark (and the chaos invariant) would catch.
    """

    def __init__(self, shards: list[WorkerShard]):
        self.shards = list(shards)
        self.decode_shards = [s for s in shards if s.role == "decode"]
        self.prefill_shards = [s for s in shards if s.role == "prefill"]
        self.placements = 0
        self.rejections = 0

    def place(self, req: FleetRequest, record: PlanRecord,
              lane: list[WorkerShard]) -> tuple[WorkerShard | None, str, str]:
        """Pick a shard for a fresh request; ``(None, code, reason)`` when
        no shard in the lane can ever fit it."""
        charge = record.charge_bytes(req.klass)
        fit = [s for s in lane if s.can_ever_fit(charge, req.tenant)]
        if not fit:
            self.rejections += 1
            if any(charge <= s.pool.budget_bytes for s in lane):
                return None, "tenant_quota", (
                    f"plan needs {charge} bytes alone; no shard quota for "
                    f"tenant {req.tenant!r} admits it")
            budgets = [s.pool.budget_bytes for s in lane] or [0]
            return None, "budget", (
                f"plan needs {charge} bytes alone; largest shard budget "
                f"is {max(budgets)}")
        best = min(fit, key=lambda s: (s.load_fraction(charge), s.sid))
        self.placements += 1
        return best, "", ""

    def place_spilled(self, req: FleetRequest,
                      lane: list[WorkerShard] | None = None) \
            -> WorkerShard | None:
        """A lane shard that can admit the spilled plan *right now*
        (spills bypass queues, so fits-now is the bar), least-loaded
        first; ``None`` when no shard currently has the bytes."""
        plan = req.spill.plan
        fit = [s for s in (self.decode_shards if lane is None else lane)
               if s.fits_now(plan, req.tenant)]
        if not fit:
            return None
        return min(fit, key=lambda s: (s.load_fraction(plan.arena_bytes),
                                       s.sid))

    def can_ever_fit_anywhere(self, charge: int, tenant: str | None,
                              lane: list[WorkerShard] | None = None) -> bool:
        lane = self.decode_shards if lane is None else lane
        return any(s.can_ever_fit(charge, tenant) for s in lane)


# ---------------------------------------------------------------------------
# The fleet
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetStats:
    submitted: int = 0
    served: int = 0
    rejected: int = 0
    migrations: int = 0          # cross-shard re-admissions (non-handoff)
    handoffs: int = 0            # prefill-lane -> decode-shard handoffs
    spill_retries: int = 0
    requeues: int = 0            # spills migrated via a shard queue

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Fleet:
    """N worker shards + router + planner, driven by one global tick.

    One tick = one simulated device step on every shard in parallel (the
    shards are independent devices; python just iterates them).  The
    run loop is open-loop: requests are submitted at their arrival tick
    regardless of fleet state, spilled leases are retried with
    exponential backoff (bounded by ``max_readmit_attempts``), and the
    loop ends when every request is served or rejected.

    Args:
      planner: the :class:`PlannerService` all shards share.
      key_for: maps an :class:`~repro.runtime.loadgen.Arrival` to the
        planner fingerprint of the record it should lease (e.g. a
        sequence-bucket mapping); only needed when driving with raw
        arrivals via :meth:`run_arrivals`.
      n_decode / n_prefill: lane sizes; ``n_prefill=0`` disables
        disaggregation (prefill runs inline on decode shards).
      shard_budget_bytes / prefill_budget_bytes: per-shard byte budgets.
      prefill_threshold: prompts at least this long go to the prefill
        lane (default ``2 * prefill_chunk``; ignored without one).
      fault_plans: optional ``{sid: FaultPlan}`` — each listed shard gets
        its own :class:`ChaosController` seam.
    """

    def __init__(self, planner: PlannerService, *,
                 key_for=None,
                 n_decode: int = 4, n_prefill: int = 0,
                 shard_budget_bytes: int, prefill_budget_bytes: int | None = None,
                 max_batch: int = 8, prefill_chunk: int = 32,
                 prefill_threshold: int | None = None,
                 tenant_quotas: dict[str, int] | None = None,
                 max_readmit_attempts: int = 6,
                 fault_plans: dict | None = None):
        if n_decode < 1:
            raise ValueError("a fleet needs at least one decode shard")
        self.planner = planner
        self.key_for = key_for
        self.prefill_chunk = int(prefill_chunk)
        self.prefill_threshold = (2 * self.prefill_chunk
                                  if prefill_threshold is None
                                  else int(prefill_threshold))
        self.max_readmit_attempts = int(max_readmit_attempts)
        fault_plans = fault_plans or {}
        self.shards: list[WorkerShard] = []
        for i in range(n_decode):
            self.shards.append(WorkerShard(
                i, shard_budget_bytes, role="decode", max_batch=max_batch,
                prefill_chunk=prefill_chunk, tenant_quotas=tenant_quotas,
                chaos=(ChaosController(fault_plans[i])
                       if i in fault_plans else None)))
        for j in range(n_prefill):
            sid = n_decode + j
            self.shards.append(WorkerShard(
                sid,
                prefill_budget_bytes if prefill_budget_bytes is not None
                else shard_budget_bytes,
                role="prefill", max_batch=max_batch,
                prefill_chunk=prefill_chunk, tenant_quotas=tenant_quotas,
                chaos=(ChaosController(fault_plans[sid])
                       if sid in fault_plans else None)))
        self.router = FleetRouter(self.shards)
        self._spilled: list[FleetRequest] = []
        self.done: list[FleetRequest] = []
        self.rejected: list[FleetRequest] = []
        self.stats = FleetStats()
        self.ticks = 0

    # -- request lifecycle --------------------------------------------------

    def _lane_for(self, req: FleetRequest) -> list[WorkerShard]:
        """Prefill lane iff one exists, the prompt clears the threshold,
        and the request still has prompt tokens left to prefill."""
        if (self.router.prefill_shards
                and req.prefilled < req.prompt_len
                and req.prompt_len >= self.prefill_threshold):
            return self.router.prefill_shards
        return self.router.decode_shards

    def submit(self, req: FleetRequest, now: int) -> None:
        self.stats.submitted += 1
        req.submit_tick = now
        record = self.planner.record(req.key)
        shard, code, reason = self.router.place(req, record,
                                                self._lane_for(req))
        if shard is None:
            self._reject(req, code, reason)
            return
        ticket = shard.submit(req, record)
        if ticket.rejected:
            # the pool's own never-fits check disagrees only when budgets
            # moved between ranking and submit (chaos) — honor it
            self._reject(req, ticket.reason_code, ticket.reason)

    def reroute_or_reject(self, req: FleetRequest, ticket: Ticket,
                          now: int) -> None:
        """A queued ticket was swept by a shard budget shrink; try the
        other shards before giving up."""
        record = self.planner.record(req.key)
        shard, code, reason = self.router.place(req, record,
                                                self._lane_for(req))
        if shard is None:
            self._reject(req, ticket.reason_code or code,
                         ticket.reason or reason)
            return
        t = shard.submit(req, record)
        if t.rejected:
            self._reject(req, t.reason_code, t.reason)

    def add_spilled(self, req: FleetRequest) -> None:
        self._spilled.append(req)

    def retire(self, req: FleetRequest) -> None:
        self.done.append(req)
        self.stats.served += 1

    def _reject(self, req: FleetRequest, code: str, reason: str) -> None:
        req.rejected = True
        req.reject_code = code or "rejected"
        req.reject_reason = reason
        req.lease = None
        req.spill = None
        req.state = None
        self.rejected.append(req)
        self.stats.rejected += 1

    def _retry_spilled(self, now: int) -> None:
        still: list[FleetRequest] = []
        for req in self._spilled:
            spill = req.spill
            if not spill.due(now):
                still.append(req)
                continue
            self.stats.spill_retries += 1
            lane = self._lane_for(req)
            shard = self.router.place_spilled(req, lane)
            if shard is None:
                charge = spill.plan.arena_bytes
                if not self.router.can_ever_fit_anywhere(charge, req.tenant,
                                                         lane):
                    self._reject(req, "budget", (
                        f"spilled plan needs {charge} bytes alone; no "
                        f"decode shard budget admits it"))
                elif spill.attempts >= 1:
                    # fits-now keeps losing the race against the shards'
                    # FIFO queues (every freed byte is claimed by a queued
                    # arrival before the backed-off retry fires).  Migrate
                    # instead: re-submit into the least-loaded shard's
                    # queue — the host-spilled state rides along on the
                    # request and is restored verbatim at admission, so
                    # this is the same round trip, minus the livelock.
                    self._requeue_spilled(req, lane)
                else:
                    spill.backoff(now)
                    still.append(req)
                continue
            ticket = shard.readmit(req)
            if ticket.admitted:
                pass     # the crossing is classified at collection time
            elif ticket.rejected:
                self._reject(req, ticket.reason_code, ticket.reason)
            elif spill.attempts >= self.max_readmit_attempts:
                self._reject(req, "readmit_exhausted", (
                    f"re-admission failed {spill.attempts} times "
                    f"(max {self.max_readmit_attempts})"))
            else:
                spill.backoff(now)
                still.append(req)
        self._spilled = still

    def _requeue_spilled(self, req: FleetRequest,
                         lane: list[WorkerShard]) -> None:
        """Migrate a spill that can't fit *now* anywhere by queueing it on
        the least-loaded shard that can *ever* fit it."""
        record = self.planner.record(req.key)
        shard, code, reason = self.router.place(req, record, lane)
        if shard is None:        # budgets moved since the can-ever check
            self._reject(req, code, reason)
            return
        ticket = shard.submit(req, record)
        if ticket.rejected:
            self._reject(req, ticket.reason_code, ticket.reason)
        else:
            self.stats.requeues += 1

    def shard_by_sid(self, sid: int) -> WorkerShard:
        return self.shards[sid]

    # -- the drive loop -----------------------------------------------------

    def run(self, requests: list[FleetRequest], *,
            max_ticks: int | None = None) -> dict:
        """Drive the open-loop tick clock until every request resolves."""
        pending = collections.deque(sorted(
            requests, key=lambda r: (r.arrival_tick, r.rid)))
        if max_ticks is None:
            horizon = max((r.arrival_tick for r in requests), default=0)
            work = sum(r.gen_len + r.prompt_len // self.prefill_chunk + 2
                       for r in requests)
            max_ticks = horizon + 1000 + 4 * work // max(
                1, len(self.router.decode_shards))
        wall0 = time.perf_counter()
        now = 0
        while pending or self._spilled or any(s.busy for s in self.shards):
            now += 1
            if now > max_ticks:
                raise FleetStallError(
                    f"fleet made no full drain within {max_ticks} ticks "
                    f"({len(pending)} pending, {len(self._spilled)} "
                    f"spilled)", report=self.describe())
            while pending and pending[0].arrival_tick <= now:
                self.submit(pending.popleft(), now)
            self._retry_spilled(now)
            for shard in self.shards:
                shard.tick(now, self)
        self.ticks = now
        return self.metrics(wall_s=time.perf_counter() - wall0)

    def run_arrivals(self, arrivals: list[Arrival], **kwargs) -> dict:
        if self.key_for is None:
            raise ValueError("run_arrivals needs key_for= at construction")
        reqs = [FleetRequest.from_arrival(a, key=self.key_for(a))
                for a in arrivals]
        return self.run(reqs, **kwargs)

    # -- reporting ----------------------------------------------------------

    def metrics(self, wall_s: float | None = None) -> dict:
        served = self.done
        n = self.stats.submitted
        lat = sorted(r.latency_ticks for r in served)
        if lat:
            p50 = float(np.percentile(lat, 50))
            p99 = float(np.percentile(lat, 99))
        else:
            # an all-rejected fleet has no latency to report — NaN, never
            # a vacuous 0.0 (the DecodeServer fix, same convention)
            p50 = p99 = float("nan")
        tokens = sum(s.stats.tokens for s in self.shards)
        ticks = max(1, self.ticks)
        out = {
            "n_requests": n,
            "n_served": len(served),
            "n_rejected": len(self.rejected),
            "n_lost": n - len(served) - len(self.rejected),
            "rejection_rate": round(len(self.rejected) / n, 4) if n else 0.0,
            "ticks": self.ticks,
            "p50_ticks": round(p50, 1) if math.isfinite(p50) else p50,
            "p99_ticks": round(p99, 1) if math.isfinite(p99) else p99,
            "tokens": tokens,
            "tok_per_tick": round(tokens / ticks, 3),
            "migrations": self.stats.migrations,
            "handoffs": self.stats.handoffs,
            "requeues": self.stats.requeues,
            "preemptions": sum(
                s.pool.preemption_stats.preemptions for s in self.shards),
            "max_over_budget": max(s.max_over_budget for s in self.shards),
            "prefill_stall_ticks": sum(
                s.stats.prefill_stall_ticks for s in self.shards),
            "planner": self.planner.stats.as_dict(),
        }
        if wall_s is not None:
            out["wall_s"] = round(wall_s, 3)
        return out

    def describe(self) -> dict:
        """Structured stall/debug report: fleet counters + per-shard state
        (incl. each pool's queue diagnostics)."""
        return {
            "fleet": self.stats.as_dict(),
            "spilled": [
                {"rid": r.rid, "attempts": r.spill.attempts,
                 "next_tick": r.spill.next_tick}
                for r in self._spilled
            ],
            "shards": [
                {**s.report(), "queue": s.pool.queue_report()}
                for s in self.shards
            ],
        }


# ---------------------------------------------------------------------------
# Synthetic fleet workloads (benchmarks + tests)
# ---------------------------------------------------------------------------


def sim_state_graph(smax: int, *, n_cache: int = 3, bytes_per_pos: int = 8,
                    transient_bytes: int | None = None) -> Graph:
    """A decode-state stand-in sized for ``smax`` sequence positions:
    ``n_cache`` persistent cache buffers of ``smax * bytes_per_pos`` bytes
    plus a short transient activation chain — the same shape the chaos
    suite's SimServer uses, parameterized so sequence buckets map to
    genuinely different plans (and byte charges)."""
    cache_bytes = smax * bytes_per_pos
    if transient_bytes is None:
        transient_bytes = max(64, cache_bytes // 2)
    specs = [dict(name=f"s{i}", op="cache", size_bytes=cache_bytes, preds=[])
             for i in range(n_cache)]
    specs.append(dict(name="h", op="act", size_bytes=transient_bytes // 2,
                      preds=[]))
    specs.append(dict(name="l", op="act", size_bytes=transient_bytes,
                      preds=[len(specs) - 1]))
    specs.append(dict(name="tok", op="act", size_bytes=4,
                      preds=[len(specs) - 1]))
    return Graph.build(specs, name=f"simstate{smax}")


def bucketed_records(planner: PlannerService, buckets: tuple[int, ...],
                     graph_for=sim_state_graph) -> dict[int, PlanRecord]:
    """Plan one record per sequence bucket through ``planner``; returns
    ``{bucket: record}``.  Buckets must be sorted ascending."""
    if tuple(sorted(buckets)) != tuple(buckets):
        raise ValueError(f"buckets must be ascending, got {buckets}")
    return {b: planner.plan_graph(graph_for(b)) for b in buckets}


def bucket_key_for(records: dict[int, PlanRecord]):
    """``key_for`` closure for :class:`Fleet`: an arrival leases the
    smallest bucket record covering ``prompt + gen``; oversize arrivals
    get the largest bucket's record (whose plan then typically exceeds
    every shard budget — a *real* router rejection, not a special case)."""
    buckets = sorted(records)

    def key_for(a: Arrival) -> str:
        for b in buckets:
            if a.smax <= b:
                return records[b].key
        return records[buckets[-1]].key

    return key_for
