"""Fault-tolerant training-loop runtime.

``FaultTolerantLoop`` wraps a jitted step with:

  * periodic (async) checkpointing + restore-on-start,
  * bounded retry on transient failures (preemption-style XlaRuntimeError:
    re-init from the last checkpoint and continue),
  * straggler detection: an EMA of step time flags steps slower than
    ``straggler_factor``x the moving median — on multi-host deployments this
    feeds the controller that triggers slice-swap; here it logs and counts
    (the hook is the deliverable; there is one process in this container),
  * clean shutdown on SIGTERM (checkpoint before exit — preemption notice).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class StepTimer:
    """EMA/median step timing + straggler flagging."""
    straggler_factor: float = 2.5
    window: int = 32

    def __post_init__(self):
        self.history: list[float] = []
        self.stragglers = 0

    def observe(self, dt: float) -> bool:
        self.history.append(dt)
        if len(self.history) > self.window:
            self.history.pop(0)
        med = sorted(self.history)[len(self.history) // 2]
        is_straggler = len(self.history) >= 8 and dt > self.straggler_factor * med
        if is_straggler:
            self.stragglers += 1
            log.warning("straggler step: %.3fs vs median %.3fs", dt, med)
        return is_straggler


class FaultTolerantLoop:
    def __init__(
        self,
        step_fn: Callable,                  # (state, batch) -> (state, metrics)
        ckpt_manager,
        batch_iter_factory: Callable[[int], Any],   # start_step -> iterator
        ckpt_every: int = 100,
        max_retries: int = 3,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.batch_iter_factory = batch_iter_factory
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.timer = StepTimer()
        self._stop = False
        try:
            signal.signal(signal.SIGTERM, self._on_sigterm)
        except ValueError:
            pass  # not on main thread (tests)

    def _on_sigterm(self, *_):
        log.warning("SIGTERM: checkpointing before exit")
        self._stop = True

    def run(self, state, start_step: int, n_steps: int,
            on_metrics: Callable | None = None):
        step = start_step
        retries = 0
        it = self.batch_iter_factory(step)
        while step < n_steps and not self._stop:
            batch = next(it)
            t0 = time.perf_counter()
            try:
                state, metrics = self.step_fn(state, batch)
            except Exception as e:   # transient runtime failure path
                retries += 1
                log.error("step %d failed (%s); retry %d/%d", step, e,
                          retries, self.max_retries)
                if retries > self.max_retries:
                    raise
                # restore from last checkpoint and rebuild the input stream
                last = self._latest()
                if last is not None:
                    state = self._restore(state, last)
                    step = last
                    it = self.batch_iter_factory(step)
                continue
            retries = 0
            self.timer.observe(time.perf_counter() - t0)
            step += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % self.ckpt_every == 0:
                self.ckpt.save_async(step, state)
        self.ckpt.wait()
        self.ckpt.save(step, state)
        return state, step

    def _latest(self):
        from repro.checkpoint import latest_step

        # a pending async save may hold the newest step: without the join,
        # a failure racing the writer thread restores a stale checkpoint
        # (or none at all) and silently replays from the wrong step
        self.ckpt.wait()
        return latest_step(self.ckpt.dir)

    def _restore(self, like, step):
        from repro.checkpoint import restore

        log.info("restoring from step %d", step)
        return restore(self.ckpt.dir, step, like)
