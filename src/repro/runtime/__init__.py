from repro.runtime.chaos import (
    ChaosController,
    FaultPlan,
    FaultSpec,
    TransientExecutorError,
    seeded_corpus,
)
from repro.runtime.fault import FaultTolerantLoop, StepTimer
from repro.runtime.pool import (
    ArenaPool,
    Lease,
    LeaseError,
    PoolError,
    PoolStats,
    PreemptionStats,
    SpilledLease,
    Ticket,
)

__all__ = [
    "ArenaPool",
    "ChaosController",
    "FaultPlan",
    "FaultSpec",
    "FaultTolerantLoop",
    "Lease",
    "LeaseError",
    "PoolError",
    "PoolStats",
    "PreemptionStats",
    "SpilledLease",
    "StepTimer",
    "Ticket",
    "TransientExecutorError",
    "seeded_corpus",
]
