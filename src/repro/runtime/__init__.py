from repro.runtime.fault import FaultTolerantLoop, StepTimer
from repro.runtime.pool import (
    ArenaPool,
    Lease,
    LeaseError,
    PoolError,
    PoolStats,
    Ticket,
)

__all__ = [
    "ArenaPool",
    "FaultTolerantLoop",
    "Lease",
    "LeaseError",
    "PoolError",
    "PoolStats",
    "StepTimer",
    "Ticket",
]
