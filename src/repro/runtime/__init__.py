from repro.runtime.chaos import (
    ChaosController,
    FaultPlan,
    FaultSpec,
    TransientExecutorError,
    seeded_corpus,
)
from repro.runtime.fault import FaultTolerantLoop, StepTimer
from repro.runtime.fleet import (
    Fleet,
    FleetRequest,
    FleetRouter,
    FleetStallError,
    PlannerService,
    PlanRecord,
    WorkerShard,
)
from repro.runtime.loadgen import Arrival, OpenLoopLoadGen, workload_summary
from repro.runtime.pool import (
    ArenaPool,
    Lease,
    LeaseError,
    PoolError,
    PoolStats,
    PreemptionStats,
    ScratchReservation,
    SpilledLease,
    Ticket,
)

__all__ = [
    "ArenaPool",
    "Arrival",
    "ChaosController",
    "FaultPlan",
    "FaultSpec",
    "FaultTolerantLoop",
    "Fleet",
    "FleetRequest",
    "FleetRouter",
    "FleetStallError",
    "Lease",
    "LeaseError",
    "OpenLoopLoadGen",
    "PlanRecord",
    "PlannerService",
    "PoolError",
    "PoolStats",
    "PreemptionStats",
    "ScratchReservation",
    "SpilledLease",
    "StepTimer",
    "Ticket",
    "TransientExecutorError",
    "WorkerShard",
    "seeded_corpus",
    "workload_summary",
]
