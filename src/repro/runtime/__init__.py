from repro.runtime.fault import FaultTolerantLoop, StepTimer

__all__ = ["FaultTolerantLoop", "StepTimer"]
