"""Open-loop load generation for the serving fleet (DESIGN.md §14).

``synth_requests`` hands the server one fixed batch — fine for exercising
a tick loop, useless for sizing a fleet: admission control, shard routing
and preemption only show their behavior under *arrival pressure*, where
requests keep landing whether or not the system has drained the last
ones.  This module generates that pressure as data, ahead of time:

  * **open loop** — arrival times are drawn from a seeded Poisson process
    (exponential inter-arrival gaps at ``rate`` requests/tick) and never
    react to the system under test, so an overloaded fleet sees its queue
    grow instead of the workload politely slowing down;
  * **sampled lengths** — prompt lengths are lognormal (a heavy right
    tail: most prompts are short, a few are huge and stress the prefill
    lane or overflow every shard), generation lengths geometric, both
    clipped to configured bounds;
  * **mixes** — each arrival carries a Pareto request class
    (latency-sensitive fraction), a priority level and a tenant drawn
    from weighted choices, so quota and preemption policies face a
    realistic blend.

Everything is a pure function of ``(seed, parameters)``: the same
generator yields byte-identical workloads across runs and machines, which
is what lets `bench_fleet` exact-diff its tick-domain metrics and the
chaos tests compare faulted runs against a fault-free twin.  No jax —
arrivals are plain numpy/dataclass values usable by both the simulated
fleet and the real decode server.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One generated request: identity, arrival time, and sampled shape.

    ``tick`` is the open-loop arrival time in scheduler ticks (the fleet
    submits the request at that tick, ready or not).  ``prompt_len`` /
    ``gen_len`` are the sampled prompt and generation lengths;
    ``klass`` is the Pareto request class (``'latency'`` / ``'memory'`` /
    ``None``), ``priority`` orders preemption, ``tenant`` selects a quota.
    """

    rid: int
    tick: int
    prompt_len: int
    gen_len: int
    klass: str | None = None
    priority: int = 0
    tenant: str | None = None

    @property
    def smax(self) -> int:
        """Total sequence budget this request needs (prompt + generated)."""
        return self.prompt_len + self.gen_len


class OpenLoopLoadGen:
    """Seeded open-loop workload generator.

    Args:
      seed: RNG seed; identical seeds + parameters yield identical
        workloads (the whole point — see module docstring).
      rate: mean arrivals per tick of the Poisson process.
      prompt_mean / prompt_sigma: lognormal prompt-length distribution —
        ``prompt_mean`` is the distribution *mean* (the underlying
        normal's mu is derived), ``prompt_sigma`` the log-space sigma
        controlling tail heaviness.
      prompt_min / prompt_max: clip bounds for prompt lengths.
      gen_mean: mean of the geometric generation-length distribution.
      gen_min / gen_max: clip bounds for generation lengths.
      latency_frac: fraction of arrivals tagged ``klass='latency'``
        (the rest are ``'memory'``); 0 leaves ``klass=None``.
      priority_weights: ``{priority: weight}`` for the priority mix
        (default: everything priority 0).
      tenant_weights: ``{tenant: weight}`` for the tenant mix (default:
        ``tenant=None``).
    """

    def __init__(self, seed: int = 0, *, rate: float = 4.0,
                 prompt_mean: float = 48.0, prompt_sigma: float = 0.6,
                 prompt_min: int = 1, prompt_max: int = 2048,
                 gen_mean: float = 8.0, gen_min: int = 1, gen_max: int = 64,
                 latency_frac: float = 0.0,
                 priority_weights: dict[int, float] | None = None,
                 tenant_weights: dict[str, float] | None = None):
        if rate <= 0:
            raise ValueError(f"arrival rate must be positive, got {rate}")
        if not 0.0 <= latency_frac <= 1.0:
            raise ValueError(f"latency_frac must be in [0, 1], got "
                             f"{latency_frac}")
        if prompt_min < 1 or prompt_max < prompt_min:
            raise ValueError(f"bad prompt bounds [{prompt_min}, {prompt_max}]")
        if gen_min < 1 or gen_max < gen_min:
            raise ValueError(f"bad gen bounds [{gen_min}, {gen_max}]")
        if gen_mean < 1:
            raise ValueError(f"gen_mean must be >= 1, got {gen_mean}")
        for name, weights in (("priority_weights", priority_weights),
                              ("tenant_weights", tenant_weights)):
            if weights is not None and (
                    not weights or any(w < 0 for w in weights.values())
                    or sum(weights.values()) <= 0):
                raise ValueError(f"{name} needs positive total weight")
        self.seed = int(seed)
        self.rate = float(rate)
        self.prompt_mean = float(prompt_mean)
        self.prompt_sigma = float(prompt_sigma)
        self.prompt_min = int(prompt_min)
        self.prompt_max = int(prompt_max)
        self.gen_mean = float(gen_mean)
        self.gen_min = int(gen_min)
        self.gen_max = int(gen_max)
        self.latency_frac = float(latency_frac)
        self.priority_weights = dict(priority_weights or {})
        self.tenant_weights = dict(tenant_weights or {})

    def arrivals(self, n: int) -> list[Arrival]:
        """Generate the first ``n`` arrivals, sorted by arrival tick."""
        if n <= 0:
            return []
        rng = np.random.default_rng(self.seed)
        # Poisson process: exponential gaps at `rate` per tick; the cumsum
        # is the arrival clock, floored onto the integer tick grid.
        gaps = rng.exponential(1.0 / self.rate, size=n)
        ticks = np.floor(np.cumsum(gaps)).astype(np.int64) + 1
        # Lognormal prompts with mean `prompt_mean`: mu is derived so the
        # distribution mean (not median) matches before clipping.
        mu = math.log(self.prompt_mean) - 0.5 * self.prompt_sigma ** 2
        prompts = np.clip(
            np.rint(rng.lognormal(mu, self.prompt_sigma, size=n)),
            self.prompt_min, self.prompt_max).astype(np.int64)
        gens = np.clip(rng.geometric(min(1.0, 1.0 / self.gen_mean), size=n),
                       self.gen_min, self.gen_max).astype(np.int64)
        lat = rng.random(n) < self.latency_frac if self.latency_frac else None
        priorities = self._mix(rng, self.priority_weights, n, default=0)
        tenants = self._mix(rng, self.tenant_weights, n, default=None)
        return [
            Arrival(
                rid=i,
                tick=int(ticks[i]),
                prompt_len=int(prompts[i]),
                gen_len=int(gens[i]),
                klass=(None if lat is None
                       else ("latency" if lat[i] else "memory")),
                priority=priorities[i],
                tenant=tenants[i],
            )
            for i in range(n)
        ]

    @staticmethod
    def _mix(rng: np.random.Generator, weights: dict, n: int, default):
        """Draw ``n`` weighted choices from ``weights`` (all ``default``
        when no weights are configured)."""
        if not weights:
            return [default] * n
        keys = sorted(weights)                  # deterministic choice order
        p = np.array([weights[k] for k in keys], dtype=np.float64)
        idx = rng.choice(len(keys), size=n, p=p / p.sum())
        return [keys[i] for i in idx]

    def describe(self) -> dict:
        """Config echo for benchmark rows / logs."""
        return {
            "seed": self.seed, "rate": self.rate,
            "prompt_mean": self.prompt_mean,
            "prompt_sigma": self.prompt_sigma,
            "prompt_max": self.prompt_max,
            "gen_mean": self.gen_mean, "gen_max": self.gen_max,
            "latency_frac": self.latency_frac,
            "priorities": sorted(self.priority_weights),
            "tenants": sorted(self.tenant_weights),
        }


def workload_summary(arrivals: list[Arrival]) -> dict:
    """Deterministic shape summary of a generated workload — the numbers
    `bench_fleet` emits (and exact-diffs, seeds being fixed) to pin the
    workload a fleet measurement was taken under."""
    if not arrivals:
        return {"n": 0}
    prompts = np.array([a.prompt_len for a in arrivals])
    gens = np.array([a.gen_len for a in arrivals])
    span = max(a.tick for a in arrivals)
    return {
        "n": len(arrivals),
        "span_ticks": int(span),
        "prompt_mean": round(float(prompts.mean()), 2),
        "prompt_p99": int(np.percentile(prompts, 99)),
        "gen_mean": round(float(gens.mean()), 2),
        "tokens_total": int(gens.sum()),
        "latency_frac": round(
            sum(a.klass == "latency" for a in arrivals) / len(arrivals), 3),
    }
