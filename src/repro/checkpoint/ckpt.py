"""Fault-tolerant sharded checkpointing.

Design points (the large-scale requirements, scaled to this container):

  * **atomic**: written to ``<dir>/tmp.<step>`` then os.rename'd — a crash
    mid-write never corrupts the latest checkpoint;
  * **sharded**: each process writes only its local shards
    (``addressable_shards``) plus a metadata manifest; restore reassembles;
  * **elastic**: ``restore(..., shardings=new)`` re-lays-out arrays onto a
    *different* mesh than they were saved from (node-failure / rescale path);
  * **async**: ``CheckpointManager.save_async`` snapshots to host then writes
    in a background thread, keeping the train loop running;
  * **bounded**: keeps the most recent ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Write checkpoint atomically; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "arrays": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in logical_dtype:
            # numpy can't round-trip ml_dtypes without pickle: store raw bits
            np.save(os.path.join(tmp, fname),
                    arr.view(np.uint16 if arr.dtype.itemsize == 2
                             else np.uint8),
                    allow_pickle=False)
        else:
            np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
        manifest["arrays"].append(
            {"path": p, "file": fname, "shape": list(arr.shape),
             "dtype": logical_dtype}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally reshard onto new
    ``shardings`` (elastic restart onto a different mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {a["path"]: a for a in manifest["arrays"]}
    paths, leaves, treedef = _flatten_with_paths(like)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None
        else [None] * len(leaves)
    )
    import ml_dtypes

    out = []
    for p, leaf, shd in zip(paths, leaves, shard_leaves):
        rec = by_path[p]
        arr = np.load(os.path.join(path, rec["file"]), allow_pickle=False)
        if "bfloat16" in rec["dtype"] and arr.dtype == np.uint16:
            arr = arr.view(ml_dtypes.bfloat16)
        target_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(target_dtype)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree: Any) -> None:
        # snapshot to host synchronously (cheap), write in background
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True
        )
        self._thread.start()

    def _write(self, step, host_tree):
        save(self.dir, step, host_tree)
        self._gc()

    def save(self, step: int, tree: Any) -> str:
        p = save(self.dir, step, tree)
        self._gc()
        return p

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)
