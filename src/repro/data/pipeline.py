"""Deterministic, shardable, resumable synthetic LM data pipeline.

Properties a 1000-node training job needs:

  * stateless addressing — batch(step) is a pure function of (seed, step,
    process_index), so restart/elastic-rescale resumes exactly without
    data-state checkpoints;
  * per-process sharding — each host materializes only its slice of the
    global batch;
  * background prefetch — a double-buffered thread hides generation latency;
  * structured stream — Zipf-distributed tokens over the vocab with Markov
    bigram structure, so LM losses actually *decrease* during the example
    runs (pure-uniform tokens would have irreducible loss = log V).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass
class DataPipeline:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    n_processes: int = 1
    process_index: int = 0
    prefetch: int = 2

    def __post_init__(self):
        assert self.global_batch % self.n_processes == 0
        self.local_batch = self.global_batch // self.n_processes
        V = self.cfg.vocab_size
        rng = np.random.default_rng(self.seed)
        # fixed zipfian unigram + low-rank bigram mixing table
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = rng.integers(1, V, size=64)

    # -- stateless batch addressing -------------------------------------------
    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.process_index
        )
        B, S, V = self.local_batch, self.seq_len, self.cfg.vocab_size
        base = rng.choice(V, size=(B, S), p=self._unigram)
        # Markov structure: token_t depends on token_{t-1} half the time
        mix = rng.random((B, S)) < 0.5
        shifted = (np.roll(base, 1, axis=1)
                   + self._shift[np.arange(S) % 64][None, :]) % V
        tokens = np.where(mix, shifted, base).astype(np.int32)
        batch = {"tokens": tokens}
        if self.cfg.is_encoder_decoder or self.cfg.frontend == "audio_frames":
            batch["frames"] = rng.standard_normal(
                (B, S, self.cfg.d_model), dtype=np.float32
            )
        return batch

    # -- prefetching iterator ---------------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            step = 0
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    def iter_from(self, start_step: int) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(cfg: ArchConfig, shape: ShapeConfig, **kw) -> DataPipeline:
    return DataPipeline(cfg=cfg, seq_len=shape.seq_len,
                        global_batch=shape.global_batch, **kw)
