from repro.data.pipeline import DataPipeline, make_pipeline

__all__ = ["DataPipeline", "make_pipeline"]
