"""Divide-and-conquer over single-node separators (paper Section 3.2, Fig. 7).

Irregularly wired networks from NAS stack single-input/single-output cells
into an hourglass topology.  A node ``v`` is a *separator* iff

  (a) every other node is either a strict ancestor or strict descendant of
      ``v``            (ancestors ∪ {v} ∪ descendants == V), and
  (b) no edge jumps across ``v`` (from a strict ancestor directly to a strict
      descendant) — otherwise that edge's tensor stays live across the cut and
      the sub-schedules would not compose memory-independently.

With both conditions, any schedule factors as (ancestors..., v, descendants...)
and the only tensor live at the cut is v's output, so concatenating per-part
optimal schedules is globally optimal (Wilken et al., 2000 — the argument the
paper invokes).

``partition(g)`` returns the flat list of segments (each a list of node ids
in the original graph) such that segment k+1 sees segment k's cut node as a
*preplaced* boundary input.  ``partition_hierarchy(g)`` generalizes this to
a nested segment tree: each segment's induced subgraph is recursively
re-partitioned (with its boundary carried as preplaced input) until no
further separator splits its free nodes.  For single-node separator cuts the
flat pass is provably maximal — any separator of a segment's subgraph is
already a separator of the whole graph (DESIGN.md §8), so the recursion
converges after one level on chain-of-cells networks — but the tree is the
structure the scheduler walks and the isomorphic-cell plan reuse keys on:
stacked networks decompose into leaves whose anonymized subgraphs hash
identically, so each unique cell is DP-scheduled once and replayed.
"""

from __future__ import annotations

import dataclasses

from repro.core.graph import Graph


@dataclasses.dataclass
class Segment:
    node_ids: list[int]          # nodes scheduled inside this segment
    boundary_in: list[int]       # preplaced producers from earlier segments


def find_separators(g: Graph) -> list[int]:
    n = len(g)
    anc = g.ancestors_masks()
    full = (1 << n) - 1
    desc = [0] * n
    for u in range(n):
        m = anc[u]  # mark u as a descendant of each of its ancestors
        for p in range(n):
            if m >> p & 1:
                desc[p] |= 1 << u
    seps = []
    topo = g.topo_order()
    for v in topo:
        cover = anc[v] | desc[v] | (1 << v)
        if cover != full:
            continue
        # (b) no ancestor->descendant edge bypassing v
        ok = True
        for b in range(n):
            if desc[v] >> b & 1:
                if g.pred_mask[b] & anc[v]:
                    ok = False
                    break
        if ok:
            seps.append(v)
    return seps


def partition(g: Graph) -> list[Segment]:
    """Split at every separator; segments are contiguous topo slices."""
    seps = find_separators(g)
    if not seps:
        return [Segment(node_ids=g.topo_order(), boundary_in=[])]
    anc = g.ancestors_masks()
    # order separators by ancestor-count (= topological position)
    seps.sort(key=lambda v: bin(anc[v]).count("1"))
    segments: list[Segment] = []
    placed = 0          # bitmask of nodes already assigned
    boundary: list[int] = []
    for v in seps:
        seg_mask = (anc[v] | (1 << v)) & ~placed
        ids = [u for u in range(len(g)) if seg_mask >> u & 1]
        if ids:
            segments.append(Segment(node_ids=ids, boundary_in=list(boundary)))
            placed |= seg_mask
            boundary = [v]
    rest = [u for u in range(len(g)) if not placed >> u & 1]
    if rest:
        segments.append(Segment(node_ids=rest, boundary_in=list(boundary)))
    return segments


# ---------------------------------------------------------------------------
# Nested segment tree (hierarchical divide and conquer, DESIGN.md §8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PartitionNode:
    """One node of the nested segment tree.

    ``node_ids`` are the original-graph nodes this (sub)segment schedules;
    ``boundary_in`` the preplaced producers from earlier segments.  Internal
    nodes delegate to ``children`` (in schedule order); leaves are the atomic
    cells the DP actually runs on.
    """

    node_ids: list[int]
    boundary_in: list[int]
    children: list["PartitionNode"] = dataclasses.field(default_factory=list)
    depth: int = 0

    def leaves(self) -> list["PartitionNode"]:
        if not self.children:
            return [self]
        out: list[PartitionNode] = []
        for c in self.children:
            out.extend(c.leaves())
        return out

    @property
    def n_leaves(self) -> int:
        return len(self.leaves())

    @property
    def height(self) -> int:
        return 1 + max((c.height for c in self.children), default=0)


def partition_hierarchy(g: Graph, max_depth: int = 16) -> PartitionNode:
    """Nested segment tree: recursively split at single-node separators.

    Each level splits a segment's induced subgraph (boundary included as a
    regular node, so crossing edges stay visible to condition (b)) and
    recurses into every part that holds at least one free node; a part's
    boundary is the parent boundary plus any cut nodes placed before it.
    The recursion stops when the free nodes no longer split — for separator
    cuts that is depth one past the flat partition (the flat pass is
    maximal; see module docstring), but the guard keeps the construction
    correct even on graphs where a subgraph exposes structure the flat pass
    cannot.

    Concatenating per-leaf optimal schedules (boundary preplaced) is
    globally optimal by induction over the tree: every cut satisfies the
    separator conditions inside its parent's subgraph, and the parent's
    subgraph sees exactly the tensors the whole graph does at that cut.
    """

    def refine(node_ids: list[int], boundary_in: list[int],
               depth: int) -> PartitionNode:
        node = PartitionNode(node_ids=sorted(node_ids),
                             boundary_in=sorted(boundary_in), depth=depth)
        if depth >= max_depth or len(node_ids) <= 2:
            return node
        sub_ids = sorted(set(node_ids) | set(boundary_in))
        sub, idmap = g.induced_subgraph(sub_ids)
        inv = {v: k for k, v in idmap.items()}
        free = {idmap[u] for u in node_ids}
        parts = [s for s in partition(sub)
                 if any(u in free for u in s.node_ids)]
        if len(parts) < 2:
            return node          # no separator splits the free nodes: leaf
        for s in parts:
            child_ids = [inv[u] for u in s.node_ids if u in free]
            child_bnd = sorted(
                {inv[b] for b in s.boundary_in}
                | {inv[u] for u in s.node_ids if u not in free}
            )
            node.children.append(refine(child_ids, child_bnd, depth + 1))
        return node

    return refine(list(range(len(g))), [], 0)
