"""Divide-and-conquer over single-node separators (paper Section 3.2, Fig. 7).

Irregularly wired networks from NAS stack single-input/single-output cells
into an hourglass topology.  A node ``v`` is a *separator* iff

  (a) every other node is either a strict ancestor or strict descendant of
      ``v``            (ancestors ∪ {v} ∪ descendants == V), and
  (b) no edge jumps across ``v`` (from a strict ancestor directly to a strict
      descendant) — otherwise that edge's tensor stays live across the cut and
      the sub-schedules would not compose memory-independently.

With both conditions, any schedule factors as (ancestors..., v, descendants...)
and the only tensor live at the cut is v's output, so concatenating per-part
optimal schedules is globally optimal (Wilken et al., 2000 — the argument the
paper invokes).

``partition(g)`` returns the list of segments (each a list of node ids in the
original graph) such that segment k+1 sees segment k's cut node as a
*preplaced* boundary input.
"""

from __future__ import annotations

import dataclasses

from repro.core.graph import Graph


@dataclasses.dataclass
class Segment:
    node_ids: list[int]          # nodes scheduled inside this segment
    boundary_in: list[int]       # preplaced producers from earlier segments


def find_separators(g: Graph) -> list[int]:
    n = len(g)
    anc = g.ancestors_masks()
    full = (1 << n) - 1
    desc = [0] * n
    for u in range(n):
        m = anc[u]  # mark u as a descendant of each of its ancestors
        for p in range(n):
            if m >> p & 1:
                desc[p] |= 1 << u
    seps = []
    topo = g.topo_order()
    for v in topo:
        cover = anc[v] | desc[v] | (1 << v)
        if cover != full:
            continue
        # (b) no ancestor->descendant edge bypassing v
        ok = True
        for b in range(n):
            if desc[v] >> b & 1:
                if g.pred_mask[b] & anc[v]:
                    ok = False
                    break
        if ok:
            seps.append(v)
    return seps


def partition(g: Graph) -> list[Segment]:
    """Split at every separator; segments are contiguous topo slices."""
    seps = find_separators(g)
    if not seps:
        return [Segment(node_ids=g.topo_order(), boundary_in=[])]
    anc = g.ancestors_masks()
    # order separators by ancestor-count (= topological position)
    seps.sort(key=lambda v: bin(anc[v]).count("1"))
    segments: list[Segment] = []
    placed = 0          # bitmask of nodes already assigned
    boundary: list[int] = []
    for v in seps:
        seg_mask = (anc[v] | (1 << v)) & ~placed
        ids = [u for u in range(len(g)) if seg_mask >> u & 1]
        if ids:
            segments.append(Segment(node_ids=ids, boundary_in=list(boundary)))
            placed |= seg_mask
            boundary = [v]
    rest = [u for u in range(len(g)) if not placed >> u & 1]
    if rest:
        segments.append(Segment(node_ids=rest, boundary_in=list(boundary)))
    return segments
