"""Adaptive soft budgeting (paper Algorithm 2).

A meta-search for the pruning budget tau used by the DP scheduler:

  * tau_max  — peak footprint of Kahn's schedule (always feasible), the
               paper's "hard budget".
  * 'no solution' (tau < mu*)        -> raise tau toward the last feasible one:
        tau_old <- tau_new ; tau_new <- (tau_new + tau_old)/2   (midpoint)
  * 'timeout'  (search step too big) -> lower tau aggressively:
        tau_old <- tau_new ; tau_new <- tau_new/2

Both updates are the paper's, with its "simultaneous" semantics (the midpoint
uses the *previous* tau_old).  The paper's per-step wall-clock limit T is
realized deterministically as a per-step signature quota (``state_quota``);
the literal wall-clock limit is also supported.

Termination: the paper loops until 'solution'.  With integer byte budgets the
interval [best-known-infeasible, best-known-feasible] shrinks monotonically,
but a too-small quota can make *every* tau in the interval time out.  In that
case (interval collapsed without a solution) we escalate the quota (x4) and
restart — with quota -> infinity the search degenerates to the exact DP, so
termination is guaranteed.  This fallback is our addition (DESIGN.md §3).

Every DP round inherits the scheduler's fragmentation-aware tie-break:
among equal-peak signatures the winner is the partial schedule with the
smaller estimated arena watermark, so the tau meta-search converges on
orders the offset allocator can realize without fragmentation (rule and
rationale in DESIGN.md §5).

Since the branch-and-bound rework (DESIGN.md §8) the DP bounds itself with
a heuristic incumbent, so a plain ``dp_schedule`` call already runs with an
automatic, tighter-than-Kahn tau; this meta-search is the *fallback* the
pipeline reaches for when even the bounded search exceeds its state quota
(every round still benefits from the bound: the effective tau is
``min(tau_round, incumbent)`` plus the dominance and lower-bound prunes).
"""

from __future__ import annotations

import dataclasses
import time

from repro.core.graph import Graph
from repro.core.heuristics import kahn_schedule
from repro.core.scheduler import (
    NoSolutionError,
    ScheduleResult,
    SearchTimeout,
    dp_schedule,
)


@dataclasses.dataclass
class BudgetSearchStats:
    tau_trajectory: list[tuple[int, str]]      # (tau, flag) per round
    tau_final: int
    tau_max: int
    quota_escalations: int
    wall_time_s: float


def adaptive_budget_schedule(
    g: Graph,
    *,
    state_quota: int = 20_000,
    preplaced: tuple[int, ...] = (),
    max_rounds: int = 64,
    wall_clock_limit_s: float | None = None,
    tau_max: int | None = None,
    engine: str = "auto",
) -> tuple[ScheduleResult, BudgetSearchStats]:
    """Algorithm 2: binary meta-search for tau wrapping the DP scheduler.

    ``tau_max`` defaults to the Kahn peak (the paper's hard budget); callers
    may pass a tighter *known-feasible* peak (e.g. the best heuristic's) —
    since the DP prunes strictly-greater peaks only, a feasible tau never
    yields 'no solution', it just shrinks the search space further.

    ``engine`` selects the DP implementation per round (see
    :func:`repro.core.scheduler.dp_schedule`); every round of the meta-search
    shares the graph's precomputed bitmask tables, so retries with a new tau
    re-run only the frontier sweep, not the setup.
    """
    t0 = time.perf_counter()
    kahn = kahn_schedule(g, preplaced=preplaced)
    if tau_max is None:
        tau_max = kahn.peak_bytes
    trajectory: list[tuple[int, str]] = []
    escalations = 0
    quota = state_quota

    while True:
        tau_old = tau_new = tau_max
        lo_infeasible = -1                  # tightest tau that returned 'no solution'
        result: ScheduleResult | None = None
        for _round in range(max_rounds):
            try:
                result = dp_schedule(
                    g,
                    budget=tau_new,
                    state_quota=quota,
                    preplaced=preplaced,
                    wall_clock_limit_s=wall_clock_limit_s,
                    engine=engine,
                )
                trajectory.append((tau_new, "solution"))
                break
            except SearchTimeout:
                trajectory.append((tau_new, "timeout"))
                tau_old, tau_new = tau_new, tau_new // 2
            except NoSolutionError:
                trajectory.append((tau_new, "no solution"))
                lo_infeasible = max(lo_infeasible, tau_new)
                tau_old, tau_new = tau_new, (tau_new + tau_old) // 2
            # keep tau above the tightest known-infeasible point
            if tau_new <= lo_infeasible:
                tau_new = (lo_infeasible + max(tau_old, lo_infeasible + 2)) // 2 + 1
            if tau_new >= tau_max:
                # interval exhausted under this quota -> escalate
                break
        if result is not None:
            stats = BudgetSearchStats(
                tau_trajectory=trajectory,
                tau_final=trajectory[-1][0],
                tau_max=tau_max,
                quota_escalations=escalations,
                wall_time_s=time.perf_counter() - t0,
            )
            return result, stats
        escalations += 1
        quota *= 4
        if escalations > 12:   # pragmatically unreachable; protects CI
            stats = BudgetSearchStats(
                tau_trajectory=trajectory,
                tau_final=tau_max,
                tau_max=tau_max,
                quota_escalations=escalations,
                wall_time_s=time.perf_counter() - t0,
            )
            return kahn, stats
