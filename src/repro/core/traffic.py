"""Belady (clairvoyant) off-chip traffic simulator (paper Fig. 11).

Given a schedule and an on-chip capacity, simulate tensor residency with the
optimal eviction policy (evict the resident tensor whose next use is furthest
in the future — Belady 1966).  The paper uses exactly this, justified because
the whole schedule is known at compile time.

Model (activations; weights are a mandatory one-way read stream):
  * executing node u requires all of u's live input tensors on-chip — absent
    ones are fetched (read traffic += size);
  * u's output is produced on-chip (no traffic);
  * evicting a tensor that is still needed later writes it off-chip once
    (write traffic += size) — re-fetches count again on use;
  * dead tensors vanish for free;
  * weight bytes of u are streamed on use: read traffic += weight_bytes
    (identical for every schedule, so it shifts all bars equally, as in the
    paper's sweep).

Returns bytes of off-chip traffic; 0 means the whole execution fit on-chip
(the paper's "eradicated" case).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.graph import Graph


@dataclasses.dataclass
class TrafficResult:
    read_bytes: int
    write_bytes: int
    weight_read_bytes: int
    fits_entirely: bool

    @property
    def total_bytes(self) -> int:
        return self.read_bytes + self.write_bytes + self.weight_read_bytes


def simulate_traffic(
    g: Graph,
    order: Sequence[int],
    capacity_bytes: int,
    include_weights: bool = True,
) -> TrafficResult:
    pos = {u: i for i, u in enumerate(order)}
    n = len(g)
    # next-use lists per tensor (ascending schedule positions of consumers)
    uses: dict[int, list[int]] = {u: [] for u in range(n)}
    for u in order:
        for p in g.nodes[u].preds:
            uses[p].append(pos[u])
    for k in uses:
        uses[k].sort(reverse=True)  # pop() yields the earliest next use

    resident: dict[int, int] = {}   # tensor -> size
    used_cap = 0
    reads = writes = weight_reads = 0
    spilled: set[int] = set()       # tensors currently off-chip but still live

    INF = 1 << 60

    def next_use(t: int, now: int) -> int:
        lst = uses[t]
        while lst and lst[-1] <= now:
            lst.pop()
        return lst[-1] if lst else INF

    def evict_until(free_needed: int, now: int, pinned: set[int]) -> None:
        nonlocal used_cap, writes
        while used_cap + free_needed > capacity_bytes and resident:
            candidates = [t for t in resident if t not in pinned]
            if not candidates:
                break  # cannot satisfy; arena overflows (caller accounts)
            victim = max(candidates, key=lambda t: (next_use(t, now), t))
            sz = resident.pop(victim)
            used_cap -= sz
            if next_use(victim, now) != INF:
                writes += sz
                spilled.add(victim)

    overflow = False
    for i, u in enumerate(order):
        nd = g.nodes[u]
        if include_weights:
            weight_reads += nd.weight_bytes
        pinned = set(nd.preds) | {u}
        # fetch inputs
        for p in nd.preds:
            if p in resident:
                continue
            sz = g.sizes[p]
            evict_until(sz, i, pinned)
            if used_cap + sz > capacity_bytes:
                overflow = True
            reads += sz
            resident[p] = sz
            used_cap += sz
            spilled.discard(p)
        # produce output
        sz = g.sizes[u]
        evict_until(sz, i, pinned)
        if used_cap + sz > capacity_bytes:
            overflow = True
        resident[u] = sz
        used_cap += sz
        # drop dead tensors
        for p in list(resident):
            if next_use(p, i) == INF and g.succs[p]:
                used_cap -= resident.pop(p)
    fits = reads == 0 and writes == 0 and not overflow
    return TrafficResult(
        read_bytes=reads,
        write_bytes=writes,
        weight_read_bytes=weight_reads if include_weights else 0,
        fits_entirely=fits,
    )
