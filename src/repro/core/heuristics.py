"""Baseline topological-ordering heuristics.

``kahn``   — Kahn's algorithm with FIFO tie-break (the paper's tau_max source
             and its stand-in for TensorFlow Lite's allocation-order
             execution, which runs nodes in flatbuffer/topological order).
``dfs``    — depth-first post-order (what many graph exporters emit).
``greedy`` — memory-aware greedy: from the current zero-indegree frontier pick
             the node minimizing the footprint after its deallocations (ties:
             smaller resulting peak, then id).  A strong non-optimal baseline.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Sequence

from repro.core.graph import Graph, simulate_schedule
from repro.core.scheduler import ScheduleResult


def _result(g: Graph, order: list[int], preplaced: Sequence[int]) -> ScheduleResult:
    sim = simulate_schedule(g, order, preplaced=preplaced)
    return ScheduleResult(
        order=order,
        peak_bytes=sim.peak_bytes,
        final_bytes=sim.final_bytes,
        n_states_expanded=len(order),
        n_signatures=len(order),
        wall_time_s=0.0,
        exact=False,
    )


def kahn_schedule(g: Graph, preplaced: Sequence[int] = ()) -> ScheduleResult:
    pre = set(preplaced)
    indeg = [0] * len(g)
    for nd in g.nodes:
        indeg[nd.id] = sum(1 for p in nd.preds if p not in pre)
    q = deque(
        i for i in range(len(g)) if i not in pre and indeg[i] == 0
    )
    order: list[int] = []
    while q:
        u = q.popleft()
        order.append(u)
        for v in g.succs[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                q.append(v)
    return _result(g, order, preplaced)


def dfs_schedule(g: Graph, preplaced: Sequence[int] = ()) -> ScheduleResult:
    pre = set(preplaced)
    seen = set(pre)
    order: list[int] = []

    def visit(u: int) -> None:
        if u in seen:
            return
        seen.add(u)
        for p in g.nodes[u].preds:
            visit(p)
        order.append(u)

    for x in sorted(set(range(len(g))) - pre):
        visit(x)
    return _result(g, order, preplaced)


def greedy_schedule(g: Graph, preplaced: Sequence[int] = ()) -> ScheduleResult:
    """Pick, at every step, the frontier node with the best immediate footprint."""
    pre = set(preplaced)
    n = len(g)
    indeg = [0] * n
    for nd in g.nodes:
        indeg[nd.id] = sum(1 for p in nd.preds if p not in pre)
    remaining = [len(g.succs[i]) for i in range(n)]
    resident = set(pre)
    mu = sum(g.sizes[p] for p in pre)
    frontier = {i for i in range(n) if i not in pre and indeg[i] == 0}
    order: list[int] = []
    while frontier:
        best_u, best_key = -1, None
        for u in sorted(frontier):
            nd = g.nodes[u]
            alias = sum(g.sizes[p] for p in nd.alias_preds)
            peak_u = mu + g.sizes[u] - alias
            mu_u = peak_u
            for p in nd.preds:
                if remaining[p] == 1 and p in resident and p not in nd.alias_preds:
                    mu_u -= g.sizes[p]
            key = (mu_u, peak_u, u)
            if best_key is None or key < best_key:
                best_key, best_u = key, u
        u = best_u
        nd = g.nodes[u]
        mu += g.sizes[u] - sum(g.sizes[p] for p in nd.alias_preds)
        resident.add(u)
        for p in nd.preds:
            remaining[p] -= 1
            if remaining[p] == 0 and p in resident:
                resident.discard(p)
                if p not in nd.alias_preds:
                    mu -= g.sizes[p]
        order.append(u)
        frontier.discard(u)
        for v in g.succs[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                frontier.add(v)
    return _result(g, order, preplaced)


def best_heuristic_schedule(
    g: Graph, preplaced: Sequence[int] = ()
) -> ScheduleResult:
    """The tightest heuristic order: min peak over Kahn / greedy / DFS.

    Used by the DP's branch-and-bound layer as the search incumbent
    (DESIGN.md §8): each order is feasible, so its peak upper-bounds the
    optimum and every state that provably cannot beat it is pruned.
    """
    best: ScheduleResult | None = None
    for fn in (kahn_schedule, greedy_schedule, dfs_schedule):
        res = fn(g, preplaced=preplaced)
        if best is None or res.peak_bytes < best.peak_bytes:
            best = res
    return best


BASELINES: dict[str, Callable[..., ScheduleResult]] = {
    "kahn": kahn_schedule,
    "tflite": kahn_schedule,   # TFLite executes in graph/topo order (DESIGN.md §3)
    "dfs": dfs_schedule,
    "greedy": greedy_schedule,
}
