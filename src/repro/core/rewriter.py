"""Identity graph rewriting (paper Section 3.3, Eq. 3-8, Fig. 9).

Two paper patterns plus one LM-era analogue (DESIGN.md §4):

* ``concat -> conv``        =>  accumulating *partial convs*  (channel-wise
  partitioning, Eq. 3-6).  Each branch input x_i is convolved with its channel
  slice of the kernel and accumulated in place into the output buffer, so the
  concatenated tensor never materializes:  cost  sum(x_i) + y  ->  max(x_i) + y.

* ``concat -> depthconv``   =>  *partial depthconvs* writing into their slice
  of the output (kernel-wise partitioning, Eq. 7-8).  The final ``concat_view``
  node aliases all partial outputs (slice-writes into one buffer, zero copy).

* ``fused_proj -> split``   =>  independent projections (distributive identity
  on the output-channel axis — the GeGLU/QKV analogue used on LM graphs).

All rewrites preserve mathematical identity; numeric equivalence of the conv
patterns is asserted against ``jax.lax`` convolutions in
``tests/test_rewriter_numeric.py``.

The rewriter is pure pattern matching over the IR: it returns a new Graph and
a report of the applied matches.
"""

from __future__ import annotations

import dataclasses

from repro.core.graph import Graph, Node


@dataclasses.dataclass
class RewriteReport:
    n_concat_conv: int = 0
    n_concat_depthconv: int = 0
    n_fused_proj_split: int = 0
    n_inplace: int = 0            # set by annotate_inplace (separate pass)

    @property
    def total(self) -> int:
        return (self.n_concat_conv + self.n_concat_depthconv
                + self.n_fused_proj_split + self.n_inplace)


def _rebuild(specs: list[dict], name: str) -> Graph:
    return Graph.build(specs, name=name)


def rewrite_graph(g: Graph) -> tuple[Graph, RewriteReport]:
    """Apply all identity rewrites bottom-up until fixpoint (single pass is
    enough for the paper's patterns: matches never create new matches)."""
    report = RewriteReport()
    # Mutable spec list; node ids remapped at the end.
    specs: list[dict] = []
    for nd in g.nodes:
        specs.append(
            dict(
                name=nd.name,
                op=nd.op,
                size_bytes=nd.size_bytes,
                preds=list(nd.preds),
                alias_preds=set(nd.alias_preds),
                weight_bytes=nd.weight_bytes,
                meta=dict(nd.meta),
                dead=False,
            )
        )
    succs = [list(s) for s in g.succs]

    def single_consumer(i: int) -> int | None:
        alive = [s for s in succs[i] if not specs[s]["dead"]]
        return alive[0] if len(alive) == 1 else None

    next_id = len(specs)

    def add_node(spec: dict) -> int:
        nonlocal next_id
        spec.setdefault("alias_preds", set())
        spec.setdefault("weight_bytes", 0)
        spec.setdefault("meta", {})
        spec["dead"] = False
        specs.append(spec)
        succs.append([])
        for p in spec["preds"]:
            succs[p].append(next_id)
        i = next_id
        next_id += 1
        return i

    def redirect(old: int, new: int) -> None:
        """Point all consumers of `old` at `new`."""
        for s in list(succs[old]):
            if specs[s]["dead"]:
                continue
            specs[s]["preds"] = [new if p == old else p for p in specs[s]["preds"]]
            specs[s]["alias_preds"] = {
                new if p == old else p for p in specs[s]["alias_preds"]
            }
            succs[new].append(s)
        succs[old] = []

    for cid in range(len(g)):
        c = specs[cid]
        if c["dead"] or c["op"] != "concat" or len(c["preds"]) < 2:
            continue
        consumer = single_consumer(cid)
        if consumer is None:
            continue
        k = specs[consumer]
        if k["dead"] or k["preds"] != [cid]:
            continue   # conv must consume the concat alone
        branches = list(c["preds"])
        if k["op"] == "conv":
            # concat+conv  =>  accumulating partial convs (in-place into y).
            # Kernel of shape [m, sum(c_i), k, k] splits channel-wise; each
            # partial conv reads x_i and the running accumulator, writes the
            # accumulator in place (alias).  Weight bytes split evenly-ish by
            # branch activation share.
            total_in = sum(specs[b]["size_bytes"] for b in branches) or 1
            acc = None
            for j, b in enumerate(branches):
                w_share = k["weight_bytes"] * specs[b]["size_bytes"] // total_in
                preds = [b] if acc is None else [b, acc]
                alias = set() if acc is None else {acc}
                acc = add_node(
                    dict(
                        name=f"{k['name']}.partial{j}",
                        op="partial_conv",
                        size_bytes=k["size_bytes"],
                        preds=preds,
                        alias_preds=alias,
                        weight_bytes=w_share,
                        meta={**k["meta"], "rewritten_from": k["name"]},
                    )
                )
            specs[cid]["dead"] = True
            specs[consumer]["dead"] = True
            redirect(consumer, acc)
            report.n_concat_conv += 1
        elif k["op"] == "depthconv":
            # concat+depthconv  =>  per-branch depthconv + aliasing concat_view.
            total_in = sum(specs[b]["size_bytes"] for b in branches) or 1
            parts = []
            for j, b in enumerate(branches):
                share = k["size_bytes"] * specs[b]["size_bytes"] // total_in
                w_share = k["weight_bytes"] * specs[b]["size_bytes"] // total_in
                parts.append(
                    add_node(
                        dict(
                            name=f"{k['name']}.dw{j}",
                            op="partial_depthconv",
                            size_bytes=share,
                            preds=[b],
                            weight_bytes=w_share,
                            meta={**k["meta"], "rewritten_from": k["name"]},
                        )
                    )
                )
            view = add_node(
                dict(
                    name=f"{k['name']}.view",
                    op="concat_view",
                    size_bytes=k["size_bytes"],
                    preds=list(parts),
                    alias_preds=set(parts),
                    meta={"rewritten_from": k["name"]},
                )
            )
            specs[cid]["dead"] = True
            specs[consumer]["dead"] = True
            redirect(consumer, view)
            report.n_concat_depthconv += 1

    # fused_proj -> split : replace with independent per-output projections.
    for fid in range(len(g)):
        f = specs[fid]
        if f["dead"] or f["op"] != "fused_proj":
            continue
        consumer = single_consumer(fid)
        if consumer is None or specs[consumer]["op"] != "split":
            continue
        sp = specs[consumer]
        outs = [s for s in succs[consumer] if not specs[s]["dead"]]
        if not outs:
            continue
        total = sp["size_bytes"] or 1
        # one projection per downstream consumer of the split
        for j, o in enumerate(outs):
            share = f["size_bytes"] // len(outs)
            w_share = f["weight_bytes"] // len(outs)
            pj = add_node(
                dict(
                    name=f"{f['name']}.proj{j}",
                    op="proj",
                    size_bytes=share,
                    preds=list(f["preds"]),
                    weight_bytes=w_share,
                    meta={"rewritten_from": f["name"]},
                )
            )
            specs[o]["preds"] = [pj if p == consumer else p for p in specs[o]["preds"]]
            succs[pj].append(o)
        specs[fid]["dead"] = True
        specs[consumer]["dead"] = True
        report.n_fused_proj_split += 1

    # ---- compact: drop dead nodes, remap ids ---------------------------------
    alive = [i for i, s in enumerate(specs) if not s["dead"]]
    idmap = {old: new for new, old in enumerate(alive)}
    out_specs = []
    for old in alive:
        s = specs[old]
        out_specs.append(
            dict(
                name=s["name"],
                op=s["op"],
                size_bytes=s["size_bytes"],
                preds=[idmap[p] for p in s["preds"]],
                alias_preds={idmap[p] for p in s["alias_preds"]},
                weight_bytes=s["weight_bytes"],
                meta=s["meta"],
            )
        )
    return _rebuild(out_specs, name=f"{g.name}+rw"), report


# ---------------------------------------------------------------------------
# In-place elementwise annotation (DESIGN.md §4)
# ---------------------------------------------------------------------------

# Unary elementwise ops that can overwrite their input buffer: same element
# count in and out, each output element depends only on the matching input
# element.
INPLACE_UNARY_OPS = frozenset({
    "relu", "relu6", "bn", "batchnorm", "sigmoid", "tanh", "gelu", "silu",
    "bias_add", "scale", "dropout", "identity", "cast_inplace",
})
# N-ary accumulating ops: the output can be accumulated into one (dying)
# input buffer, like the rewriter's partial-conv accumulators.
INPLACE_ACCUM_OPS = frozenset({"add"})


def annotate_inplace(
    g: Graph,
    unary_ops: frozenset[str] = INPLACE_UNARY_OPS,
    accum_ops: frozenset[str] = INPLACE_ACCUM_OPS,
) -> tuple[Graph, int]:
    """Mark in-place-eligible elementwise ops as aliasing a predecessor.

    A predecessor ``p`` of node ``u`` is in-place-eligible when overwriting
    its buffer is safe and free:

      * ``u`` is its only consumer (nobody else reads ``p`` afterwards),
      * sizes match exactly (the output reuses the buffer verbatim),
      * ``p`` is not a graph input (caller-owned storage stays intact),
      * ``u`` does not already alias (rewriter chains take precedence).

    Unary ops alias their single predecessor; accumulating ops (``add``)
    alias one eligible operand.  The aliases flow through the existing
    alias-chain machinery: the DP charges zero net allocation for the node,
    the arena planner fuses the chain into one buffer, and the executor
    overwrites the predecessor's arena slice in place (DESIGN.md §6), so
    unary chains (relu -> bn -> ...) share storage end-to-end.

    Args:
      g: graph to annotate (node sizes in bytes; sizes must match exactly
        for a mark, since the output reuses the buffer verbatim).
      unary_ops: op names treated as unary elementwise (overwrite-safe).
      accum_ops: op names allowed to accumulate into one dying operand.

    Returns:
      ``(annotated_graph, n_marked)`` — the input graph object itself when
      nothing was marked (``n_marked == 0``), otherwise a rebuilt graph
      with ``alias_preds`` set on the marked nodes.
    """
    def eligible(u: Node, p: int) -> bool:
        return (
            len(g.succs[p]) == 1
            and g.sizes[p] == u.size_bytes
            and g.nodes[p].op != "input"
        )

    n_marked = 0
    specs: list[dict] = []
    for nd in g.nodes:
        alias = set(nd.alias_preds)
        if not alias:
            if nd.op in unary_ops and len(nd.preds) == 1:
                if eligible(nd, nd.preds[0]):
                    alias = {nd.preds[0]}
                    n_marked += 1
            elif nd.op in accum_ops and len(nd.preds) >= 2:
                # alias at most one operand; preds may repeat, and a
                # duplicated operand has >= 2 uses here, so require a
                # uniquely-consumed single occurrence
                for p in nd.preds:
                    if nd.preds.count(p) == 1 and eligible(nd, p):
                        alias = {p}
                        n_marked += 1
                        break
        specs.append(
            dict(
                name=nd.name,
                op=nd.op,
                size_bytes=nd.size_bytes,
                preds=list(nd.preds),
                alias_preds=alias,
                weight_bytes=nd.weight_bytes,
                meta=dict(nd.meta),
            )
        )
    if n_marked == 0:
        return g, 0
    return _rebuild(specs, name=g.name), n_marked
