"""Identity graph rewriting (paper Section 3.3, Eq. 3-8, Fig. 9).

Two paper patterns plus one LM-era analogue (DESIGN.md §4):

* ``concat -> conv``        =>  accumulating *partial convs*  (channel-wise
  partitioning, Eq. 3-6).  Each branch input x_i is convolved with its channel
  slice of the kernel and accumulated in place into the output buffer, so the
  concatenated tensor never materializes:  cost  sum(x_i) + y  ->  max(x_i) + y.

* ``concat -> depthconv``   =>  *partial depthconvs* writing into their slice
  of the output (kernel-wise partitioning, Eq. 7-8).  The final ``concat_view``
  node aliases all partial outputs (slice-writes into one buffer, zero copy).

* ``fused_proj -> split``   =>  independent projections (distributive identity
  on the output-channel axis — the GeGLU/QKV analogue used on LM graphs).

All rewrites preserve mathematical identity; numeric equivalence of the conv
patterns is asserted against ``jax.lax`` convolutions in
``tests/test_rewriter_numeric.py``.

The rewriter is pure pattern matching over the IR: it returns a new Graph and
a report of the applied matches.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.graph import Graph, Node, simulate_schedule


@dataclasses.dataclass
class RewriteReport:
    n_concat_conv: int = 0
    n_concat_depthconv: int = 0
    n_fused_proj_split: int = 0
    n_inplace: int = 0            # set by annotate_inplace (separate pass)

    @property
    def total(self) -> int:
        return (self.n_concat_conv + self.n_concat_depthconv
                + self.n_fused_proj_split + self.n_inplace)


def _rebuild(specs: list[dict], name: str) -> Graph:
    return Graph.build(specs, name=name)


def rewrite_graph(g: Graph) -> tuple[Graph, RewriteReport]:
    """Apply all identity rewrites bottom-up until fixpoint (single pass is
    enough for the paper's patterns: matches never create new matches)."""
    report = RewriteReport()
    # Mutable spec list; node ids remapped at the end.
    specs: list[dict] = []
    for nd in g.nodes:
        specs.append(
            dict(
                name=nd.name,
                op=nd.op,
                size_bytes=nd.size_bytes,
                preds=list(nd.preds),
                alias_preds=set(nd.alias_preds),
                weight_bytes=nd.weight_bytes,
                meta=dict(nd.meta),
                dead=False,
            )
        )
    succs = [list(s) for s in g.succs]

    def single_consumer(i: int) -> int | None:
        alive = [s for s in succs[i] if not specs[s]["dead"]]
        return alive[0] if len(alive) == 1 else None

    next_id = len(specs)

    def add_node(spec: dict) -> int:
        nonlocal next_id
        spec.setdefault("alias_preds", set())
        spec.setdefault("weight_bytes", 0)
        spec.setdefault("meta", {})
        spec["dead"] = False
        specs.append(spec)
        succs.append([])
        for p in spec["preds"]:
            succs[p].append(next_id)
        i = next_id
        next_id += 1
        return i

    def redirect(old: int, new: int) -> None:
        """Point all consumers of `old` at `new`."""
        for s in list(succs[old]):
            if specs[s]["dead"]:
                continue
            specs[s]["preds"] = [new if p == old else p for p in specs[s]["preds"]]
            specs[s]["alias_preds"] = {
                new if p == old else p for p in specs[s]["alias_preds"]
            }
            succs[new].append(s)
        succs[old] = []

    for cid in range(len(g)):
        c = specs[cid]
        if c["dead"] or c["op"] != "concat" or len(c["preds"]) < 2:
            continue
        consumer = single_consumer(cid)
        if consumer is None:
            continue
        k = specs[consumer]
        if k["dead"] or k["preds"] != [cid]:
            continue   # conv must consume the concat alone
        branches = list(c["preds"])
        if k["op"] == "conv":
            # concat+conv  =>  accumulating partial convs (in-place into y).
            # Kernel of shape [m, sum(c_i), k, k] splits channel-wise; each
            # partial conv reads x_i and the running accumulator, writes the
            # accumulator in place (alias).  Weight bytes split evenly-ish by
            # branch activation share.
            total_in = sum(specs[b]["size_bytes"] for b in branches) or 1
            acc = None
            for j, b in enumerate(branches):
                w_share = k["weight_bytes"] * specs[b]["size_bytes"] // total_in
                preds = [b] if acc is None else [b, acc]
                alias = set() if acc is None else {acc}
                acc = add_node(
                    dict(
                        name=f"{k['name']}.partial{j}",
                        op="partial_conv",
                        size_bytes=k["size_bytes"],
                        preds=preds,
                        alias_preds=alias,
                        weight_bytes=w_share,
                        meta={**k["meta"], "rewritten_from": k["name"]},
                    )
                )
            specs[cid]["dead"] = True
            specs[consumer]["dead"] = True
            redirect(consumer, acc)
            report.n_concat_conv += 1
        elif k["op"] == "depthconv":
            # concat+depthconv  =>  per-branch depthconv + aliasing concat_view.
            total_in = sum(specs[b]["size_bytes"] for b in branches) or 1
            parts = []
            for j, b in enumerate(branches):
                share = k["size_bytes"] * specs[b]["size_bytes"] // total_in
                w_share = k["weight_bytes"] * specs[b]["size_bytes"] // total_in
                parts.append(
                    add_node(
                        dict(
                            name=f"{k['name']}.dw{j}",
                            op="partial_depthconv",
                            size_bytes=share,
                            preds=[b],
                            weight_bytes=w_share,
                            meta={**k["meta"], "rewritten_from": k["name"]},
                        )
                    )
                )
            view = add_node(
                dict(
                    name=f"{k['name']}.view",
                    op="concat_view",
                    size_bytes=k["size_bytes"],
                    preds=list(parts),
                    alias_preds=set(parts),
                    meta={"rewritten_from": k["name"]},
                )
            )
            specs[cid]["dead"] = True
            specs[consumer]["dead"] = True
            redirect(consumer, view)
            report.n_concat_depthconv += 1

    # fused_proj -> split : replace with independent per-output projections.
    for fid in range(len(g)):
        f = specs[fid]
        if f["dead"] or f["op"] != "fused_proj":
            continue
        consumer = single_consumer(fid)
        if consumer is None or specs[consumer]["op"] != "split":
            continue
        sp = specs[consumer]
        outs = [s for s in succs[consumer] if not specs[s]["dead"]]
        if not outs:
            continue
        total = sp["size_bytes"] or 1
        # one projection per downstream consumer of the split
        for j, o in enumerate(outs):
            share = f["size_bytes"] // len(outs)
            w_share = f["weight_bytes"] // len(outs)
            pj = add_node(
                dict(
                    name=f"{f['name']}.proj{j}",
                    op="proj",
                    size_bytes=share,
                    preds=list(f["preds"]),
                    weight_bytes=w_share,
                    meta={"rewritten_from": f["name"]},
                )
            )
            specs[o]["preds"] = [pj if p == consumer else p for p in specs[o]["preds"]]
            succs[pj].append(o)
        specs[fid]["dead"] = True
        specs[consumer]["dead"] = True
        report.n_fused_proj_split += 1

    # ---- compact: drop dead nodes, remap ids ---------------------------------
    alive = [i for i, s in enumerate(specs) if not s["dead"]]
    idmap = {old: new for new, old in enumerate(alive)}
    out_specs = []
    for old in alive:
        s = specs[old]
        out_specs.append(
            dict(
                name=s["name"],
                op=s["op"],
                size_bytes=s["size_bytes"],
                preds=[idmap[p] for p in s["preds"]],
                alias_preds={idmap[p] for p in s["alias_preds"]},
                weight_bytes=s["weight_bytes"],
                meta=s["meta"],
            )
        )
    return _rebuild(out_specs, name=f"{g.name}+rw"), report


# ---------------------------------------------------------------------------
# In-place elementwise annotation (DESIGN.md §4)
# ---------------------------------------------------------------------------

# Unary elementwise ops that can overwrite their input buffer: same element
# count in and out, each output element depends only on the matching input
# element.
INPLACE_UNARY_OPS = frozenset({
    "relu", "relu6", "bn", "batchnorm", "sigmoid", "tanh", "gelu", "silu",
    "bias_add", "scale", "dropout", "identity", "cast_inplace",
})
# N-ary accumulating ops: the output can be accumulated into one (dying)
# input buffer, like the rewriter's partial-conv accumulators.
INPLACE_ACCUM_OPS = frozenset({"add"})


def annotate_inplace(
    g: Graph,
    unary_ops: frozenset[str] = INPLACE_UNARY_OPS,
    accum_ops: frozenset[str] = INPLACE_ACCUM_OPS,
) -> tuple[Graph, int]:
    """Mark in-place-eligible elementwise ops as aliasing a predecessor.

    A predecessor ``p`` of node ``u`` is in-place-eligible when overwriting
    its buffer is safe and free:

      * ``u`` is its only consumer (nobody else reads ``p`` afterwards),
      * sizes match exactly (the output reuses the buffer verbatim),
      * ``p`` is not a graph input (caller-owned storage stays intact),
      * ``u`` does not already alias (rewriter chains take precedence).

    Unary ops alias their single predecessor; accumulating ops (``add``)
    alias one eligible operand.  The aliases flow through the existing
    alias-chain machinery: the DP charges zero net allocation for the node,
    the arena planner fuses the chain into one buffer, and the executor
    overwrites the predecessor's arena slice in place (DESIGN.md §6), so
    unary chains (relu -> bn -> ...) share storage end-to-end.

    Args:
      g: graph to annotate (node sizes in bytes; sizes must match exactly
        for a mark, since the output reuses the buffer verbatim).
      unary_ops: op names treated as unary elementwise (overwrite-safe).
      accum_ops: op names allowed to accumulate into one dying operand.

    Returns:
      ``(annotated_graph, n_marked)`` — the input graph object itself when
      nothing was marked (``n_marked == 0``), otherwise a rebuilt graph
      with ``alias_preds`` set on the marked nodes.
    """
    def eligible(u: Node, p: int) -> bool:
        return (
            len(g.succs[p]) == 1
            and g.sizes[p] == u.size_bytes
            and g.nodes[p].op != "input"
        )

    n_marked = 0
    specs: list[dict] = []
    for nd in g.nodes:
        alias = set(nd.alias_preds)
        if not alias:
            if nd.op in unary_ops and len(nd.preds) == 1:
                if eligible(nd, nd.preds[0]):
                    alias = {nd.preds[0]}
                    n_marked += 1
            elif nd.op in accum_ops and len(nd.preds) >= 2:
                # alias at most one operand; preds may repeat, and a
                # duplicated operand has >= 2 uses here, so require a
                # uniquely-consumed single occurrence
                for p in nd.preds:
                    if nd.preds.count(p) == 1 and eligible(nd, p):
                        alias = {p}
                        n_marked += 1
                        break
        specs.append(
            dict(
                name=nd.name,
                op=nd.op,
                size_bytes=nd.size_bytes,
                preds=list(nd.preds),
                alias_preds=alias,
                weight_bytes=nd.weight_bytes,
                meta=dict(nd.meta),
            )
        )
    if n_marked == 0:
        return g, 0
    return _rebuild(specs, name=g.name), n_marked


# ---------------------------------------------------------------------------
# Rematerialization: trade FLOPs for peak (DESIGN.md §10)
# ---------------------------------------------------------------------------

# Ops a recompute clone may never replicate: inputs are caller-owned storage,
# and the rewriter's alias-chain ops (accumulators, views) reuse their
# predecessors' buffers — a clone would need its own alias chain and the
# single-consumer alias invariant forbids it anyway.
RECOMPUTE_EXCLUDED_OPS = frozenset({
    "input", "concat_view", "partial_conv", "partial_depthconv",
})


def _spec_flops(spec: dict, size_of) -> int:
    """Surrogate FLOPs of one node spec (``size_of(id) -> bytes``).

    Weightless ops (elementwise, views, adds, concats) cost one op per
    output element.  For weighted ops the true MAC count is estimated as
    the geometric mean ``sqrt(weights * in_elems * out_elems)`` — exact
    for 1x1 convolutions (``px*cin*cout``) and within a small constant
    factor for kxk/depthwise kernels (DESIGN.md §10).  Units are abstract
    "surrogate FLOPs"; only ratios of them are ever consumed.
    """
    out = max(spec["size_bytes"] // 4, 1)
    w = spec.get("weight_bytes", 0) // 4
    if w <= 0:
        return out
    ins = max(sum(size_of(p) for p in spec["preds"]) // 4, 1)
    return max(out, math.isqrt(w * ins * out))


def node_flops(g: Graph, u: int) -> int:
    """Surrogate FLOPs of node ``u`` (see :func:`graph_flops`)."""
    nd = g.nodes[u]
    if nd.op == "input":
        return 0
    spec = dict(size_bytes=nd.size_bytes, weight_bytes=nd.weight_bytes,
                preds=nd.preds)
    return _spec_flops(spec, lambda p: g.sizes[p])


def graph_flops(g: Graph) -> int:
    """Total surrogate FLOPs of ``g`` (inputs cost nothing)."""
    return sum(node_flops(g, u) for u in range(len(g)))


@dataclasses.dataclass
class RecomputeReport:
    """What :func:`rematerialize` did to a graph.

    ``frontier`` is the peak-vs-FLOPs Pareto frontier over every clone set
    the beam search evaluated: ``(flops_ratio, peak_bytes, n_clones)``
    tuples sorted by ratio, starting at the no-recompute base point
    ``(1.0, base_peak, 0)`` and strictly decreasing in peak.  Peaks are
    bounded-search upper bounds (any frontier point is achievable by a
    real schedule; the true optimum of that clone set can only be lower).
    """

    n_steps: int = 0                 # beam steps applied on the chosen path
    n_clones: int = 0                # recompute nodes emitted
    extra_flops: int = 0             # surrogate FLOPs added by the clones
    base_flops: int = 0              # surrogate FLOPs of the input graph
    base_peak_bytes: int = 0         # bounded-search peak of the base graph
    peak_bytes: int = 0              # bounded-search peak of the chosen graph
    n_evals: int = 0                 # clone sets evaluated by the search
    cloned: list[str] = dataclasses.field(default_factory=list)
    frontier: tuple[tuple[float, int, int], ...] = ()

    @property
    def flops_ratio(self) -> float:
        """Expanded-graph FLOPs as a multiple of the base graph's."""
        if self.base_flops <= 0:
            return 1.0
        return (self.base_flops + self.extra_flops) / self.base_flops


def recompute_provenance(nd: Node) -> tuple[str, int] | None:
    """``(original name, original id)`` when ``nd`` is a recompute clone."""
    meta = dict(nd.meta)
    if "recompute_of" not in meta:
        return None
    return str(meta["recompute_of"]), int(meta["recompute_sig"])


def _clone_out(g: Graph, u: int, n_clone: int) -> Graph:
    """One rematerialization step: clone ``u`` for its last ``n_clone``
    consumers (by node id — a proxy for topological position).

    Each clone is a fresh node with the same op/size/weights reading the
    same predecessors; its consumer's pred edge is rewired onto it.  After
    the step ``u``'s output dies at its earliest remaining consumer instead
    of staying live across all of them.  Clones append at the end, so every
    original node keeps its id — provenance ids stay valid and the step
    composes (a clone, having one consumer, is itself never a candidate,
    but cloning ``u`` makes ``u``'s predecessors multi-consumer, which is
    how chains unroll back to an anchor over successive steps).
    """
    specs: list[dict] = []
    for nd in g.nodes:
        specs.append(
            dict(
                name=nd.name,
                op=nd.op,
                size_bytes=nd.size_bytes,
                preds=list(nd.preds),
                alias_preds=set(nd.alias_preds),
                weight_bytes=nd.weight_bytes,
                meta=dict(nd.meta),
            )
        )
    cons = sorted(g.succs[u])
    root = specs[u]["meta"].get("recompute_of", specs[u]["name"])
    sig = specs[u]["meta"].get("recompute_sig", u)
    for c in cons[len(cons) - n_clone:]:
        ci = len(specs)
        specs.append(
            dict(
                name=f"{root}.rc{ci}",
                op=specs[u]["op"],
                size_bytes=specs[u]["size_bytes"],
                preds=list(specs[u]["preds"]),
                alias_preds=set(),
                weight_bytes=specs[u]["weight_bytes"],
                meta={**specs[u]["meta"],
                      "recompute_of": root, "recompute_sig": sig},
            )
        )
        specs[c]["preds"] = [ci if p == u else p for p in specs[c]["preds"]]
    return _rebuild(specs, name=g.name)


def rematerialize(
    g: Graph,
    *,
    flops_budget: float = 1.3,
    beam_width: int = 4,
    max_rounds: int = 6,
    eval_quota: int = 800,
    inplace: bool = True,
) -> tuple[Graph, RecomputeReport]:
    """Expand ``g`` with recompute clones that lower its schedulable peak.

    The planner-side half of rematerialization.  A *step* picks a
    multi-consumer node and gives some of its consumers their own clone —
    a fresh node with the same op/size/weights reading the same
    predecessors — so the original's output dies early instead of staying
    live across all consumers.  The scheduler needs no new machinery: it
    simply orders the expanded DAG (each clone right before its consumer,
    if that is where the optimum lies).

    Which steps actually help is decided *empirically*, not by a static
    score: a small beam search applies candidate steps and evaluates each
    resulting graph with a bounded beam DP
    (:func:`~repro.core.scheduler.dp_schedule` with ``on_quota='beam'``),
    keeping the ``beam_width`` lowest-peak states per round.  Scheduler
    feedback is essential — a clone can *raise* the exact peak (it extends
    its predecessors' liveness and can break in-place eligibility), which
    no liveness heuristic reliably predicts.  Because every evaluation is
    a real schedule, each frontier point is an achievable upper bound.

    Clones carry provenance metadata — ``recompute_of`` (the root original
    node's name) and ``recompute_sig`` (its id in the pre-expansion
    graph) — which the executor uses to give a clone the *same* surrogate
    value function as its original, so expanded-graph outputs stay
    bit-equal to the no-recompute reference.

    Args:
      g: graph to expand (typically post-``rewrite_graph``, pre-
        ``annotate_inplace`` — cloning changes consumer counts and hence
        in-place eligibility, so the in-place pass must rerun after).
      flops_budget: cap on expanded/base surrogate-FLOPs ratio (≥ 1.0);
        the search never applies a step that would exceed it.
      beam_width: states kept per beam round.
      max_rounds: beam rounds (clone steps on the deepest path).
      eval_quota: DP state quota per evaluation; higher is tighter but
        slower.  Evaluation cost is roughly
        ``beam_width * candidates * max_rounds`` bounded-DP runs.
      inplace: evaluate candidate graphs with in-place annotation applied
        (must match how the final graph will be scheduled).

    Returns:
      ``(expanded graph, RecomputeReport)`` — the input graph object
      itself when no clone set within budget lowers the evaluated peak.
      The report's ``frontier`` has the full peak-vs-FLOPs Pareto
      frontier; the returned graph is the frontier's lowest-peak point.
    """
    from repro.core.scheduler import dp_schedule

    base_flops = graph_flops(g)

    def _peak(gx: Graph) -> int:
        gi = annotate_inplace(gx)[0] if inplace else gx
        res = dp_schedule(gi, state_quota=eval_quota, on_quota="beam")
        return simulate_schedule(gi, res.order).peak_bytes

    def _key(gx: Graph) -> tuple:
        # A clone set's identity: which original node each clone recomputes
        # and which consumers it feeds — invariant to discovery order.
        ks = []
        for i in range(len(g.nodes), len(gx.nodes)):
            sig = dict(gx.nodes[i].meta)["recompute_sig"]
            ks.append((sig, tuple(sorted(gx.succs[i]))))
        return tuple(sorted(ks))

    report = RecomputeReport(base_flops=base_flops)
    base_peak = _peak(g)
    report.base_peak_bytes = base_peak
    report.n_evals = 1

    # beam state: (eval peak, extra flops, steps applied, graph)
    beam: list[tuple[int, int, int, Graph]] = [(base_peak, 0, 0, g)]
    evaluated = list(beam)
    seen = {_key(g)}
    for _round in range(max_rounds):
        grown: list[tuple[int, int, int, Graph]] = []
        for _, extra, steps, bg in beam:
            for u in range(len(bg.nodes)):
                nd = bg.nodes[u]
                n_cons = len(bg.succs[u])
                if (n_cons < 2 or nd.op in RECOMPUTE_EXCLUDED_OPS
                        or nd.alias_preds):
                    continue
                fl = node_flops(bg, u)
                # two step shapes: peel the single farthest consumer, or
                # clone out all but the first — intermediate splits are
                # reachable by composing peels across rounds
                for n_clone in {1, n_cons - 1}:
                    extra2 = extra + fl * n_clone
                    if (base_flops + extra2) / base_flops > flops_budget:
                        continue
                    gx = _clone_out(bg, u, n_clone)
                    k = _key(gx)
                    if k in seen:
                        continue
                    seen.add(k)
                    report.n_evals += 1
                    grown.append((_peak(gx), extra2, steps + 1, gx))
        if not grown:
            break
        grown.sort(key=lambda s: (s[0], s[1]))
        beam = grown[:beam_width]
        evaluated.extend(beam)

    # Pareto frontier over evaluated states: sort by FLOPs, keep strictly
    # decreasing peaks.  The base point always leads, so a state only
    # appears if it beats the no-recompute peak.
    evaluated.sort(key=lambda s: (s[1], s[0]))
    frontier: list[tuple[float, int, int]] = []
    best_peak = None
    winner: tuple[int, int, int, Graph] | None = None
    for st in evaluated:
        if best_peak is not None and st[0] >= best_peak:
            continue
        best_peak = st[0]
        ratio = (base_flops + st[1]) / base_flops if base_flops else 1.0
        frontier.append((ratio, st[0], len(st[3].nodes) - len(g.nodes)))
        winner = st
    report.frontier = tuple(frontier)

    if winner is None or winner[3] is g:
        report.peak_bytes = base_peak
        return g, report
    peak, extra, steps, gw = winner
    report.peak_bytes = peak
    report.extra_flops = extra
    report.n_steps = steps
    report.n_clones = len(gw.nodes) - len(g.nodes)
    report.cloned = sorted(
        {recompute_provenance(nd)[0]
         for nd in gw.nodes[len(g.nodes):]})
    gw = _rebuild(
        [dict(name=nd.name, op=nd.op, size_bytes=nd.size_bytes,
              preds=list(nd.preds), alias_preds=set(nd.alias_preds),
              weight_bytes=nd.weight_bytes, meta=dict(nd.meta))
         for nd in gw.nodes],
        name=f"{g.name}+rc{report.n_clones}")
    return gw, report


# ---------------------------------------------------------------------------
# Alias-chain fusion regions (DESIGN.md §11)
# ---------------------------------------------------------------------------

# Ops that never join a fused region.  A ``concat_view`` computes nothing —
# its parts already sit back-to-back at distinct intra-buffer offsets — so
# there is no value to forward through it, and its members' writes land at
# different addresses than the view's own offset.
FUSE_BARRIER_OPS = frozenset({"concat_view"})


@dataclasses.dataclass(frozen=True)
class FusedRegion:
    """A maximal schedule-contiguous in-place alias chain, executed as one
    unit: the head's value is computed once, every member transforms it in
    registers, and the final value is written to the chain's (shared) arena
    slice in a single store (DESIGN.md §11).

    ``node_ids`` is ordered as scheduled; a length-1 region is an unfused
    node (the slice-per-node step).
    """

    node_ids: tuple[int, ...]

    @property
    def head(self) -> int:
        return self.node_ids[0]

    @property
    def out(self) -> int:
        """The node whose value the region's single write stores."""
        return self.node_ids[-1]

    def __len__(self) -> int:
        return len(self.node_ids)


def fuse_alias_chains(g: Graph, order, plan=None) -> list[FusedRegion]:
    """Partition a schedule into maximal in-place alias chains.

    A *link* ``u -> v`` exists when ``v`` aliases exactly ``u``
    (``alias_preds == {u}`` — the chains produced by
    :func:`annotate_inplace` and the rewriter's accumulating partial
    convs), neither op is a fusion barrier, sizes match exactly, and —
    when a ``plan`` is given — both nodes resolve to the *same* planned
    byte offset (an intra-buffer delta would mean the running value no
    longer stands for the arena content at the write address).  Since an
    aliased predecessor has exactly one consumer (``Graph`` validation),
    links form vertex-disjoint paths; each maximal path is one
    :class:`FusedRegion`, every other node a singleton.

    Members need *not* be schedule-contiguous: the DP routinely interleaves
    branch computation between a chain's accumulation steps.  Fused
    execution is still legal because nothing outside the chain can read an
    interior member (single-consumer invariant) and the chain's allocation
    stays live for the chain's whole span, so no interleaved node writes
    into its slice.  The executor therefore forwards the running value in
    registers across the gaps and stores only the final member
    (DESIGN.md §11).

    Returns regions covering ``order`` exactly once, ordered by head
    schedule position, with each region's ``node_ids`` in schedule order.
    """
    order = list(order)
    pos = {u: i for i, u in enumerate(order)}
    link: dict[int, int] = {}
    for v in order:
        nd = g.nodes[v]
        if len(nd.alias_preds) != 1 or nd.op in FUSE_BARRIER_OPS:
            continue
        (u,) = tuple(nd.alias_preds)
        if (u in pos
                and g.nodes[u].op not in FUSE_BARRIER_OPS
                and g.sizes[v] == g.sizes[u]
                and (plan is None
                     or plan.offset_of(v) == plan.offset_of(u))):
            link[u] = v
    tails = set(link.values())
    regions: list[FusedRegion] = []
    for u in order:                      # heads precede members in order
        if u in tails:
            continue
        chain = [u]
        while chain[-1] in link:
            chain.append(link[chain[-1]])
        regions.append(FusedRegion(tuple(chain)))
    return regions
