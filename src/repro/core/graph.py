"""Dataflow-graph intermediate representation for SERENITY scheduling.

The IR mirrors the paper's augmented graph (Section 3): every node carries
operation type, input/output edges, output shape and memory cost.  The memory
model is exactly Algorithm 1's:

  * scheduling node ``u`` allocates ``u.size_bytes`` (its output activation),
  * the running footprint ``mu`` is bumped, the peak ``mu_peak`` updated,
  * any predecessor whose consumers are now all scheduled is deallocated.

Two extensions (documented in DESIGN.md §3) generalize the model without
changing it on paper graphs:

  * ``alias_preds`` — in-place/viewing ops (the rewriter's accumulating
    partial-conv and slice-writing concat) whose output storage subsumes the
    listed predecessors' storage.  Scheduling such a node adds
    ``size - sum(aliased sizes)`` bytes and the aliased predecessors are never
    separately freed (their storage lives on inside the node's output).
  * ``preplaced`` nodes — used by divide-and-conquer: boundary tensors that are
    already resident when a sub-schedule starts.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Node:
    """One operation in the dataflow graph."""

    id: int
    name: str
    op: str
    size_bytes: int                      # bytes of the node's output activation
    preds: tuple[int, ...] = ()
    alias_preds: frozenset[int] = frozenset()
    weight_bytes: int = 0                # parameter bytes read by this op (traffic model)
    meta: tuple[tuple[str, object], ...] = ()

    def replace(self, **kw) -> "Node":
        return dataclasses.replace(self, **kw)


class GraphError(ValueError):
    pass


class Graph:
    """An immutable DAG of :class:`Node` with O(1) pred/succ lookups.

    Node ids must be dense ``0..n-1``.  Edges are implied by ``node.preds``.
    """

    def __init__(self, nodes: Sequence[Node], name: str = "graph"):
        nodes = sorted(nodes, key=lambda n: n.id)
        if [n.id for n in nodes] != list(range(len(nodes))):
            raise GraphError("node ids must be dense 0..n-1")
        self.name = name
        self.nodes: tuple[Node, ...] = tuple(nodes)
        n = len(nodes)
        succs: list[list[int]] = [[] for _ in range(n)]
        for nd in nodes:
            for p in nd.preds:
                if not (0 <= p < n):
                    raise GraphError(f"node {nd.id} has out-of-range pred {p}")
                if p == nd.id:
                    raise GraphError(f"self-loop at node {nd.id}")
                succs[p].append(nd.id)
        self.succs: tuple[tuple[int, ...], ...] = tuple(tuple(s) for s in succs)
        self.sizes: tuple[int, ...] = tuple(nd.size_bytes for nd in nodes)
        # Bitmask helpers for the DP scheduler.
        self.pred_mask: tuple[int, ...] = tuple(
            _mask(nd.preds) for nd in nodes
        )
        self.succ_mask: tuple[int, ...] = tuple(_mask(s) for s in self.succs)
        self._validate()

    # -- construction helpers ------------------------------------------------

    @staticmethod
    def build(specs: Iterable[Mapping], name: str = "graph") -> "Graph":
        """Build from dicts with keys name/op/size_bytes/preds[/alias_preds]."""
        nodes = []
        for i, s in enumerate(specs):
            nodes.append(
                Node(
                    id=i,
                    name=s.get("name", f"n{i}"),
                    op=s.get("op", "op"),
                    size_bytes=int(s["size_bytes"]),
                    preds=tuple(s.get("preds", ())),
                    alias_preds=frozenset(s.get("alias_preds", ())),
                    weight_bytes=int(s.get("weight_bytes", 0)),
                    meta=tuple(sorted(dict(s.get("meta", {})).items())),
                )
            )
        return Graph(nodes, name=name)

    # -- basic queries ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def n_edges(self) -> int:
        return sum(len(nd.preds) for nd in self.nodes)

    def entries(self) -> tuple[int, ...]:
        return tuple(nd.id for nd in self.nodes if not nd.preds)

    def exits(self) -> tuple[int, ...]:
        return tuple(nd.id for nd in self.nodes if not self.succs[nd.id])

    def total_bytes(self) -> int:
        return sum(self.sizes)

    def topo_order(self) -> list[int]:
        """Kahn order with FIFO tie-break on node id (deterministic)."""
        from collections import deque

        indeg = [len(nd.preds) for nd in self.nodes]
        q = deque(i for i in range(len(self)) if indeg[i] == 0)
        order: list[int] = []
        while q:
            u = q.popleft()
            order.append(u)
            for v in self.succs[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    q.append(v)
        if len(order) != len(self):
            raise GraphError("graph has a cycle")
        return order

    def is_topological(self, order: Sequence[int]) -> bool:
        pos = {u: i for i, u in enumerate(order)}
        if len(pos) != len(self):
            return False
        return all(pos[p] < pos[nd.id] for nd in self.nodes for p in nd.preds)

    # -- structure -------------------------------------------------------------

    def ancestors_masks(self) -> list[int]:
        """Bitmask of strict ancestors per node (O(V·E/64) via topo DP)."""
        anc = [0] * len(self)
        for u in self.topo_order():
            m = 0
            for p in self.nodes[u].preds:
                m |= anc[p] | (1 << p)
            anc[u] = m
        return anc

    def descendants_masks(self) -> list[int]:
        """Bitmask of strict descendants per node (transpose of ancestors)."""
        anc = self.ancestors_masks()
        desc = [0] * len(self)
        for u in range(len(self)):
            m = anc[u]
            while m:
                b = m & -m
                m ^= b
                desc[b.bit_length() - 1] |= 1 << u
        return desc

    def induced_subgraph(
        self, node_ids: Sequence[int], anonymize: bool = False
    ) -> tuple["Graph", dict[int, int]]:
        """Subgraph on ``node_ids``; edges from outside are dropped.

        ``anonymize`` replaces node names with ``n{new_id}`` and drops
        ``meta`` (which carries provenance labels such as the rewriter's
        ``rewritten_from``) so that two structurally identical segments
        whose nodes merely carry different labels (stacked cells: ``c0.x``
        vs ``c3.x``) produce byte-identical graphs.  The scheduler reads
        only op/sizes/wiring/alias, so this is exactly the payload the
        isomorphic-cell plan reuse may key on (DESIGN.md §8).

        Returns (subgraph, old_id -> new_id map).
        """
        idmap = {old: new for new, old in enumerate(sorted(node_ids))}
        nodes = []
        for old in sorted(node_ids):
            nd = self.nodes[old]
            preds = tuple(idmap[p] for p in nd.preds if p in idmap)
            alias = frozenset(idmap[p] for p in nd.alias_preds if p in idmap)
            nodes.append(
                Node(
                    id=idmap[old],
                    name=f"n{idmap[old]}" if anonymize else nd.name,
                    op=nd.op,
                    size_bytes=nd.size_bytes,
                    preds=preds,
                    alias_preds=alias,
                    weight_bytes=nd.weight_bytes,
                    meta=() if anonymize else nd.meta,
                )
            )
        return Graph(nodes, name=f"{self.name}.sub"), idmap

    # -- validation ------------------------------------------------------------

    def _validate(self) -> None:
        self.topo_order()  # raises on cycles
        for nd in self.nodes:
            if nd.size_bytes < 0:
                raise GraphError(f"negative size at node {nd.id}")
            extra = nd.alias_preds - set(nd.preds)
            if extra:
                raise GraphError(f"alias_preds {extra} of node {nd.id} not preds")
            for p in nd.alias_preds:
                if len(self.succs[p]) != 1:
                    raise GraphError(
                        f"node {nd.id} aliases pred {p} which has "
                        f"{len(self.succs[p])} consumers (must be 1)"
                    )

    # -- vectorized scheduling tables ------------------------------------------

    def masks(self) -> "BitmaskTables":
        """Numpy bitmask/byte tables for the vectorized DP (built once, cached)."""
        bt = self.__dict__.get("_masks")
        if bt is None:
            bt = BitmaskTables(self)
            self._masks = bt
        return bt

    def __getstate__(self) -> dict:
        # derived tables are pure caches — rebuild on demand after unpickle
        # rather than bloating every pickled plan (the bound tables alone
        # hold an O(n^2) float64 matrix)
        state = dict(self.__dict__)
        for cache_attr in ("_masks", "_bound_tables", "_incumbents"):
            state.pop(cache_attr, None)
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)

    def __repr__(self) -> str:
        return (
            f"Graph({self.name!r}, nodes={len(self)}, edges={self.n_edges}, "
            f"bytes={self.total_bytes()})"
        )


def _mask(ids: Iterable[int]) -> int:
    m = 0
    for i in ids:
        m |= 1 << i
    return m


class BitmaskTables:
    """Per-graph numpy tables backing the vectorized bitmask DP.

    Masks over the ``n`` nodes are packed into ``words = ceil(n/64)`` little-
    endian uint64 words, so a level of ``S`` DP states is an ``(S, words)``
    array and every transition rule (alloc, dealloc, frontier update) becomes
    a batched integer operation instead of a per-state Python loop.

    For single-word graphs (``n <= 64`` — every paper cell) the scheduler uses
    the dense ``(n, n)`` helper matrices to evaluate *all* transitions of a
    level in a handful of numpy ops.
    """

    def __init__(self, g: "Graph"):
        n = len(g)
        self.n = n
        self.words = W = max(1, (n + 63) // 64)
        self.sizes = np.array(g.sizes, dtype=np.int64)
        self.pred_mask = _pack_masks(g.pred_mask, W)          # (n, W) uint64
        self.succ_mask = _pack_masks(g.succ_mask, W)          # (n, W) uint64
        self.node_bit = _pack_masks([1 << i for i in range(n)], W)
        # net bytes allocated when scheduling u (aliased storage subsumed)
        self.net_alloc = np.array(
            [g.sizes[u] - sum(g.sizes[p] for p in g.nodes[u].alias_preds)
             for u in range(n)],
            dtype=np.int64,
        )
        # bytes the arena must find room for (aliases reuse their pred's
        # storage, so never less than zero) — the DP's watermark estimate
        self.alloc_pos = np.maximum(self.net_alloc, 0)
        # Two CSR edge tables sharing one subset test: scheduling u touches
        # its non-alias preds (freed iff the pred's successor mask is now a
        # subset of the signature; contributes `size` bytes) and its succs
        # (enter the frontier iff their pred mask is a subset; contribute a
        # frontier `bit`).  They are kept separate because the DP needs the
        # freed bytes for *every* transition of a level (the eager-move
        # dominance test, DESIGN.md §8) but the frontier refill only for the
        # deduplicated winners; each table is expanded with a single
        # repeat/gather/reduceat pass per level.
        pe_tgt: list[int] = []       # pred edges: succ mask to be covered
        pe_size: list[int] = []      # bytes freed on hit
        pe_len = np.zeros(n, dtype=np.int64)
        se_tgt: list[int] = []       # succ edges: pred mask to be covered
        se_bit: list[int] = []       # frontier bit set on hit
        se_len = np.zeros(n, dtype=np.int64)
        for u in range(n):
            nd = g.nodes[u]
            for p in nd.preds:
                if p not in nd.alias_preds:
                    pe_tgt.append(g.succ_mask[p])
                    pe_size.append(g.sizes[p])
                    pe_len[u] += 1
            for s in g.succs[u]:
                se_tgt.append(g.pred_mask[s])
                se_bit.append(1 << s)
                se_len[u] += 1
        self.pe_tgt = _pack_masks(pe_tgt, W)
        self.pe_size = np.array(pe_size, dtype=np.int64)
        self.pe_len = pe_len
        self.pe_off = np.concatenate(([0], np.cumsum(pe_len)))[:-1]
        self.se_tgt = _pack_masks(se_tgt, W)
        self.se_bit = _pack_masks(se_bit, W)
        self.se_len = se_len
        self.se_off = np.concatenate(([0], np.cumsum(se_len)))[:-1]
        if W == 1:
            self.pred_mask1 = self.pred_mask[:, 0]
            self.succ_mask1 = self.succ_mask[:, 0]
            self.node_bit1 = self.node_bit[:, 0]
            self.pe_tgt1 = self.pe_tgt[:, 0]
            self.se_tgt1 = self.se_tgt[:, 0]
            self.se_bit1 = self.se_bit[:, 0]


def _pack_masks(masks: Sequence[int], words: int) -> np.ndarray:
    out = np.zeros((len(masks), words), dtype=np.uint64)
    for i, m in enumerate(masks):
        for w in range(words):
            out[i, w] = (m >> (64 * w)) & 0xFFFFFFFFFFFFFFFF
    return out


# ---------------------------------------------------------------------------
# Memory simulation (the single source of truth for the footprint model).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    peak_bytes: int
    trace: list[int]          # footprint after each scheduled node (incl. deallocs)
    final_bytes: int


def simulate_schedule(
    g: Graph,
    order: Sequence[int],
    preplaced: Sequence[int] = (),
    keep_outputs: bool = True,
) -> SimResult:
    """Replay ``order`` through the paper's alloc/dealloc model.

    ``preplaced`` nodes start resident (their bytes count toward mu_0) and are
    freed after their last in-schedule consumer, like any other tensor.
    ``keep_outputs``: tensors with no consumers stay resident to the end
    (graph outputs must survive), matching the paper's trace in Fig. 12(b).
    """
    n = len(g)
    pre = set(preplaced)
    sched_set = set(order)
    if sched_set & pre:
        raise GraphError("schedule and preplaced overlap")
    # remaining consumers *within this schedule* for every producer
    remaining = [0] * n
    for u in order:
        for p in g.nodes[u].preds:
            remaining[p] += 1
    resident = [False] * n
    mu = 0
    for p in pre:
        resident[p] = True
        mu += g.sizes[p]
    peak = mu
    trace: list[int] = []
    for u in order:
        nd = g.nodes[u]
        for p in nd.preds:
            if not resident[p]:
                raise GraphError(
                    f"schedule not topological: node {u} needs {p} "
                    f"which is not resident"
                )
        alias_bytes = sum(g.sizes[p] for p in nd.alias_preds)
        mu += g.sizes[u] - alias_bytes
        resident[u] = True
        peak = max(peak, mu)
        for p in nd.preds:
            remaining[p] -= 1
            if remaining[p] == 0 and resident[p]:
                resident[p] = False
                if p not in nd.alias_preds:   # aliased storage lives on inside u
                    mu -= g.sizes[p]
        trace.append(mu)
    del keep_outputs  # outputs (no consumers) are never freed by construction
    return SimResult(peak_bytes=peak, trace=trace, final_bytes=mu)


def simulate_steps(
    g: Graph,
    steps: Sequence[Sequence[int]],
    preplaced: Sequence[int] = (),
) -> SimResult:
    """Replay a width-W *step schedule* through the concurrent-step model.

    A step issues its member ops concurrently (DESIGN.md §12): every
    member's output storage is claimed *before* any of the step's
    deallocations land, so the step's transient is

        mu_before + sum over members of max(net_alloc, 0)

    (an alias member only claims the bytes its output needs beyond its
    pred's storage — exactly the allocator's ``alloc_pos``), and
    predecessors fully consumed by the step are freed at the step's end.
    Members of one step must be mutually independent (no intra-step edge);
    a step reading a value produced in the same step is rejected.

    With every step a singleton this reproduces :func:`simulate_schedule`
    bit-for-bit (same peak, same per-step trace, same final bytes): a
    negative-net alias op claims 0 transient bytes here versus a negative
    delta there, but ``mu`` never exceeds the running peak between ops, so
    the max is unaffected.

    ``trace`` holds the footprint after each *step* (including its frees).
    """
    n = len(g)
    pre = set(preplaced)
    flat = [u for step in steps for u in step]
    if len(set(flat)) != len(flat):
        raise GraphError("step schedule repeats a node")
    if set(flat) & pre:
        raise GraphError("schedule and preplaced overlap")
    remaining = [0] * n
    for u in flat:
        for p in g.nodes[u].preds:
            remaining[p] += 1
    resident = [False] * n
    mu = 0
    for p in pre:
        resident[p] = True
        mu += g.sizes[p]
    peak = mu
    trace: list[int] = []
    for step in steps:
        in_step = set(step)
        claimed = 0
        net = 0
        for u in step:
            nd = g.nodes[u]
            for p in nd.preds:
                if p in in_step:
                    raise GraphError(
                        f"step {tuple(step)} is not an antichain: node {u} "
                        f"reads co-issued node {p}")
                if not resident[p]:
                    raise GraphError(
                        f"schedule not topological: node {u} needs {p} "
                        f"which is not resident")
            alias_bytes = sum(g.sizes[p] for p in nd.alias_preds)
            claimed += max(g.sizes[u] - alias_bytes, 0)
            net += g.sizes[u] - alias_bytes
        peak = max(peak, mu + claimed)
        mu += net
        for u in step:
            resident[u] = True
        for u in step:
            nd = g.nodes[u]
            for p in nd.preds:
                remaining[p] -= 1
                if remaining[p] == 0 and resident[p]:
                    resident[p] = False
                    if p not in nd.alias_preds:
                        mu -= g.sizes[p]
        trace.append(mu)
    return SimResult(peak_bytes=peak, trace=trace, final_bytes=mu)
