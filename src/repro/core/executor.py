"""Arena-backed execution of SERENITY schedules (DESIGN.md §6).

The scheduler/allocator stack plans *where* every intermediate tensor lives
(`ScheduleResult.order` + `ArenaPlan` byte offsets); this module closes the
loop by actually *running* a graph against that plan: one donated linear
arena buffer holds every intermediate, each node reads its predecessors as
slices at their planned offsets and writes its output at its own offset
(``repro.kernels.arena``: XLA ``dynamic_slice``/``dynamic_update_slice`` on
CPU/GPU, Pallas slice kernels on TPU).  Alias chains from the rewriter
execute without copies: in-place nodes overwrite their predecessor's slice,
``concat_view`` parts slice-write back-to-back into the view's buffer, so
the rewritten concat is never materialized.

Because benchmark graphs carry only byte costs (not tensor semantics),
node computation uses a *surrogate numerics* registry: every tensor is a
flat float32 vector of ``size_bytes / 4`` elements and every op is a
deterministic, value- and position-sensitive function of its inputs.  The
executor's correctness contract is *schedule/arena transparency*: for any
graph and any valid (order, plan), ``execute_plan`` must produce bit-for-bit
the values of the plain dict-storage interpreter ``run_reference`` — a wrong
offset, a premature overwrite, or a mis-laid concat part shows up as a
numeric mismatch.

Alongside values, execution *measures* the arena (realized, not estimated):

  ``realized_peak_bytes``  -- high-water of live bytes resident in the arena,
                              tracked from executed alloc/free events; must
                              equal ``ArenaPlan.peak_bytes`` exactly.
  ``realized_arena_bytes`` -- high-water byte extent (max live offset+size);
                              must equal ``ArenaPlan.arena_bytes`` exactly.

``strict=True`` (default) asserts both equalities — the realized-vs-planned
invariant of DESIGN.md §6.

Execution has two granularities (DESIGN.md §11): the default
*slice-per-node* path issues one arena read per predecessor and one write
per node — maximally transparent, every dataflow edge round-trips through
the arena — and the *fused* path (``fuse=True``) executes each in-place
alias chain (:func:`repro.core.rewriter.fuse_alias_chains`) as one region:
the running value is forwarded in registers between chain members and the
chain's shared slice is written once (a single Pallas launch /
``dynamic_update_slice`` for pure-elementwise tails).  Both paths are
bit-equal to ``run_reference`` and realize the same planned footprint.

Public entry points
-------------------
run_reference(g, inputs)                   -> {output name: value}
reference_fn(g)                            -> jit-able unscheduled baseline
execute_plan(g, order, plan, inputs, ...)  -> ExecutionResult
compile_plan(g, order, plan, ...)          -> PlanProgram (precompiled,
                                              memoized on the plan)
RealizedTracker                            -- the measurement machinery
pack_buffers / unpack_buffer               -- move real (shaped, dtyped)
                                              tensors in/out of a planned
                                              uint8 arena (serving state)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocator import ArenaPlan
from repro.core.graph import Graph, Node
from repro.core.rewriter import FusedRegion, fuse_alias_chains
from repro.kernels.arena import (
    arena_accum,
    arena_chain_write,
    arena_read,
    arena_write,
)
from repro.kernels.arena.elemwise import ELEMWISE_FNS


class ExecutorError(ValueError):
    pass


# ---------------------------------------------------------------------------
# Surrogate numerics: deterministic per-op value functions on flat float32
# ---------------------------------------------------------------------------

# unary elementwise ops (the in-place-eligible set plus synonyms); the
# canonical table lives in repro.kernels.arena.elemwise so the fused chain
# kernels apply the exact same jnp callables (bit-equality by construction)
_ELEMWISE: dict[str, Callable] = ELEMWISE_FNS

OpFn = Callable[[Node, list, int], "jnp.ndarray"]


def _fit(x, n: int):
    """Resize a flat vector to ``n`` elements (truncate or tile)."""
    m = x.shape[0]
    if m == n:
        return x
    if m > n or m == 0:
        return jnp.zeros(n, x.dtype) if m == 0 else x[:n]
    reps = -(-n // m)
    return jnp.tile(x, reps)[:n]


def _concat_pad(xs, n: int):
    """Concatenate then zero-pad/truncate to ``n`` elements.

    This is the reference semantics of ``concat``/``concat_view``: the arena
    path realizes it as back-to-back slice-writes plus a zeroed tail, so the
    reference must pad with zeros (never tile)."""
    if not xs:
        return jnp.zeros(n, jnp.float32)
    cc = jnp.concatenate(xs) if len(xs) > 1 else xs[0]
    if cc.shape[0] >= n:
        return cc[:n]
    return jnp.concatenate([cc, jnp.zeros(n - cc.shape[0], cc.dtype)])


def _ramp(uid: int, n: int):
    # per-node positional signature: makes off-by-one-slice bugs visible
    return 0.05 * jnp.cos(jnp.arange(n, dtype=jnp.float32)
                          * (0.37 + 0.013 * (uid % 29)))


def _blend(xs, n: int):
    if not xs:
        return jnp.zeros(n, jnp.float32)
    acc = _fit(xs[0], n)
    for x in xs[1:]:
        acc = acc + _fit(x, n)
    return acc / len(xs)


def _sig(nd: Node) -> int:
    """The node id keying the positional signature.

    Recompute clones (``repro.core.rewriter.rematerialize``) carry their
    original's id as ``recompute_sig`` metadata; using it here makes a
    clone compute bit-for-bit the same value as the node it rematerializes,
    for every op — the executor-side half of the recompute contract.
    """
    for k, v in nd.meta:
        if k == "recompute_sig":
            return int(v)
    return nd.id


def _default_op(nd: Node, xs, n: int):
    acc = _blend(xs, n)
    acc = jnp.tanh(acc + 0.25 * jnp.roll(acc, 1))
    return 0.9 * acc + _ramp(_sig(nd), n)


def _partial_conv_contrib(nd: Node, branch_xs, n: int):
    """The per-branch accumulation step of a rewritten partial conv."""
    t = _blend(branch_xs, n)
    return 0.4 * jnp.tanh(t + 0.25 * jnp.roll(t, 1)) + 0.1 * _ramp(_sig(nd), n)


def _split_accum(nd: Node, invals):
    """(accumulator value or None, branch values) for an accumulating node."""
    acc, branches = None, []
    for p, v in zip(nd.preds, invals):
        if p in nd.alias_preds and acc is None:
            acc = v
        else:
            branches.append(v)
    return acc, branches


def node_value(nd: Node, invals, n: int,
               registry: Mapping[str, OpFn] | None = None):
    """Reference output of ``nd`` given predecessor values (``(n,)`` f32).

    ``registry`` overrides/extends the built-in op table; entries are called
    as ``fn(node, raw_pred_values, n_elements)``.
    """
    if registry is not None and nd.op in registry:
        return registry[nd.op](nd, invals, n)
    if nd.op in ("concat", "concat_view"):
        return _concat_pad(invals, n)
    if nd.op == "partial_conv":
        acc, branches = _split_accum(nd, invals)
        contrib = _partial_conv_contrib(nd, branches, n)
        return contrib if acc is None else acc + contrib
    if nd.op == "add":
        return _blend(invals, n)
    if nd.op in _ELEMWISE and len(invals) == 1:
        return _ELEMWISE[nd.op](_fit(invals[0], n))
    return _default_op(nd, invals, n)


# ---------------------------------------------------------------------------
# Input / output plumbing
# ---------------------------------------------------------------------------


def _elems(nbytes: int, what: str) -> int:
    if nbytes % 4:
        raise ExecutorError(
            f"{what}: size {nbytes} bytes is not float32-aligned (the "
            f"surrogate executor models tensors as 4-byte elements)"
        )
    return nbytes // 4


def input_nodes(g: Graph) -> list[int]:
    return [nd.id for nd in g.nodes if nd.op == "input"]


def _resolve_inputs(g: Graph, inputs) -> dict[int, "jnp.ndarray"]:
    """Accept {name: array}, {node_id: array}, or a sequence in input-node
    id order; returns flat float32 arrays keyed by node id."""
    ids = input_nodes(g)
    by_name = {g.nodes[i].name: i for i in ids}
    out: dict[int, jnp.ndarray] = {}
    if inputs is None:
        inputs = {}
    if isinstance(inputs, Mapping):
        for k, v in inputs.items():
            nid = by_name.get(k, k if isinstance(k, int) else None)
            if nid is None or nid not in ids:
                raise ExecutorError(f"unknown input {k!r}")
            out[nid] = jnp.asarray(v, jnp.float32).reshape(-1)
    else:
        vals = list(inputs)
        if len(vals) != len(ids):
            raise ExecutorError(
                f"graph has {len(ids)} inputs, got {len(vals)}")
        for nid, v in zip(ids, vals):
            out[nid] = jnp.asarray(v, jnp.float32).reshape(-1)
    for nid in ids:
        out.setdefault(nid, _ramp(nid, _elems(g.sizes[nid], g.nodes[nid].name))
                       / 0.05 * 0.3)
    return out


# ---------------------------------------------------------------------------
# Realized-footprint measurement
# ---------------------------------------------------------------------------


class RealizedTracker:
    """Measure the arena from executed events (DESIGN.md §6).

    Feed it each node as it executes (`step(u)`); it activates the node's
    allocation on first touch (the whole chain buffer is reserved from its
    first write) and retires an allocation one step after its last consumer
    executed — exactly the allocator's free-before-alloc event order.  Bytes
    of graph outputs stay resident to the end.

    ``peak_bytes`` is the high-water of summed live allocation sizes;
    ``extent_bytes`` the high-water of ``offset + size`` over live
    allocations.  Both are in bytes and must reproduce the plan's
    ``peak_bytes`` / ``arena_bytes`` when execution follows the planned
    order — the realized-vs-planned invariant.
    """

    def __init__(self, g: Graph, order: Sequence[int], plan: ArenaPlan,
                 steps: Sequence[Sequence[int]] | None = None):
        self._g = g
        sched = set(order)
        horizon = len(order) if steps is None else len(steps)
        self._alloc = {u: plan.allocation_of(u) for u in order}
        self._uses: dict[int, int] = {}
        self._output: dict[int, bool] = {}
        for a in {id(a): a for a in self._alloc.values()}.values():
            uses = 0
            is_out = False
            for m in a.node_ids:
                consumers = [s for s in g.succs[m] if s in sched]
                uses += len(consumers)
                is_out |= not consumers
            self._uses[id(a)] = uses
            # a plan may hold buffers past their last consumer (pinned
            # latency-class plans set t_free beyond the horizon): honor the
            # plan's lifetime, not just graph-output-ness
            self._output[id(a)] = is_out or a.t_free > horizon
        self._active: set[int] = set()
        self._pending_retire: list = []
        self._live = 0
        self.peak_bytes = 0
        self.extent_bytes = 0

    def step(self, u: int) -> None:
        self.step_group((u,))

    def step_group(self, units: Sequence[int]) -> None:
        """One time slot: all of ``units`` execute concurrently.

        Every member's allocation is activated before the slot's peak is
        sampled (co-issued outputs are live together — the step-model
        transient of ``simulate_steps``), and predecessors fully consumed by
        the slot retire at its end, landing before the next slot's allocs.
        """
        # frees scheduled from the previous step land before this alloc
        for a in self._pending_retire:
            self._active.discard(id(a))
            self._live -= a.size
        self._pending_retire = []
        for u in units:
            a = self._alloc[u]
            if id(a) not in self._active:
                self._active.add(id(a))
                self._live += a.size
                self.extent_bytes = max(self.extent_bytes, a.offset + a.size)
        self.peak_bytes = max(self.peak_bytes, self._live)
        for u in units:
            for p in self._g.nodes[u].preds:
                pa = self._alloc.get(p)
                if pa is None:
                    continue
                self._uses[id(pa)] -= 1
                if self._uses[id(pa)] == 0 and not self._output[id(pa)] \
                        and id(pa) in self._active:
                    self._pending_retire.append(pa)


# ---------------------------------------------------------------------------
# Interpreters
# ---------------------------------------------------------------------------


def reference_fn(g: Graph,
                 registry: Mapping[str, OpFn] | None = None) -> Callable:
    """A jit-able closure computing ``g``'s reference outputs.

    Returns ``fn(ext_vals) -> tuple`` mapping a tuple of input-node values
    (input-node id order, flat float32) to the tuple of exit-node values,
    with every intermediate held as its own array — no arena, XLA plans the
    memory.  This is the *unscheduled jit* baseline of
    ``benchmarks/bench_executor.py``; :func:`run_reference` wraps it.
    """
    order = list(g.topo_order())
    nds = g.nodes
    elems = {u: _elems(g.sizes[u], nds[u].name) for u in order}

    def fn(ext_vals):
        env: dict[int, jnp.ndarray] = {}
        it = iter(ext_vals)
        for u in order:
            nd = nds[u]
            if nd.op == "input":
                env[u] = _fit(next(it), elems[u])
            else:
                env[u] = node_value(nd, [env[p] for p in nd.preds],
                                    elems[u], registry)
        return tuple(env[u] for u in g.exits())

    return fn


def run_reference(g: Graph, inputs=None, *,
                  registry: Mapping[str, OpFn] | None = None
                  ) -> dict[str, "jnp.ndarray"]:
    """Plain dict-storage interpreter: the executor's numeric ground truth.

    Runs ``g`` in topological order with every intermediate held as its own
    array (no arena).  Returns ``{node name: flat f32 value}`` for the graph
    outputs (nodes with no consumers).
    """
    ext = _resolve_inputs(g, inputs)
    vals = tuple(ext[u] for u in input_nodes(g))
    outs = reference_fn(g, registry)(vals)
    return {g.nodes[u].name: v for u, v in zip(g.exits(), outs)}


@dataclasses.dataclass
class ExecutionResult:
    """What ``execute_plan`` produced and measured.

    ``outputs`` maps output-node names to their flat float32 values (read
    back from the final arena).  All ``*_bytes`` fields are bytes;
    ``realized_*`` are measured from execution, ``planned_*`` copied from
    the plan.
    """

    outputs: dict[str, "jnp.ndarray"]
    realized_peak_bytes: int
    realized_arena_bytes: int
    planned_peak_bytes: int
    planned_arena_bytes: int
    order: list[int]
    impl: str
    fused: bool = False
    n_regions: int = 0

    @property
    def realized_matches_plan(self) -> bool:
        return (self.realized_peak_bytes == self.planned_peak_bytes
                and self.realized_arena_bytes == self.planned_arena_bytes)


class PlanProgram:
    """A precompiled executable for one ``(graph, order, plan)`` triple.

    Everything derivable from the plan alone is computed once at
    construction — float32 element counts, per-node element offsets, the
    realized peak/extent (the :class:`RealizedTracker` replay is a pure
    function of the schedule), the fused-region decomposition and each
    region's elementwise tail — so calling :meth:`run` only feeds values
    through the arena program.  ``execute_plan`` used to re-derive all of
    this on every call, which dominated on the 274-node full networks; it
    now routes through :func:`compile_plan`, which memoizes instances on
    the plan itself.  The whole-program jit (``jit=True``) is traced once
    per program and reused, arena donated.

    With ``fuse=False`` the program replays the slice-per-node path
    bit-for-bit (one read per predecessor, one write/accumulate per node).
    With ``fuse=True`` each :class:`~repro.core.rewriter.FusedRegion` runs
    as one unit: the running chain value is forwarded in registers from
    member to member (legal because an aliased predecessor has exactly one
    consumer — nothing else ever reads the interior values) and only the
    final member's value is stored, through
    :func:`~repro.kernels.arena.arena_chain_write` when the region tail is
    pure unregistered elementwise (one launch), else a single
    ``arena_write``.  Cross-region edges still round-trip through the
    arena, so the fused path realizes the identical footprint and stays
    bit-equal to ``run_reference`` (DESIGN.md §11).
    """

    def __init__(self, g: Graph, order: Sequence[int], plan: ArenaPlan, *,
                 fuse: bool = False,
                 registry: Mapping[str, OpFn] | None = None,
                 impl: str = "auto", interpret: bool = False,
                 steps: Sequence[Sequence[int]] | None = None):
        self.graph = g
        self.order = list(order)
        self.plan = plan
        self.steps = None if steps is None else tuple(
            tuple(s) for s in steps)
        self.fuse = bool(fuse)
        self.registry = registry
        self.impl = impl
        self.interpret = interpret
        nds = g.nodes
        self._elems = {u: _elems(g.sizes[u], nds[u].name)
                       for u in self.order}
        off = {}
        for u in self.order:
            b = plan.offset_of(u)
            if b % 4:
                raise ExecutorError(
                    f"node {nds[u].name}: planned byte offset {b} is not "
                    f"float32-aligned")
            off[u] = b // 4
        self._off = off
        self.arena_elems = -(-plan.arena_bytes // 4)
        self._input_ids = [u for u in self.order if nds[u].op == "input"]
        self._exit_ids = list(g.exits())

        # rewriter-produced views alias every predecessor; a mixed view has
        # no arena layout for the non-aliased parts — refuse rather than
        # silently diverge from run_reference
        for u in self.order:
            nd = nds[u]
            if nd.op == "concat_view" and nd.alias_preds and \
                    any(p not in nd.alias_preds for p in nd.preds):
                raise ExecutorError(
                    f"concat_view {nd.name}: preds {nd.preds} are not "
                    f"all aliased ({sorted(nd.alias_preds)}); mixed "
                    f"views are not executable")

        # a width-W step schedule executes member ops of one slot against
        # simultaneously-live storage: the plan must place every co-issued
        # slot disjointly (the steps were the plan's lifetime positions)
        if self.steps is not None:
            if [u for s in self.steps for u in s] != self.order:
                raise ExecutorError("steps do not flatten to order")
            for st in self.steps:
                if len(st) < 2:
                    continue
                in_step = set(st)
                spans = []
                for u in st:
                    if set(nds[u].preds) & in_step:
                        raise ExecutorError(
                            f"step {st} is not an antichain: {nds[u].name} "
                            f"reads a co-issued node")
                    a = plan.allocation_of(u)
                    spans.append((a.offset, a.offset + a.size, u, id(a)))
                spans.sort()
                for s0, s1 in zip(spans, spans[1:]):
                    if s1[0] < s0[1] and s1[3] != s0[3]:
                        raise ExecutorError(
                            f"co-issued nodes {nds[s0[2]].name} and "
                            f"{nds[s1[2]].name} overlap in the arena "
                            f"([{s0[0]}, {s0[1]}) vs [{s1[0]}, {s1[1]})); "
                            f"plan the arena with steps= to keep them "
                            f"disjoint")

        # realized footprint is a pure function of (g, order, plan): replay
        # it once here instead of on every execution
        tracker = RealizedTracker(g, self.order, plan, steps=self.steps)
        if self.steps is not None:
            for st in self.steps:
                tracker.step_group(st)
        else:
            for u in self.order:
                tracker.step(u)
        self.realized_peak_bytes = tracker.peak_bytes
        self.realized_arena_bytes = tracker.extent_bytes

        if self.fuse:
            self.regions = fuse_alias_chains(g, self.order, plan)
        else:
            self.regions = [FusedRegion((u,)) for u in self.order]
        # interior members forward their value in registers (no arena write)
        self._interior = {u for r in self.regions for u in r.node_ids[:-1]}
        # collapse schedule-contiguous pure-elementwise chain runs ending at
        # a region tail into one arena_chain_write launch:
        #   {schedule position of run head: (members consumed, ops, tail id)}
        link_next: dict[int, int] = {}
        for r in self.regions:
            for a, b in zip(r.node_ids, r.node_ids[1:]):
                link_next[a] = b
        self._groups: dict[int, tuple[int, tuple[str, ...], int]] = {}
        consumed: set[int] = set()
        for i, u in enumerate(self.order):
            if i in consumed:
                continue
            j, ops = i, []
            while j + 1 < len(self.order):
                nxt = link_next.get(self.order[j])
                if nxt is None or self.order[j + 1] != nxt:
                    break
                nd = nds[nxt]
                if (nd.op not in ELEMWISE_FNS or len(nd.preds) != 1
                        or (registry is not None and nd.op in registry)):
                    break
                ops.append(nd.op)
                j += 1
            if ops and self.order[j] not in self._interior:
                self._groups[i] = (j - i, tuple(ops), self.order[j])
                consumed.update(range(i + 1, j + 1))
        self._jitted = None

    @property
    def n_regions(self) -> int:
        return len(self.regions)

    @property
    def n_fused_nodes(self) -> int:
        """Chain members executed without their own arena write."""
        return sum(len(r) - 1 for r in self.regions)

    # -- program body ------------------------------------------------------

    def _zero_view_tail(self, arena, u):
        # concat_view parts already sit back-to-back inside this buffer: the
        # concat never materializes.  Zero any tail the parts do not cover
        # so the view equals the reference's zero-pad.
        n, covered = self._elems[u], sum(self._elems[p]
                                         for p in self.graph.nodes[u].preds)
        if covered < n:
            arena = arena_write(
                arena, jnp.zeros(n - covered, jnp.float32),
                self._off[u] + covered, impl=self.impl,
                interpret=self.interpret)
        return arena

    def _body_slice(self, arena, ext_it):
        """Slice-per-node: one read per predecessor, one store per node."""
        nds = self.graph.nodes
        elems, off = self._elems, self._off
        impl, interpret, registry = self.impl, self.interpret, self.registry
        for u in self.order:
            nd = nds[u]
            if nd.op == "concat_view" and nd.alias_preds:
                arena = self._zero_view_tail(arena, u)
                continue
            if nd.op == "input":
                arena = arena_write(arena, next(ext_it), off[u], impl=impl,
                                    interpret=interpret)
                continue
            invals = [arena_read(arena, off[p], elems[p], impl=impl,
                                 interpret=interpret) for p in nd.preds]
            if nd.op == "partial_conv" and nd.alias_preds and \
                    (registry is None or nd.op not in registry):
                # in-place accumulation into the (aliased) running output —
                # a true read-modify-write of the shared slice
                branches = [v for p, v in zip(nd.preds, invals)
                            if p not in nd.alias_preds]
                contrib = _partial_conv_contrib(nd, branches, elems[u])
                arena = arena_accum(arena, contrib, off[u], impl=impl,
                                    interpret=interpret)
                continue
            arena = arena_write(arena, node_value(nd, invals, elems[u],
                                                  registry),
                                off[u], impl=impl, interpret=interpret)
        return arena

    def _body_fused(self, arena, ext_it):
        """Fused: chain members forward their value in registers; only the
        region tail stores.  Legal because an aliased predecessor has
        exactly one consumer — the next chain member — so nothing an
        interleaved node does can observe (or clobber: the chain's
        allocation is live throughout) the skipped interior stores.
        Schedule-contiguous pure-elementwise runs ending at a tail execute
        as one ``arena_chain_write`` launch."""
        nds = self.graph.nodes
        elems, off = self._elems, self._off
        impl, interpret, registry = self.impl, self.interpret, self.registry
        order = self.order
        fwd: dict = {}
        i = 0
        while i < len(order):
            u = order[i]
            nd = nds[u]
            if nd.op == "concat_view" and nd.alias_preds:
                arena = self._zero_view_tail(arena, u)
                i += 1
                continue
            if nd.op == "input":
                val = next(ext_it)
            else:
                invals = [fwd[p] if p in fwd
                          else arena_read(arena, off[p], elems[p], impl=impl,
                                          interpret=interpret)
                          for p in nd.preds]
                val = node_value(nd, invals, elems[u], registry)
                for p in nd.preds:
                    fwd.pop(p, None)  # single consumer: value is dead now
            grp = self._groups.get(i)
            if grp is not None:
                m, ops, out = grp
                arena = arena_chain_write(arena, val, off[out], ops,
                                          impl=impl, interpret=interpret)
                i += m + 1
                continue
            if u in self._interior:
                fwd[u] = val
            else:
                arena = arena_write(arena, val, off[u], impl=impl,
                                    interpret=interpret)
            i += 1
        return arena

    def _program(self, arena, ext_flat):
        body = self._body_fused if self.fuse else self._body_slice
        arena = body(arena, iter(ext_flat))
        outs = tuple(arena_read(arena, self._off[u], self._elems[u],
                                impl=self.impl, interpret=self.interpret)
                     for u in self._exit_ids)
        return outs, arena

    # -- entry point -------------------------------------------------------

    def resolve_ext(self, inputs) -> tuple:
        """Flatten/resize user inputs to the program's input tuple."""
        ext = _resolve_inputs(self.graph, inputs)
        return tuple(_fit(ext[u], self._elems[u]) for u in self._input_ids)

    def run(self, inputs=None, *, arena=None, jit: bool = False,
            strict: bool = True) -> ExecutionResult:
        """Execute the program; see :func:`execute_plan` for semantics."""
        plan = self.plan
        ext_vals = self.resolve_ext(inputs)
        if arena is None:
            arena = jnp.zeros(self.arena_elems, jnp.float32)
        elif strict and arena.shape[0] < self.arena_elems:
            raise ExecutorError(
                f"donated arena has {arena.shape[0]} elements "
                f"({arena.shape[0] * 4} bytes) < planned arena_bytes "
                f"{plan.arena_bytes}")
        if strict and (self.realized_peak_bytes != plan.peak_bytes
                       or self.realized_arena_bytes != plan.arena_bytes):
            raise ExecutorError(
                f"realized arena diverges from plan: peak "
                f"{self.realized_peak_bytes} vs planned {plan.peak_bytes}, "
                f"extent {self.realized_arena_bytes} vs planned "
                f"{plan.arena_bytes}")

        if jit:
            if self._jitted is None:
                self._jitted = jax.jit(self._program, donate_argnums=(0,))
            outs, _ = self._jitted(arena, ext_vals)
        else:
            outs, _ = self._program(arena, ext_vals)

        nds = self.graph.nodes
        return ExecutionResult(
            outputs={nds[u].name: v for u, v in zip(self._exit_ids, outs)},
            realized_peak_bytes=self.realized_peak_bytes,
            realized_arena_bytes=self.realized_arena_bytes,
            planned_peak_bytes=plan.peak_bytes,
            planned_arena_bytes=plan.arena_bytes,
            order=list(self.order),
            impl=self.impl,
            fused=self.fuse,
            n_regions=self.n_regions,
        )


_PROGRAM_CACHE_CAP = 8


def compile_plan(
    g: Graph,
    order: Sequence[int],
    plan: ArenaPlan,
    *,
    fuse: bool = False,
    registry: Mapping[str, OpFn] | None = None,
    impl: str = "auto",
    interpret: bool = False,
    steps: Sequence[Sequence[int]] | None = None,
) -> PlanProgram:
    """Build (or fetch) the :class:`PlanProgram` for this plan.

    Programs are memoized on the plan object itself (like its offset
    index), keyed by the schedule and execution options, so repeat
    executions — the decode tick loop, benchmark steady state — skip the
    per-plan precomputation and reuse the cached jit trace.  The cache is
    dropped on pickling (``ArenaPlan.__getstate__``) and capped per plan.
    """
    steps_key = None if steps is None else tuple(tuple(s) for s in steps)
    key = (id(g), tuple(order), bool(fuse), impl, bool(interpret),
           None if registry is None else id(registry), steps_key)
    cache = plan.__dict__.setdefault("_programs", {})
    prog = cache.get(key)
    # ids can be recycled after gc: accept a hit only if it still points at
    # the same live objects
    if prog is not None and prog.graph is g and \
            (registry is None or prog.registry is registry):
        return prog
    prog = PlanProgram(g, order, plan, fuse=fuse, registry=registry,
                       impl=impl, interpret=interpret, steps=steps)
    cache[key] = prog
    while len(cache) > _PROGRAM_CACHE_CAP:
        cache.pop(next(iter(cache)))
    return prog


def execute_plan(
    g: Graph,
    order: Sequence[int],
    plan: ArenaPlan,
    inputs=None,
    *,
    registry: Mapping[str, OpFn] | None = None,
    impl: str = "auto",
    interpret: bool = False,
    arena=None,
    jit: bool = False,
    strict: bool = True,
    fuse: bool = False,
    steps: Sequence[Sequence[int]] | None = None,
) -> ExecutionResult:
    """Run schedule ``order`` of ``g`` against the planned arena.

    Args:
      g: the graph to execute (typically ``SerenityResult.graph`` — i.e.
        post-rewrite, so alias chains are present).
      order: the schedule to execute; must be the order ``plan`` was built
        from (the realized-vs-planned invariant is asserted against it).
      plan: the :class:`ArenaPlan` whose byte offsets place every tensor.
      inputs: input-node values ({name: array}, {node_id: array}, or a
        sequence in input-node order); missing inputs get a deterministic
        per-node default.  Values are flattened to float32.
      registry: optional op-function overrides (see :func:`node_value`).
      impl: arena slice op dispatch — 'auto' (Pallas on TPU, XLA elsewhere;
        ``$REPRO_ARENA_IMPL`` overrides), 'pallas', 'xla', or 'ref'.
      interpret: run Pallas kernels in interpret mode (CPU validation).
      arena: optional donated float32 buffer of at least
        ``plan.arena_bytes / 4`` elements to execute in (reused storage,
        e.g. across decode steps); allocated fresh when ``None``.
      jit: trace the whole arena program into one jitted function with the
        arena buffer donated to XLA (trace cached per plan/options).
      strict: assert the realized-vs-planned invariant and that the arena
        buffer is large enough.
      fuse: execute in-place alias chains as fused regions — value
        forwarding between members, one write (or one chain-kernel launch)
        per region instead of per node (DESIGN.md §11).  Bit-equal to the
        default slice-per-node path.
      steps: optional width-W step schedule (must flatten to ``order``, and
        ``plan`` must have been packed with the same ``steps``).  Values
        still stream through the arena one op at a time — co-issued ops'
        outputs are bit-identical because the plan places them disjointly
        (asserted) — but the realized footprint is replayed in step groups,
        so the realized-vs-planned invariant checks the *concurrent* peak
        (DESIGN.md §12).

    Returns:
      :class:`ExecutionResult` with output values and the measured
      realized peak/extent bytes.
    """
    return compile_plan(g, order, plan, fuse=fuse, registry=registry,
                        impl=impl, interpret=interpret, steps=steps).run(
        inputs, arena=arena, jit=jit, strict=strict)


# ---------------------------------------------------------------------------
# Real-tensor arena packing (serving state)
# ---------------------------------------------------------------------------


def _to_bytes(x) -> "jnp.ndarray":
    """Flatten any (non-bool) array to its raw little-endian uint8 bytes."""
    x = jnp.asarray(x)
    if x.dtype == jnp.bool_:
        raise ExecutorError("bool tensors cannot be arena-packed")
    # bitcast appends an itemsize axis for multi-byte dtypes (none for u8)
    return jax.lax.bitcast_convert_type(x.reshape(-1),
                                        jnp.uint8).reshape(-1)


def _from_bytes(b, shape, dtype) -> "jnp.ndarray":
    """Rebuild an array of ``shape``/``dtype`` from its raw bytes."""
    dtype = jnp.dtype(dtype)
    if dtype.itemsize == 1:
        return jax.lax.bitcast_convert_type(b, dtype).reshape(shape)
    return jax.lax.bitcast_convert_type(
        b.reshape(-1, dtype.itemsize), dtype).reshape(shape)


def pack_buffers(plan: ArenaPlan, arrays: Mapping[int, "jnp.ndarray"], *,
                 arena=None, impl: str = "auto",
                 jit: bool = True) -> "jnp.ndarray":
    """Pack real tensors into one uint8 arena at their planned byte offsets.

    ``arrays`` maps node ids (of the graph the plan was built from) to
    arbitrarily shaped/dtyped tensors; each must fit the node's planned
    span in bytes.  Returns the (donatable) uint8 arena of
    ``plan.arena_bytes`` bytes.  The pack loop is jitted with the arena
    donated by default, so XLA fuses it into one in-place pack instead of
    copying the whole arena once per tensor.  Used by the serving driver to
    realize the decode-state plan (DESIGN.md §1/§6).
    """
    items = sorted(arrays.items())
    for nid, x in items:
        a = plan.allocation_of(nid)
        span = a.size - a.intra.get(nid, 0)
        nbytes = int(np.prod(jnp.shape(x))) * jnp.dtype(
            jnp.result_type(x)).itemsize
        if nbytes > span:
            raise ExecutorError(
                f"node {nid}: {nbytes} bytes exceed planned span {span}")

    def _pack(arena, vals):
        for (nid, _), x in zip(items, vals):
            arena = arena_write(arena, _to_bytes(x), plan.offset_of(nid),
                                impl=impl)
        return arena

    if arena is None:
        arena = jnp.zeros(plan.arena_bytes, jnp.uint8)
    vals = tuple(x for _, x in items)
    if jit:
        return jax.jit(_pack, donate_argnums=(0,))(arena, vals)
    return _pack(arena, vals)


def unpack_buffer(arena, plan: ArenaPlan, node_id: int, shape, dtype, *,
                  impl: str = "auto") -> "jnp.ndarray":
    """Read one planned tensor back out of a uint8 arena."""
    nbytes = int(np.prod(shape)) * jnp.dtype(dtype).itemsize
    b = arena_read(arena, plan.offset_of(node_id), nbytes, impl=impl)
    return _from_bytes(b, shape, dtype)
