"""Content-addressed plan cache for SERENITY scheduling results.

Scheduling is a pure function of the graph *structure* — node shapes/sizes,
ops and wiring — never of the node labels an importer happened to assign.
This module therefore addresses cached plans by a canonical graph hash:

``canonical_hash(g)``
    Weisfeiler–Lehman-style color refinement over the scheduling-relevant
    node payload (op, output bytes, weight bytes, meta/shape entries, alias
    structure) and the edge wiring.  Two graphs that differ only by a node
    relabeling hash identically; changing any shape, size or edge changes
    the hash.

``labeled_fingerprint(g)``
    Exact hash of the concrete labeled graph.  Used as the second key tier
    so a cache hit hands back a plan whose node ids are valid verbatim for
    the requesting graph.  Note the consequence: a *relabeled* isomorphic
    graph shares the canonical address but does not hit — translating a
    cached plan across labelings is future work; today the canonical tier
    buys address stability (same bucket, dedup-friendly disk names), not
    cross-labeling reuse.

``PlanCache``
    Two-tier memo: an in-process LRU (a hit on a live graph is O(1) — the
    content hashes are memoized on the instance and the stored plan is
    returned zero-copy) and an optional on-disk pickle store shared across
    processes.  Cached plans are shared objects: treat them as immutable.

The default process-wide cache is wired through
:func:`repro.core.serenity.plan`, :mod:`repro.core.jax_bridge` and
``repro.launch.serve``; set the ``REPRO_PLANCACHE_DIR`` environment variable
to also persist plans across processes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import struct
import threading
import zlib
from collections import OrderedDict
from typing import Any, Callable

from repro.core.graph import Graph

_ENV_DIR = "REPRO_PLANCACHE_DIR"


# ---------------------------------------------------------------------------
# Canonical graph hashing
# ---------------------------------------------------------------------------


def _node_payload(g: Graph, u: int) -> tuple:
    nd = g.nodes[u]
    return (nd.op, nd.size_bytes, nd.weight_bytes, nd.meta)


_M64 = (1 << 64) - 1
_FNV = 1099511628211


def _fold(salt: int, values) -> int:
    """Order-sensitive 64-bit fold (callers sort first for multisets)."""
    h = salt
    for x in values:
        h = ((h * _FNV) ^ x) & _M64
        h = (h ^ (h >> 29)) * 0xBF58476D1CE4E5B9 & _M64   # splitmix64 finalize
    return h


def wl_colors(g: Graph) -> list[int]:
    """Per-node Weisfeiler–Lehman colors over the scheduling payload.

    Initial colors come from sha256 of the node payload (op, sizes, meta —
    *not* names), refinement mixes the sorted neighbor color multisets with
    64-bit integer arithmetic (no per-node hashing in the loop — the
    refinement is the hot path for cache lookups).  Isomorphic relabelings
    produce the same color multiset; nodes distinguished by structure get
    distinct colors, which is what :func:`translate_order` keys on.
    """
    n = len(g)
    payload_color: dict[bytes, int] = {}
    colors = []
    for u in range(n):
        key = repr(_node_payload(g, u)).encode()
        c = payload_color.get(key)
        if c is None:
            c = int.from_bytes(hashlib.sha256(key).digest()[:8], "big")
            payload_color[key] = c
        colors.append(c)
    succs = g.succs
    for _ in range(max(1, n.bit_length())):
        nxt = [
            _fold(0xA5, (
                colors[u],
                _fold(0xB7, sorted(colors[p] for p in g.nodes[u].preds)),
                _fold(0xC9, sorted(colors[s] for s in succs[u])),
                _fold(0xD1, sorted(colors[p] for p in g.nodes[u].alias_preds)),
            ))
            for u in range(n)
        ]
        if nxt == colors:
            break
        colors = nxt
    return colors


def canonical_hash(g: Graph) -> str:
    """Label-invariant content hash of the scheduling-relevant structure.

    sha256 over the sorted WL color multiset (:func:`wl_colors`) plus edge
    color pairs.  Isomorphic relabelings hash equal; any shape/size/op/edge
    change does not.
    """
    n = len(g)
    colors = wl_colors(g)
    acc = hashlib.sha256()
    acc.update(f"n={n}".encode())
    for c in sorted(colors):
        acc.update(c.to_bytes(8, "big"))
    for cu, cv in sorted(
        (colors[p], colors[nd.id]) for nd in g.nodes for p in nd.preds
    ):
        acc.update(cu.to_bytes(8, "big") + cv.to_bytes(8, "big"))
    return acc.hexdigest()


def labeled_fingerprint(g: Graph) -> str:
    """Exact content hash of the labeled graph (ids, names, wiring, sizes)."""
    acc = hashlib.sha256()
    acc.update(repr(len(g)).encode())
    for nd in g.nodes:
        acc.update(repr((
            nd.id, nd.name, nd.op, nd.size_bytes, nd.weight_bytes,
            nd.preds, tuple(sorted(nd.alias_preds)), nd.meta,
        )).encode())
    return acc.hexdigest()


def translate_order(src: Graph, dst: Graph, order: list[int]) -> list[int] | None:
    """Map a schedule of ``src`` onto the isomorphic-but-relabeled ``dst``.

    The WL colors (:func:`wl_colors`) of both graphs are compared; when the
    refinement individualizes every node (all color classes are singletons)
    the node bijection is forced, and after verifying it really is an
    isomorphism (pred and alias sets map exactly — WL equality alone is
    necessary, not sufficient) the order is rewritten through it.  Returns
    ``None`` when the graphs aren't color-equivalent or the cell is too
    symmetric to individualize — callers fall back to rescheduling.

    This is what turns the plan cache's canonical (WL) tier into real
    cross-labeling reuse for repeated network cells (DESIGN.md §8).
    """
    n = len(src)
    if n != len(dst):
        return None
    cs, cd = wl_colors(src), wl_colors(dst)
    if sorted(cs) != sorted(cd):
        return None
    by_color: dict[int, int] = {}
    for u, c in enumerate(cd):
        if c in by_color:
            return None          # symmetric cell: bijection not forced
        by_color[c] = u
    mapping = [by_color[c] for c in cs]          # src id -> dst id
    for u in range(n):                           # verify the isomorphism
        su, du = src.nodes[u], dst.nodes[mapping[u]]
        if sorted(mapping[p] for p in su.preds) != sorted(du.preds):
            return None
        if {mapping[p] for p in su.alias_preds} != set(du.alias_preds):
            return None
        if su.size_bytes != du.size_bytes or su.op != du.op:
            return None
    return [mapping[u] for u in order]


# Bump whenever the *shape* of cached payloads changes (new plan fields,
# different tuple layouts...): folded into every options key, so stale disk
# entries from older code become clean misses instead of poison.
SCHEMA_VERSION = 7   # 5: PlanConfig-keyed plans, recompute-expanded graphs
                     # 6: pareto plans (Plan.steps/makespan/schedule_frontier,
                     #    ScheduleResult.makespan/width, PlanConfig.objective/
                     #    max_width/latency_budget)
                     # 7: CRC32-framed disk blobs (DESIGN.md §13)


def _options_key(options: Any) -> str:
    return hashlib.sha256(
        repr((SCHEMA_VERSION, options)).encode()
    ).hexdigest()[:16]


# Disk-blob frame (DESIGN.md §13): magic + writer schema + CRC32 of the
# pickle payload, prepended to every on-disk entry.  Disk corruption —
# truncated writes, garbage bytes, bit rot — is thereby *detected*
# (``CacheStats.corrupt``) and the entry evicted, instead of being
# silently swallowed by a bare ``pickle.loads`` except clause.  The schema
# field catches the one corruption CRC cannot: an intact blob written by a
# different code version landing at a current key path.
_BLOB_MAGIC = b"RPLN"
_BLOB_HEADER = struct.Struct("<4sII")     # magic, schema, crc32(payload)


def frame_blob(payload: bytes) -> bytes:
    """Wrap a pickle payload in the CRC32 disk frame."""
    return _BLOB_HEADER.pack(_BLOB_MAGIC, SCHEMA_VERSION,
                             zlib.crc32(payload)) + payload


def unframe_blob(blob: bytes) -> bytes | None:
    """Validate + strip the disk frame; ``None`` on any corruption
    (short/truncated blob, bad magic, stale schema, CRC mismatch)."""
    if len(blob) < _BLOB_HEADER.size:
        return None
    magic, schema, crc = _BLOB_HEADER.unpack_from(blob)
    payload = blob[_BLOB_HEADER.size:]
    if magic != _BLOB_MAGIC or schema != SCHEMA_VERSION \
            or zlib.crc32(payload) != crc:
        return None
    return payload


# ---------------------------------------------------------------------------
# The cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    disk_hits: int = 0
    puts: int = 0
    corrupt: int = 0     # disk entries the CRC frame rejected (and evicted)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlanCache:
    """Two-tier (memory LRU + optional disk) content-addressed plan store.

    Keys are ``(canonical_hash(g), options, labeled_fingerprint(g))`` — the
    canonical tier makes isomorphic graphs share an address, the labeled
    tier guarantees a returned plan's node ids are valid for the caller's
    graph verbatim.  Payloads may be any picklable object (a
    ``SerenityResult``, a bare order, an arena plan...).
    """

    def __init__(self, capacity: int = 256, disk_dir: str | None = None,
                 blob_hook: Callable[[bytes], bytes] | None = None):
        self.capacity = capacity
        self.disk_dir = disk_dir
        # fault-injection seam (DESIGN.md §13): every disk blob passes
        # through the hook before unframing, so the chaos suite can inject
        # bit flips (ChaosController.corrupt_blob) without monkeypatching
        self.blob_hook = blob_hook
        self.stats = CacheStats()
        self._mem: OrderedDict[tuple[str, str, str], Any] = OrderedDict()
        # canonical tier: (canonical, options) -> most recent full key, so
        # isomorphic-but-relabeled graphs can find *a* stored plan to
        # translate (memory tier only; validated against _mem on lookup)
        self._canon: dict[tuple[str, str], tuple[str, str, str]] = {}
        self._lock = threading.Lock()

    # -- keys ---------------------------------------------------------------

    def key_for(self, g: Graph, options: Any = ()) -> tuple[str, str, str]:
        # graphs are immutable, so the content hashes are memoized on the
        # instance — repeat lookups for a live graph are O(1)
        gk = g.__dict__.get("_plancache_key")
        if gk is None:
            gk = (canonical_hash(g), labeled_fingerprint(g))
            g._plancache_key = gk
        return (gk[0], _options_key(options), gk[1])

    # -- lookup / insert ----------------------------------------------------

    def get(self, g: Graph, options: Any = ()) -> Any | None:
        key = self.key_for(g, options)
        with self._lock:
            if key in self._mem:
                self._mem.move_to_end(key)
                self.stats.hits += 1
                return self._mem[key]
        blob = self._disk_read(key)
        if blob is not None:
            if self.blob_hook is not None:
                blob = self.blob_hook(blob)
            payload_bytes = unframe_blob(blob)
            ok = False
            payload = None
            if payload_bytes is not None:
                try:
                    payload = pickle.loads(payload_bytes)
                    ok = True
                except Exception:
                    ok = False       # CRC-valid frame, unpicklable payload
            if ok:
                with self._lock:
                    self._mem_put(key, payload)
                    self.stats.hits += 1
                    self.stats.disk_hits += 1
                return payload
            # corrupt/stale entry (truncated write, garbage bytes, older
            # schema): count it, evict it, and fall through to a clean miss
            with self._lock:
                self.stats.corrupt += 1
            self._disk_evict(key)
        with self._lock:
            self.stats.misses += 1
        return None

    def get_canonical(self, g: Graph, options: Any = ()) -> Any | None:
        """A stored payload for *any* graph isomorphic to ``g`` (same
        canonical hash, same options) — node ids inside it refer to the
        graph it was stored for; callers translate (see
        :func:`translate_order`).  Returns ``None`` on miss; never counts
        toward hit/miss stats (it's a secondary, best-effort tier)."""
        key = self.key_for(g, options)
        with self._lock:
            full = self._canon.get((key[0], key[1]))
            if full is None or full == key:
                return None
            payload = self._mem.get(full)
            if payload is None:
                self._canon.pop((key[0], key[1]), None)   # evicted: drop
                return None
            self._mem.move_to_end(full)
            return payload

    def put(self, g: Graph, options: Any, payload: Any) -> None:
        key = self.key_for(g, options)
        with self._lock:
            self._mem_put(key, payload)
            self._canon[(key[0], key[1])] = key
            self.stats.puts += 1
        if self.disk_dir:
            self._disk_write(key, frame_blob(pickle.dumps(payload)))

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._canon.clear()
            self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._mem)

    # -- internals ----------------------------------------------------------

    def _mem_put(self, key: tuple[str, str, str], payload: Any) -> None:
        self._mem[key] = payload
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)

    def _disk_path(self, key: tuple[str, str, str]) -> str | None:
        if not self.disk_dir:
            return None
        return os.path.join(
            self.disk_dir, f"{key[0][:24]}-{key[1]}-{key[2][:24]}.plan.pkl"
        )

    def _disk_read(self, key: tuple[str, str, str]) -> bytes | None:
        path = self._disk_path(key)
        if path is None or not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                return f.read()
        except OSError:
            return None

    def _disk_evict(self, key: tuple[str, str, str]) -> None:
        path = self._disk_path(key)
        if path is not None:
            try:
                os.remove(path)
            except OSError:
                pass

    def _disk_write(self, key: tuple[str, str, str], blob: bytes) -> None:
        path = self._disk_path(key)
        if path is None:
            return
        try:
            os.makedirs(self.disk_dir, exist_ok=True)
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "wb") as f:
                f.write(blob)
            os.replace(tmp, path)   # atomic publish, safe across processes
        except OSError:
            pass                    # disk tier is best-effort


# ---------------------------------------------------------------------------
# Process-wide default cache
# ---------------------------------------------------------------------------

_default_cache: PlanCache | None = None
_default_lock = threading.Lock()


def default_cache() -> PlanCache:
    """The process-wide plan cache (disk tier from ``$REPRO_PLANCACHE_DIR``)."""
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = PlanCache(
                disk_dir=os.environ.get(_ENV_DIR) or None
            )
        return _default_cache


def configure_default(cache: PlanCache | None) -> None:
    """Replace the process-wide cache (``None`` resets to a fresh one)."""
    global _default_cache
    with _default_lock:
        _default_cache = cache


def resolve(cache: "PlanCache | bool | None") -> PlanCache | None:
    """Map a user-facing cache argument to a PlanCache (or None = disabled)."""
    if cache is True:
        return default_cache()
    if cache is False or cache is None:
        return None
    return cache
