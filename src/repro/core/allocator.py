"""TFLite-style linear memory arena (simple_memory_arena reimplementation).

The paper's evaluation (Fig. 12a) measures footprint *through the allocator*:
tensors get byte offsets in one linear arena; the arena's high watermark is
the reported peak.  TFLite's ``SimpleMemoryArena`` allocates in execution
order with first-fit-by-offset against the currently live allocations; we
reproduce that policy (plus an optional best-fit variant) on the live
intervals implied by a schedule.

Alias chains (in-place rewiring from the graph rewriter) share one buffer:
the union of the members' live intervals, sized by the largest member.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

from repro.core.graph import Graph


@dataclasses.dataclass
class Allocation:
    node_ids: list[int]       # members of the alias chain sharing this buffer
    offset: int
    size: int
    t_alloc: int              # schedule index of first allocation
    t_free: int               # schedule index after last use (exclusive)


@dataclasses.dataclass
class ArenaPlan:
    allocations: list[Allocation]
    arena_bytes: int          # high watermark == required arena size

    def offset_of(self, node_id: int) -> int:
        for a in self.allocations:
            if node_id in a.node_ids:
                return a.offset
        raise KeyError(node_id)


def plan_arena(
    g: Graph,
    order: Sequence[int],
    preplaced: Sequence[int] = (),
    policy: Literal["first_fit", "best_fit"] = "first_fit",
) -> ArenaPlan:
    n = len(g)
    pos = {u: i for i, u in enumerate(order)}
    for p in preplaced:
        pos[p] = -1

    # --- union alias chains into storage roots --------------------------------
    root = list(range(n))

    def find(x: int) -> int:
        while root[x] != x:
            root[x] = root[root[x]]
            x = root[x]
        return x

    for u in order:
        for p in g.nodes[u].alias_preds:
            root[find(p)] = find(u)

    members: dict[int, list[int]] = {}
    for u in list(preplaced) + list(order):
        members.setdefault(find(u), []).append(u)

    # --- live interval per storage root ---------------------------------------
    horizon = len(order)
    items: list[Allocation] = []
    for r, mem in members.items():
        t_alloc = min(pos[m] for m in mem)
        last_use = t_alloc
        is_output = False
        for m in mem:
            consumers = [s for s in g.succs[m] if s in pos]
            if not consumers:
                is_output = True
            for s in consumers:
                last_use = max(last_use, pos[s])
        t_free = horizon + 1 if is_output else last_use + 1
        size = max(g.sizes[m] for m in mem)
        items.append(Allocation([*sorted(mem)], -1, size, t_alloc, t_free))

    # --- allocate in schedule order against live set ---------------------------
    items.sort(key=lambda a: (a.t_alloc, -a.size))
    live: list[Allocation] = []
    watermark = 0
    for it in items:
        live = [a for a in live if a.t_free > it.t_alloc]
        gaps = sorted(live, key=lambda a: a.offset)
        candidates: list[int] = []
        cursor = 0
        for a in gaps:
            if a.offset - cursor >= it.size:
                candidates.append(cursor)
            cursor = max(cursor, a.offset + a.size)
        candidates.append(cursor)
        if policy == "first_fit":
            it.offset = candidates[0]
        else:  # best_fit: tightest gap
            def gap_len(off: int) -> int:
                following = [a.offset for a in gaps if a.offset >= off + it.size]
                return (min(following) - off) if following else 1 << 60
            it.offset = min(candidates, key=gap_len)
        live.append(it)
        watermark = max(watermark, it.offset + it.size)
    return ArenaPlan(allocations=items, arena_bytes=watermark)
