"""Offset allocation: packing tensor lifetimes into one linear arena.

The paper's evaluation (Fig. 12a) measures footprint *through the allocator*:
tensors get byte offsets in one linear arena; the arena's high watermark is
the reported peak.  The DP scheduler optimizes the liveness-sum peak
(``peak_bytes``), but the bytes an edge device actually reserves are the
allocator watermark (``arena_bytes``) — fragmentation can push the latter
above the former, so the planner here runs several placement policies per
graph and keeps the tightest plan (DESIGN.md §5):

``first_fit``
    TFLite's ``SimpleMemoryArena``: allocate in schedule order at the lowest
    offset that fits between currently live allocations.
``best_fit`` / ``best_fit_coalesce``
    Allocate in schedule order into the tightest free gap (free gaps
    coalesce as neighbours die); falls back to the arena top when no gap
    fits.
``greedy_by_size``
    TFLite's ``GreedyBySizeMemoryPlanner``: place buffers in decreasing size
    order, each at the lowest offset that overlaps no temporally-conflicting
    already-placed buffer.  Usually the tightest heuristic; O(n^2), so
    ``plan_arena_best`` skips it above ``_GREEDY_BY_SIZE_MAX`` buffers.
``best``
    All of the above (plus exhaustive search on tiny plans) — keep the
    smallest arena.

The schedule-order policies run as an event-driven sweep over lifetime
intervals: a heap of expiry times retires dead allocations into a sorted,
coalescing free-gap list, so each placement costs O(log n + live gaps)
instead of the former rebuild-and-sort over the whole live set.  That is
what makes planning a 10k-buffer serving arena a milliseconds affair (see
``bench_scheduling_time``'s arena rows).

Alias chains (in-place rewiring from the graph rewriter and the elementwise
in-place pass) share one buffer: the union of the members' live intervals,
sized by the largest member.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
from typing import Literal, Sequence

from repro.core.graph import Graph

_GREEDY_BY_SIZE_MAX = 4096     # above this, greedy_by_size's O(n^2) is skipped
_EXHAUSTIVE_MAX = 6            # permutation search bound for tiny plans

Policy = Literal[
    "first_fit", "best_fit", "best_fit_coalesce", "greedy_by_size", "best"
]


@dataclasses.dataclass
class Allocation:
    """One packed buffer: an alias chain's shared storage inside the arena.

    All fields are in *bytes* (offsets/sizes) or *schedule indices* (times).

    ``intra`` maps a member node id to its byte delta inside this buffer:
    members of an accumulating/in-place chain overwrite the buffer verbatim
    (delta 0), while ``concat_view`` parts live back-to-back at cumulative
    deltas in the view's predecessor order.  ``Allocation.offset + intra[n]``
    is therefore the exact first byte of node ``n``'s output — the address
    the executor reads and writes (DESIGN.md §6).
    """

    node_ids: list[int]       # members of the alias chain sharing this buffer
    offset: int
    size: int
    t_alloc: int              # schedule index of first allocation
    t_free: int               # schedule index after last use (exclusive)
    intra: dict[int, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ArenaPlan:
    allocations: list[Allocation]
    arena_bytes: int          # high watermark == required arena size
    policy: str = "first_fit"
    peak_bytes: int = 0       # max overlapped live bytes: packing lower bound

    def offset_of(self, node_id: int) -> int:
        """Exact byte offset of ``node_id``'s output storage in the arena.

        Alias-aware: for a node that shares its chain's buffer this is the
        chain offset plus the node's intra-buffer delta (0 for in-place
        members, the cumulative slice start for ``concat_view`` parts), so
        the executor can address every tensor — including the parts of a
        never-materialized concat — directly.  Raises ``KeyError`` for a
        node id absent from the plan.
        """
        self._ensure_index()
        a = self._index[node_id]
        return a.offset + a.intra.get(node_id, 0)

    def allocation_of(self, node_id: int) -> Allocation:
        """The (possibly shared) :class:`Allocation` backing ``node_id``."""
        self._ensure_index()
        return self._index[node_id]

    def _ensure_index(self) -> None:
        if self.__dict__.get("_index") is None:
            index = {}
            for a in self.allocations:
                for nid in a.node_ids:
                    index[nid] = a
            self._index = index

    def __getstate__(self):
        # derived caches: the offset index is cheap to rebuild, and compiled
        # executor programs (repro.core.executor.compile_plan memoizes them
        # on the plan) hold jitted closures that must never hit the plan
        # cache's pickled disk tier
        state = dict(self.__dict__)
        state.pop("_index", None)
        state.pop("_programs", None)
        return state

    @property
    def frag_ratio(self) -> float:
        """arena_bytes / peak_bytes — 1.0 means a fragmentation-free packing."""
        return self.arena_bytes / max(self.peak_bytes, 1)


# ---------------------------------------------------------------------------
# Lifetime intervals
# ---------------------------------------------------------------------------


def _build_items(
    g: Graph,
    order: Sequence[int],
    preplaced: Sequence[int],
    steps: Sequence[Sequence[int]] | None = None,
) -> list[Allocation]:
    """Alias-chain-merged lifetime intervals, in schedule-allocation order.

    With ``steps`` (a width-W step schedule whose flattening is ``order``),
    lifetimes are in *step* indices: co-issued nodes share ``t_alloc``, so
    every packing policy necessarily places their outputs disjointly — the
    arena-level meaning of concurrency (DESIGN.md §12).
    """
    n = len(g)
    if steps is not None:
        pos = {u: si for si, step in enumerate(steps) for u in step}
        if [u for step in steps for u in step] != list(order):
            raise ValueError("steps do not flatten to order")
    else:
        pos = {u: i for i, u in enumerate(order)}
    for p in preplaced:
        pos[p] = -1

    # union alias chains into storage roots
    root = list(range(n))

    def find(x: int) -> int:
        while root[x] != x:
            root[x] = root[root[x]]
            x = root[x]
        return x

    for u in order:
        for p in g.nodes[u].alias_preds:
            root[find(p)] = find(u)

    members: dict[int, list[int]] = {}
    for u in list(preplaced) + list(order):
        members.setdefault(find(u), []).append(u)

    horizon = len(order) if steps is None else len(steps)
    items: list[Allocation] = []
    for mem in members.values():
        t_alloc = min(pos[m] for m in mem)
        last_use = t_alloc
        is_output = False
        for m in mem:
            consumers = [s for s in g.succs[m] if s in pos]
            if not consumers:
                is_output = True
            for s in consumers:
                last_use = max(last_use, pos[s])
        t_free = horizon + 1 if is_output else last_use + 1
        size = max(g.sizes[m] for m in mem)
        items.append(Allocation([*sorted(mem)], -1, size, t_alloc, t_free,
                                intra=_chain_intra_offsets(g, mem, pos)))
    items.sort(key=lambda a: (a.t_alloc, -a.size, a.node_ids))
    return items


def _chain_intra_offsets(
    g: Graph, members: list[int], pos: dict[int, int]
) -> dict[int, int]:
    """Byte deltas of each chain member inside the shared buffer.

    Walking members in reverse schedule order, the chain's final node sits at
    delta 0; an in-place/accumulating alias inherits its consumer's delta
    (same bytes, overwritten), and ``concat_view`` parts are laid out
    back-to-back in the view's predecessor order starting at the view's own
    delta — which is what lets rewritten graphs execute the concat as pure
    slice-writes, never materializing it.
    """
    if len(members) <= 1:
        return {}
    intra: dict[int, int] = {}
    for m in sorted(members, key=lambda u: pos[u], reverse=True):
        base = intra.setdefault(m, 0)
        nd = g.nodes[m]
        if nd.op == "concat_view":
            cum = 0
            for p in nd.preds:
                if p in nd.alias_preds:
                    intra[p] = base + cum
                    cum += g.sizes[p]
        else:
            for p in nd.alias_preds:
                intra[p] = base
    return intra


def _interval_peak(items: Sequence[Allocation]) -> int:
    """Max overlapped live bytes — the lower bound any packing must respect.

    Frees at time t happen before allocations at t (matching the placement
    policies, which retire ``t_free <= t_alloc`` before placing).
    """
    events: list[tuple[int, int, int]] = []
    for it in items:
        events.append((it.t_alloc, 1, it.size))    # frees (kind 0) sort first
        events.append((it.t_free, 0, -it.size))
    events.sort()
    live = peak = 0
    for _, _, delta in events:
        live += delta
        peak = max(peak, live)
    return peak


# ---------------------------------------------------------------------------
# Schedule-order policies: event-driven sweep over a coalescing free list
# ---------------------------------------------------------------------------


class _GapList:
    """Sorted, coalescing free-gap list below a movable arena top.

    Bytes in ``[0, top)`` are either inside a gap or occupied; everything at
    and above ``top`` is free.  Freeing the block just below ``top`` lowers
    ``top`` (after coalescing with an adjacent gap).
    """

    def __init__(self) -> None:
        self.off: list[int] = []      # gap start offsets, sorted
        self.len: list[int] = []      # parallel gap lengths
        self.top = 0

    def free(self, offset: int, size: int) -> None:
        i = bisect.bisect_left(self.off, offset)
        # coalesce with left neighbour
        if i > 0 and self.off[i - 1] + self.len[i - 1] == offset:
            i -= 1
            self.len[i] += size
        else:
            self.off.insert(i, offset)
            self.len.insert(i, size)
        # coalesce with right neighbour
        if i + 1 < len(self.off) and \
                self.off[i] + self.len[i] == self.off[i + 1]:
            self.len[i] += self.len[i + 1]
            del self.off[i + 1], self.len[i + 1]
        # retire into the open top region
        if self.off[i] + self.len[i] == self.top:
            self.top = self.off[i]
            del self.off[i], self.len[i]

    def place(self, size: int, tight: bool) -> int:
        """Claim ``size`` bytes: first fitting gap (or tightest, if asked)."""
        pick = -1
        if tight:
            best_len = -1
            for i, ln in enumerate(self.len):
                if ln >= size and (best_len < 0 or ln < best_len):
                    pick, best_len = i, ln
        else:
            for i, ln in enumerate(self.len):
                if ln >= size:
                    pick = i
                    break
        if pick < 0:
            offset = self.top
            self.top += size
            return offset
        offset = self.off[pick]
        if self.len[pick] == size:
            del self.off[pick], self.len[pick]
        else:
            self.off[pick] += size
            self.len[pick] -= size
        return offset


def _sweep_pack(items: Sequence[Allocation], tight: bool) -> int:
    """Place ``items`` (schedule order) via the event-driven gap sweep."""
    gaps = _GapList()
    expiry: list[tuple[int, int, int]] = []      # (t_free, offset, size)
    watermark = 0
    for it in items:
        while expiry and expiry[0][0] <= it.t_alloc:
            _, off, sz = heapq.heappop(expiry)
            gaps.free(off, sz)
        it.offset = gaps.place(it.size, tight)
        heapq.heappush(expiry, (it.t_free, it.offset, it.size))
        watermark = max(watermark, it.offset + it.size)
    return watermark


def _greedy_by_size_pack(items: Sequence[Allocation]) -> int:
    """TFLite greedy-by-size: biggest buffers first, first fit by offset
    against temporally-conflicting placed buffers."""
    by_size = sorted(
        range(len(items)),
        key=lambda i: (-items[i].size, items[i].t_alloc, items[i].node_ids),
    )
    placed_off: list[int] = []          # offsets of placed items, sorted
    placed: list[Allocation] = []       # parallel to placed_off
    watermark = 0
    for i in by_size:
        it = items[i]
        cursor = 0
        offset = None
        for a in placed:
            if a.t_free <= it.t_alloc or it.t_free <= a.t_alloc:
                continue                 # no lifetime overlap
            if a.offset - cursor >= it.size:
                offset = cursor
                break
            cursor = max(cursor, a.offset + a.size)
        it.offset = cursor if offset is None else offset
        j = bisect.bisect_left(placed_off, it.offset)
        placed_off.insert(j, it.offset)
        placed.insert(j, it)
        watermark = max(watermark, it.offset + it.size)
    return watermark


def _exhaustive_pack(items: Sequence[Allocation], stop_at: int) -> int:
    """Best watermark over all placement orders (tiny plans only).

    Each permutation is packed conflict-first-fit (the greedy_by_size
    placement rule under an arbitrary order).  Early-exits when ``stop_at``
    (the interval peak — unbeatable) is reached.  Offsets of ``items`` hold
    the best packing found on return.
    """
    k = len(items)
    best = None
    best_offsets = [0] * k
    for perm in itertools.permutations(range(k)):
        placed: list[Allocation] = []
        watermark = 0
        for i in perm:
            it = items[i]
            cursor = 0
            offset = None
            for a in sorted(placed, key=lambda a: a.offset):
                if a.t_free <= it.t_alloc or it.t_free <= a.t_alloc:
                    continue
                if a.offset - cursor >= it.size:
                    offset = cursor
                    break
                cursor = max(cursor, a.offset + a.size)
            it.offset = cursor if offset is None else offset
            placed.append(it)
            watermark = max(watermark, it.offset + it.size)
            if best is not None and watermark >= best:
                break
        else:
            if best is None or watermark < best:
                best = watermark
                best_offsets = [it.offset for it in items]
                if best <= stop_at:
                    break
    for it, off in zip(items, best_offsets):
        it.offset = off
    return best if best is not None else 0


_PACKERS = {
    "first_fit": lambda items: _sweep_pack(items, tight=False),
    "best_fit": lambda items: _sweep_pack(items, tight=True),
    "greedy_by_size": _greedy_by_size_pack,
}
# documented synonym: the sweep's free gaps always coalesce, so best_fit
# *is* best_fit_coalesce
_ALIASES = {"best_fit_coalesce": "best_fit"}


def _packer_for(policy: str):
    try:
        return _PACKERS[_ALIASES.get(policy, policy)]
    except KeyError:
        raise ValueError(f"unknown arena policy {policy!r}") from None


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def plan_arena(
    g: Graph,
    order: Sequence[int],
    preplaced: Sequence[int] = (),
    policy: Policy = "first_fit",
    steps: Sequence[Sequence[int]] | None = None,
) -> ArenaPlan:
    """Pack the tensors of schedule ``order`` into one linear arena.

    ``policy='best'`` delegates to :func:`plan_arena_best` (all policies,
    keep the tightest arena).  ``steps`` switches lifetimes to width-W step
    indices (see :func:`_build_items`): co-issued outputs pack disjointly.
    """
    if policy == "best":
        return plan_arena_best(g, order, preplaced=preplaced, steps=steps)
    packer = _packer_for(policy)
    items = _build_items(g, order, preplaced, steps=steps)
    watermark = packer(items)
    return ArenaPlan(
        allocations=items,
        arena_bytes=watermark,
        policy=policy,
        peak_bytes=_interval_peak(items),
    )


def plan_arena_best(
    g: Graph,
    order: Sequence[int],
    preplaced: Sequence[int] = (),
    policies: Sequence[str] = ("first_fit", "best_fit", "greedy_by_size"),
    steps: Sequence[Sequence[int]] | None = None,
) -> ArenaPlan:
    """Run every candidate policy and keep the smallest arena.

    Ties go to the earlier policy in ``policies``; the cheap O(n log n)
    sweep policies run first, and the loop stops as soon as a plan matches
    the interval-peak lower bound (nothing can beat it), so the O(n^2)
    ``greedy_by_size`` pass only runs when fragmentation is actually on the
    table.  Plans with at most ``_EXHAUSTIVE_MAX`` buffers additionally
    search all placement orders, so tiny graphs always get a
    fragmentation-free packing when one exists.  ``greedy_by_size`` is
    skipped above ``_GREEDY_BY_SIZE_MAX`` buffers (its O(n^2) placement
    would dominate planning time on serving arenas).

    Args:
      g: the (possibly rewritten) graph whose tensors are being packed.
      order: a topological schedule of ``g``'s node ids; tensor lifetimes
        are derived from positions in this order.
      preplaced: node ids already resident when the schedule starts
        (divide-and-conquer boundary tensors); they occupy arena bytes from
        time 0.
      policies: placement policies to race (see module docstring).
      steps: optional width-W step schedule flattening to ``order``;
        lifetimes switch to step indices so co-issued ops' outputs are
        live simultaneously and therefore packed disjointly.

    Returns:
      An :class:`ArenaPlan` whose ``arena_bytes`` (bytes — the buffer an
      edge device must reserve) is the minimum over the policies tried, with
      ``peak_bytes`` (bytes — the interval-overlap lower bound), the winning
      ``policy`` name, and per-node byte offsets via
      :meth:`ArenaPlan.offset_of`.
    """
    items = _build_items(g, order, preplaced, steps=steps)
    peak = _interval_peak(items)
    best_policy, best_water = _race_pack(items, policies, peak)
    return ArenaPlan(
        allocations=items,
        arena_bytes=best_water,
        policy=best_policy,
        peak_bytes=peak,
    )


def _race_pack(
    items: list[Allocation], policies: Sequence[str], peak: int
) -> tuple[str, int]:
    """Race the placement policies over ``items``; keep the tightest packing.

    On return every item's ``offset`` holds the winning placement.  Stops as
    soon as a policy matches ``peak`` (the interval lower bound — nothing
    can beat it); falls back to the exhaustive permutation search on tiny
    plans.  Returns ``(policy_name, watermark)``.
    """
    best_policy: str | None = None
    best_water = 0
    best_offsets: list[int] = []
    for pol in policies:
        if pol == "greedy_by_size" and len(items) > _GREEDY_BY_SIZE_MAX:
            continue
        water = _packer_for(pol)(items)
        if best_policy is None or water < best_water:
            best_policy, best_water = pol, water
            best_offsets = [it.offset for it in items]
        if best_water <= peak:
            break                      # unbeatable: matches the lower bound
    if best_water > peak and len(items) <= _EXHAUSTIVE_MAX:
        water = _exhaustive_pack(items, stop_at=peak)
        if water < best_water:
            best_policy, best_water = "exhaustive", water
            best_offsets = [it.offset for it in items]
    for it, off in zip(items, best_offsets):
        it.offset = off
    return best_policy or "first_fit", best_water


def plan_arena_regions(
    g: Graph,
    order: Sequence[int],
    resident: Sequence[int],
    preplaced: Sequence[int] = (),
    policies: Sequence[str] = ("first_fit", "best_fit", "greedy_by_size"),
    steps: Sequence[Sequence[int]] | None = None,
) -> ArenaPlan:
    """Two-region arena: ``resident`` tensors at the bottom, the rest on top.

    Serving state (KV caches) must survive *between* schedule executions, so
    its bytes can never be time-shared with the per-step transients — and a
    leased state buffer should cover exactly the resident bytes, with the
    transient scratch stacked above it (DESIGN.md §9).  ``resident`` node
    ids are packed back-to-back in ``[0, P)`` (they all coexist, so the
    cumulative layout is optimal); every other tensor is planned by the
    usual policy race and shifted to ``[P, arena_bytes)``.

    Every ``resident`` node must be a graph output (no consumers): a tensor
    somebody reads *and frees* mid-schedule has no business being pinned.

    Returns an :class:`ArenaPlan` whose ``meta``-free contract matches
    :func:`plan_arena_best`; the resident extent is recoverable as
    ``max(offset + size)`` over the resident allocations (==
    ``sum(sizes)``).
    """
    res_set = set(resident)
    for r in res_set:
        if g.succs[r]:
            raise ValueError(
                f"resident node {r} has consumers {g.succs[r]}; only graph "
                f"outputs (state tensors) can be pinned resident")
    items = _build_items(g, order, preplaced, steps=steps)
    res_items = [it for it in items if set(it.node_ids) & res_set]
    for it in res_items:
        if not set(it.node_ids) <= res_set:
            raise ValueError(
                f"alias chain {it.node_ids} mixes resident and transient "
                f"members")
    trans = [it for it in items if not (set(it.node_ids) & res_set)]
    off = 0
    for it in sorted(res_items, key=lambda a: a.node_ids):
        it.offset = off
        off += it.size
    resident_extent = off
    tpeak = _interval_peak(trans)
    policy, twater = _race_pack(trans, policies, tpeak)
    for it in trans:
        it.offset += resident_extent
    return ArenaPlan(
        allocations=items,
        arena_bytes=resident_extent + twater,
        policy=f"regions+{policy}",
        peak_bytes=_interval_peak(items),
    )


def pin_transients(plan: ArenaPlan) -> ArenaPlan:
    """A copy of ``plan`` with every buffer held until the schedule ends.

    Placement (offsets, ``arena_bytes``) is untouched — the plan stays valid
    for the executor — but no storage is ever reused: the latency-class
    layout serving hands to requests that would rather not pay allocator
    churn, at the cost of ``peak_bytes`` rising to the whole-plan footprint.
    From :func:`resident_bytes`' point of view every allocation becomes
    persistent, so the lease extent equals ``arena_bytes``.
    """
    if not plan.allocations:
        return ArenaPlan([], plan.arena_bytes,
                         policy=f"{plan.policy}+pinned", peak_bytes=0)
    mt = max(a.t_free for a in plan.allocations)
    allocs = [dataclasses.replace(a, t_free=mt, intra=dict(a.intra))
              for a in plan.allocations]
    return ArenaPlan(
        allocations=allocs,
        arena_bytes=plan.arena_bytes,
        policy=f"{plan.policy}+pinned",
        peak_bytes=_interval_peak(allocs),
    )


def resident_bytes(plan: ArenaPlan) -> tuple[int, int]:
    """(resident bytes, resident extent) of ``plan``'s persistent tensors.

    A *persistent* allocation is one holding a graph output: its
    ``t_free`` is the plan-wide maximum (``horizon + 1`` — see
    ``_build_items``), so it survives the whole schedule.  The extent is
    the byte span a lease buffer must cover to hold every persistent tensor
    at its planned offset (== the bytes for a :func:`plan_arena_regions`
    plan, where persistents pack at the bottom).
    """
    if not plan.allocations:
        return 0, 0
    mt = max(a.t_free for a in plan.allocations)
    pers = [a for a in plan.allocations if a.t_free == mt]
    return (sum(a.size for a in pers),
            max(a.offset + a.size for a in pers))


# ---------------------------------------------------------------------------
# Co-residency: K admitted plans sharing one device buffer (DESIGN.md §9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SharedArenaPlan:
    """K member plans packed into one joint buffer.

    ``members[i]`` is a re-packed copy of the i-th input plan: same
    allocations, same lifetimes, but offsets are *absolute in the joint
    buffer* (so ``members[i].offset_of(node)`` addresses the shared buffer
    directly, and ``members[i].arena_bytes`` is that member's own byte
    extent within it).  ``arena_bytes`` is the joint extent — what the
    device reserves for all K requests together; ``sum_member_bytes`` is
    what K standalone arenas would have reserved.
    """

    members: list[ArenaPlan]
    arena_bytes: int             # joint extent (bytes the device reserves)
    peak_bytes: int              # interval peak on the joint timeline
    sum_member_bytes: int        # sum of the standalone members' extents
    policy: str = "first_fit"
    serialize: bool = True

    @property
    def saved_bytes(self) -> int:
        return self.sum_member_bytes - self.arena_bytes

    def fits(self, budget_bytes: int) -> bool:
        return self.arena_bytes <= budget_bytes


def plan_shared_arena(
    plans: Sequence[ArenaPlan],
    budget: int | None = None,
    *,
    serialize: bool = True,
    policies: Sequence[str] = ("first_fit", "best_fit", "greedy_by_size"),
) -> SharedArenaPlan:
    """Overlap the non-concurrent slack of ``plans`` inside one buffer.

    Each member plan packs one request's tensors over its own schedule
    timeline; its *persistent* allocations (graph outputs — serving state
    that must survive between steps) are live at every moment, while its
    *transient* allocations live only inside the member's own schedule
    window.  When the runtime executes admitted requests' steps serially
    (one device, one stream — the pool's default), member i's transients
    and member j's transients are never live at the same time, so they may
    share addresses: the joint items are placed on one serial timeline
    (member windows back-to-back, persistents spanning everything) and the
    standard lifetime-aware packers do the rest.  The joint extent is
    typically ``sum(persistent_i) + max-ish(transient_i)`` — strictly less
    than ``sum(arena_bytes_i)`` whenever members have any transient slack,
    which is the pool's headline memory win (DESIGN.md §9).

    ``serialize=False`` models *batched* execution instead (every member's
    step runs concurrently): member windows all start at time 0, so
    cross-member sharing is disabled and the joint extent degrades to a
    stacked layout — the accounting an execution mode that materializes all
    members' transients at once must use.

    Args:
      plans: standalone member plans (e.g. from :func:`plan_arena_best` or
        :func:`plan_arena_regions`).  Not mutated.
      budget: optional byte budget; recorded via :meth:`SharedArenaPlan.fits`
        by callers — this function never raises on overflow (admission is
        the pool's decision, not the planner's).
      serialize: see above.
      policies: placement policies to race on the joint items.

    Returns:
      A :class:`SharedArenaPlan`; ``members[i]``'s offsets address the
      joint buffer, so a member schedule can execute against the shared
      buffer via ``execute_plan(..., arena=shared_buffer)`` unchanged.
    """
    del budget  # admission is the caller's decision; kept for signature docs
    if not plans:
        return SharedArenaPlan([], 0, 0, 0, serialize=serialize)
    joint: list[Allocation] = []
    owner: list[tuple[int, Allocation]] = []   # (member idx, original alloc)
    persistent: list[Allocation] = []
    base = 0
    total = 0
    for mi, plan in enumerate(plans):
        if not plan.allocations:
            continue
        mt = max(a.t_free for a in plan.allocations)
        horizon = mt - 1
        for a in plan.allocations:
            if a.t_free == mt:
                ji = dataclasses.replace(a, offset=-1)   # times fixed below
                persistent.append(ji)
            else:
                ji = dataclasses.replace(
                    a,
                    offset=-1,
                    t_alloc=base + max(a.t_alloc, 0),
                    t_free=base + a.t_free,
                )
            joint.append(ji)
            owner.append((mi, a))
        if serialize:
            base += horizon + 1
            total = base
        else:
            total = max(total, horizon + 1)
    for ji in persistent:
        ji.t_alloc = 0
        ji.t_free = total + 1
    pack_order = sorted(
        range(len(joint)),
        key=lambda i: (joint[i].t_alloc, -joint[i].size, owner[i][0],
                       joint[i].node_ids),
    )
    ordered = [joint[i] for i in pack_order]
    peak = _interval_peak(ordered)
    policy, water = _race_pack(ordered, policies, peak)
    sum_members = sum(p.arena_bytes for p in plans)
    if water > sum_members:
        # The joint race fragmented badly — fall back to a stacked layout:
        # each member re-packed *alone* on the joint timeline (its
        # persistents still span everything: a steady-state pool re-executes
        # member schedules every step, so a member's transients may never
        # reuse its own persistent bytes either), members placed
        # back-to-back.  Kept only if actually tighter than the race.
        race_offsets = [it.offset for it in joint]
        by_member: dict[int, list[Allocation]] = {}
        for (mi, _), ji in zip(owner, joint):
            by_member.setdefault(mi, []).append(ji)
        stacked_water = 0
        offsets: list[tuple[Allocation, int]] = []
        for mi in sorted(by_member):
            items = sorted(by_member[mi],
                           key=lambda a: (a.t_alloc, -a.size, a.node_ids))
            _, extent = _race_pack(items, policies, _interval_peak(items))
            offsets += [(it, stacked_water + it.offset) for it in items]
            stacked_water += extent
        if stacked_water < water:
            for it, off in offsets:
                it.offset = off
            policy, water = "stacked", stacked_water
        else:
            for it, off in zip(joint, race_offsets):
                it.offset = off
    member_allocs: dict[int, list[Allocation]] = {i: [] for i in range(len(plans))}
    for (mi, orig), ji in zip(owner, joint):
        member_allocs[mi].append(
            dataclasses.replace(orig, offset=ji.offset))
    members = []
    for mi, plan in enumerate(plans):
        allocs = member_allocs[mi]
        members.append(ArenaPlan(
            allocations=allocs,
            arena_bytes=max((a.offset + a.size for a in allocs), default=0),
            policy="shared",
            peak_bytes=plan.peak_bytes,
        ))
    return SharedArenaPlan(
        members=members,
        arena_bytes=water,
        peak_bytes=peak,
        sum_member_bytes=sum_members,
        policy=policy,
        serialize=serialize,
    )


# ---------------------------------------------------------------------------
# Pre-rewrite reference (differential-testing + benchmarking oracle)
# ---------------------------------------------------------------------------


def _plan_arena_reference(
    g: Graph,
    order: Sequence[int],
    preplaced: Sequence[int] = (),
    policy: str = "first_fit",
) -> ArenaPlan:
    """The seed allocator, kept verbatim: rebuilds and sorts the live set per
    allocation (O(n^2 log n)).  Tests assert the sweep packers reproduce its
    watermarks; ``bench_scheduling_time`` uses it as the pre-rewrite timing
    baseline."""
    items = _build_items(g, order, preplaced)
    live: list[Allocation] = []
    watermark = 0
    for it in items:
        live = [a for a in live if a.t_free > it.t_alloc]
        gaps = sorted(live, key=lambda a: a.offset)
        candidates: list[int] = []
        cursor = 0
        for a in gaps:
            if a.offset - cursor >= it.size:
                candidates.append(cursor)
            cursor = max(cursor, a.offset + a.size)
        candidates.append(cursor)
        if policy == "first_fit":
            it.offset = candidates[0]
        else:  # best_fit: tightest gap
            def gap_len(off: int) -> int:
                following = [a.offset for a in gaps if a.offset >= off + it.size]
                return (min(following) - off) if following else 1 << 60
            it.offset = min(candidates, key=gap_len)
        live.append(it)
        watermark = max(watermark, it.offset + it.size)
    return ArenaPlan(allocations=items, arena_bytes=watermark, policy=policy,
                     peak_bytes=_interval_peak(items))
