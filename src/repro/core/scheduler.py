"""Dynamic-programming memory-aware scheduler (paper Algorithm 1).

The paper keys the memoization table on the *zero-indegree set* ``z`` of each
partial schedule.  ``z`` is a pure function of the set of already-scheduled
nodes, so we key on the canonical bitmask of the scheduled set — the classic
Held–Karp signature — which identifies exactly the same subproblems while
being O(1) to update.  For each signature we keep only the partial schedule
with the smallest ``mu_peak`` (ties broken on smaller ``mu``), which Theorem 1
of the paper proves sufficient for optimality.

Two pruning hooks implement the paper's speed machinery:

  * ``budget`` (tau)     — drop any transition whose ``mu_peak`` exceeds tau
                           (Section 3.2, Figure 8a).
  * ``state_quota``      — the per-search-step "timeout" T of Algorithm 2,
                           made deterministic: if a search step's memo grows
                           beyond the quota we raise :class:`SearchTimeout`
                           instead of measuring wall-clock.

``wall_clock_limit_s`` offers the paper's literal wall-clock T as well.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.core.graph import Graph, simulate_schedule


class NoSolutionError(RuntimeError):
    """Budget tau is below the optimal peak: every path was pruned."""


class SearchTimeout(RuntimeError):
    """A search step exceeded its state quota / wall-clock limit."""


@dataclasses.dataclass
class ScheduleResult:
    order: list[int]
    peak_bytes: int
    final_bytes: int
    n_states_expanded: int
    n_signatures: int
    wall_time_s: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def dp_schedule(
    g: Graph,
    *,
    budget: int | None = None,
    state_quota: int | None = None,
    wall_clock_limit_s: float | None = None,
    preplaced: Sequence[int] = (),
    on_quota: str = "raise",
) -> ScheduleResult:
    """Optimal-peak topological schedule of ``g`` via signature DP.

    ``on_quota='raise'`` is the paper's behaviour (Algorithm 2 reacts to the
    timeout).  ``on_quota='beam'`` instead keeps only the ``state_quota`` best
    signatures per step (lowest peak, then footprint) — no longer provably
    optimal, but bounded; the production fallback for very wide graphs
    (DESIGN.md §3).

    Raises
    ------
    NoSolutionError   if ``budget`` prunes every path (tau < mu*).
    SearchTimeout     if a search step exceeds ``state_quota`` signatures or
                      the wall clock limit (with ``on_quota='raise'``).
    """
    t0 = time.perf_counter()
    n = len(g)
    pre = frozenset(preplaced)
    to_schedule = [i for i in range(n) if i not in pre]
    if not to_schedule:
        return ScheduleResult([], 0, 0, 0, 0, 0.0)

    sizes = g.sizes
    pred_mask = g.pred_mask
    succ_mask = g.succ_mask
    succs = g.succs
    # flat per-node transition tables (hot loop works on ints/tuples only)
    net_alloc = [0] * n          # size - aliased bytes
    dealloc_preds: list[tuple[tuple[int, int], ...]] = [()] * n
    for u in range(n):
        nd = g.nodes[u]
        net_alloc[u] = sizes[u] - sum(sizes[p] for p in nd.alias_preds)
        dealloc_preds[u] = tuple(
            (p, sizes[p]) for p in nd.preds if p not in nd.alias_preds
        )

    pre_mask = 0
    mu0 = 0
    for p in pre:
        pre_mask |= 1 << p
        mu0 += sizes[p]

    full_mask = pre_mask
    for u in to_schedule:
        full_mask |= 1 << u

    frontier0 = 0
    for u in to_schedule:
        if pred_mask[u] & pre_mask == pred_mask[u]:
            frontier0 |= 1 << u

    # level: mask -> (mu, peak, frontier); parents: mask -> (prev_mask, node)
    level: dict[int, tuple[int, int, int]] = {pre_mask: (mu0, mu0, frontier0)}
    parents: dict[int, tuple[int, int]] = {}
    expanded = 0
    n_signatures = 1

    for _step in range(len(to_schedule)):
        nxt: dict[int, tuple[int, int, int]] = {}
        timed_out = False
        for mask, (mu, peak, frontier) in level.items():
            f = frontier
            while f:
                ubit = f & -f
                f ^= ubit
                u = ubit.bit_length() - 1
                expanded += 1
                new_mu = mu + net_alloc[u]
                new_peak = peak if peak >= new_mu else new_mu
                if budget is not None and new_peak > budget:
                    continue  # pruned (soft budget)
                new_mask = mask | ubit
                for p, psz in dealloc_preds[u]:
                    if succ_mask[p] & new_mask == succ_mask[p]:
                        new_mu -= psz
                cur = nxt.get(new_mask)
                if cur is None:
                    new_frontier = frontier ^ ubit
                    for s in succs[u]:
                        pm = pred_mask[s]
                        if pm & new_mask == pm:
                            new_frontier |= 1 << s
                    nxt[new_mask] = (new_mu, new_peak, new_frontier)
                    parents[new_mask] = (mask, u)
                    if (
                        state_quota is not None
                        and on_quota == "raise"
                        and len(nxt) > state_quota
                    ):
                        timed_out = True
                        break
                elif (new_peak, new_mu) < (cur[1], cur[0]):
                    nxt[new_mask] = (new_mu, new_peak, cur[2])
                    parents[new_mask] = (mask, u)
            if timed_out:
                break
        if timed_out:
            raise SearchTimeout(
                f"step {_step}: memo > quota {state_quota}"
            )
        if (
            state_quota is not None
            and on_quota == "beam"
            and len(nxt) > state_quota
        ):
            keep = sorted(nxt.items(), key=lambda kv: (kv[1][1], kv[1][0]))
            nxt = dict(keep[:state_quota])
        if not nxt:
            raise NoSolutionError(
                f"budget {budget} prunes all paths at step {_step} "
                f"(graph {g.name!r})"
            )
        if (
            wall_clock_limit_s is not None
            and time.perf_counter() - t0 > wall_clock_limit_s
        ):
            raise SearchTimeout(f"wall clock limit {wall_clock_limit_s}s hit")
        n_signatures += len(nxt)
        level = nxt

    (final_mask, (final_mu, final_peak, _)), = level.items()
    assert final_mask == full_mask
    order: list[int] = []
    mask = final_mask
    while mask != pre_mask:
        mask, u = parents[mask]
        order.append(u)
    order.reverse()
    return ScheduleResult(
        order=order,
        peak_bytes=final_peak,
        final_bytes=final_mu,
        n_states_expanded=expanded,
        n_signatures=n_signatures,
        wall_time_s=time.perf_counter() - t0,
    )


def brute_force_schedule(
    g: Graph, preplaced: Sequence[int] = ()
) -> ScheduleResult:
    """Exhaustive search over all topological orderings (tests only)."""
    t0 = time.perf_counter()
    n = len(g)
    pre = set(preplaced)
    best_order: list[int] | None = None
    best = (1 << 62, 1 << 62)
    order: list[int] = []
    count = 0

    indeg = [0] * n
    for nd in g.nodes:
        for p in nd.preds:
            if p not in pre:
                indeg[nd.id] += 1
    avail = sorted(
        i for i in range(n) if i not in pre and indeg[i] == 0
    )

    def rec(avail: list[int]) -> None:
        nonlocal best, best_order, count
        if len(order) == n - len(pre):
            count += 1
            sim = simulate_schedule(g, order, preplaced=tuple(pre))
            key = (sim.peak_bytes, sim.final_bytes)
            if key < best:
                best = key
                best_order = list(order)
            return
        for i, u in enumerate(list(avail)):
            order.append(u)
            newly = []
            for v in g.succs[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    newly.append(v)
            rec(avail[:i] + avail[i + 1 :] + newly)
            for v in g.succs[u]:
                indeg[v] += 1
            order.pop()

    rec(avail)
    assert best_order is not None
    sim = simulate_schedule(g, best_order, preplaced=tuple(pre))
    return ScheduleResult(
        order=best_order,
        peak_bytes=sim.peak_bytes,
        final_bytes=sim.final_bytes,
        n_states_expanded=count,
        n_signatures=count,
        wall_time_s=time.perf_counter() - t0,
    )
