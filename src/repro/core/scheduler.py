"""Dynamic-programming memory-aware scheduler (paper Algorithm 1).

The paper keys the memoization table on the *zero-indegree set* ``z`` of each
partial schedule.  ``z`` determines the scheduled set exactly (and vice
versa): the unscheduled nodes are precisely ``z`` plus the strict descendants
of ``z``, so the canonical frontier signature and the scheduled-set bitmask
are two representations of the same signature (bijection proven in
DESIGN.md §8).  We key on the bitmask — the classic Held–Karp signature —
because it is O(1) to update; the frontier rides along in the state for
transition generation.  For each signature we keep only the partial schedule
with the smallest ``(mu_peak, mu, water)`` — the footprint ``mu`` is a pure
function of the signature, so this is the Pareto/dominance filter over the
signature class, which Theorem 1 of the paper proves sufficient for
optimality.

Three pruning layers implement the search-speed machinery (DESIGN.md §8):

  * **eager-move dominance** — if a ready node's scheduling fits under the
    running peak and does not grow the footprint (its deallocations cover
    its allocation), the state that schedules it immediately dominates every
    sibling at the same level: all other transitions of that state are
    dropped.  Chains and in-place ops collapse to a single path.
  * **branch and bound** — with ``bnb=True`` (default) the search seeds an
    incumbent from the best memory-aware heuristic order and prunes every
    transition whose peak exceeds it, plus every signature whose *admissible
    lower bound* (max over remaining nodes of unavoidable resident bytes)
    exceeds it.  ``budget`` (the paper's tau) remains available as an
    explicit cap; the effective bound is ``min(budget, incumbent)``.
    Both engines implement identical rules, so results stay in parity.
  * ``state_quota`` / ``wall_clock_limit_s`` — the per-search-step "timeout"
    T of Algorithm 2, deterministic (signature quota) or literal.

Beyond the paper (DESIGN.md §5): among signatures with equal ``mu_peak``
(and equal ``mu``), the DP prefers the partial schedule with the smaller
*estimated arena watermark* ``water`` — a first-fit-no-coalesce model that
orders equal-peak winners toward fragmentation-free orders.  The
peak-optimality proof is untouched by any of the above: eager moves are an
exchange-argument dominance, and the bound only removes states that provably
cannot beat an order already in hand.
"""

from __future__ import annotations

import dataclasses
import sys
import time
from typing import Sequence

import numpy as np

from repro.core.graph import Graph, simulate_schedule

# In engine='auto' the scalar loop runs until some level generates more
# transitions than this, then the search restarts on the vectorized engine
# (the scalar prefix was cheap by definition — it only ran while levels were
# narrow).  This replaces the old static node-count crossover, which made
# 'auto' pick the slower engine on small-but-wide graphs.
_AUTO_SPILL_TRANSITIONS = 512

# The admissible lower bound only pays for itself on wide levels (it exists
# to stop state-space blowups, and costs a per-signature scan / matmul).
# Levels at or below this many deduped signatures skip it — in *both*
# engines, so the explored state sets stay in parity.
_LB_MIN_STATES = 256


class NoSolutionError(RuntimeError):
    """Budget tau is below the optimal peak: every path was pruned."""


class SearchTimeout(RuntimeError):
    """A search step exceeded its state quota / wall-clock limit."""


class _EngineSpill(Exception):
    """Internal: a level outgrew the scalar loop; restart vectorized."""


@dataclasses.dataclass
class ScheduleResult:
    order: list[int]
    peak_bytes: int
    final_bytes: int
    n_states_expanded: int
    n_signatures: int
    wall_time_s: float
    arena_est_bytes: int = 0   # DP's incremental arena-watermark estimate
                               # (0 when the producing path doesn't track it)
    exact: bool = True         # False for beam-trimmed / heuristic orders
    makespan: int = 0          # surrogate-cost makespan (serial = total cost;
                               # 0 when the producing path doesn't track it)
    width: int = 1             # max ops co-issued in any step (serial = 1)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Admissible lower-bound tables (branch and bound, DESIGN.md §8)
# ---------------------------------------------------------------------------


class _BoundTables:
    """Per-graph tables for the admissible completion lower bound.

    For a state with scheduled-set mask ``S`` and any remaining node ``u``,
    the footprint at the moment ``u`` is scheduled is at least

        static_lb[u]                 (u's allocation + all its preds resident)
      + sum sizes over S & need[u]   (already-produced tensors that *cannot*
                                      die before u: a consumer among u's
                                      strict descendants, or no consumer at
                                      all — graph outputs stay resident)

    so ``LB(S) = max(peak, max_u not in S: static_lb[u] + extra(S, u))`` is a
    valid lower bound on every completion's peak: any state with
    ``LB > bound`` cannot beat an order already in hand and is dropped.
    """

    def __init__(self, g: Graph):
        n = len(g)
        sizes = g.sizes
        desc = g.descendants_masks()
        need: list[int] = [0] * n
        static_lb: list[int] = [0] * n
        for u in range(n):
            nd = g.nodes[u]
            pm = g.pred_mask[u]
            m = 0
            for t in range(n):
                if t == u or pm >> t & 1:
                    continue
                if g.succ_mask[t] == 0 or g.succ_mask[t] & desc[u]:
                    m |= 1 << t
            need[u] = m
            alias = sum(sizes[p] for p in nd.alias_preds)
            static_lb[u] = (
                sizes[u] - alias + sum(sizes[p] for p in nd.preds)
            )
        self.need = need
        self.static_lb = static_lb
        # float64 keeps the per-level evaluation a single BLAS matmul; byte
        # sums stay far below 2**53, so the arithmetic is exact
        W = np.zeros((n, n), dtype=np.float64)
        for u in range(n):
            m = need[u]
            while m:
                b = m & -m
                m ^= b
                t = b.bit_length() - 1
                W[t, u] = float(sizes[t])
        self.need_w = W
        self.static_lb_np = np.array(static_lb, dtype=np.float64)


def _bound_tables(g: Graph) -> _BoundTables:
    bt = g.__dict__.get("_bound_tables")
    if bt is None:
        bt = _BoundTables(g)
        g._bound_tables = bt
    return bt


def dp_schedule(
    g: Graph,
    *,
    budget: int | None = None,
    state_quota: int | None = None,
    wall_clock_limit_s: float | None = None,
    preplaced: Sequence[int] = (),
    on_quota: str = "raise",
    engine: str = "auto",
    bnb: bool = True,
) -> ScheduleResult:
    """Optimal-peak topological schedule of ``g`` via signature DP.

    ``on_quota='raise'`` is the paper's behaviour (Algorithm 2 reacts to the
    timeout).  ``on_quota='beam'`` instead keeps only the ``state_quota`` best
    signatures per step (lowest peak, then footprint) — no longer provably
    optimal, but bounded; the production fallback for very wide graphs
    (DESIGN.md §3).  Beam runs without the automatic bound (an incumbent
    prune can dead-end a beam whose feasible path was evicted).

    ``bnb`` (default) turns the paper's user-supplied budget tau into an
    automatic bound: the search seeds an incumbent from the best heuristic
    order (`repro.core.heuristics.best_heuristic_schedule`), prunes peaks
    above ``min(budget, incumbent)``, applies the admissible lower bound,
    and collapses zero-cost moves via the eager-move dominance.  The
    returned peak is identical to the unpruned DP's; pass ``bnb=False`` for
    the pre-bound reference search (kept for A/B state-count benchmarks).

    ``engine`` selects the DP implementation:

      * ``'python'`` — the scalar reference loop (one Python iteration per
        state transition).  Semantically the source of truth.
      * ``'numpy'``  — the vectorized bitmask engine: each DP level is a
        batch of packed-uint64 signature rows and every transition rule
        (alloc, dominance, bound prune, dealloc, frontier update, dedup) is
        evaluated for the whole level at once.  Identical results (same
        ``peak_bytes`` and ``final_bytes``; ties may pick a different but
        equally-optimal order only when the two engines enumerate states
        differently — both are deterministic).
      * ``'auto'``   — starts on the scalar loop and restarts on the
        vectorized engine the first time a level generates more than
        ``_AUTO_SPILL_TRANSITIONS`` transitions, so tiny/narrow searches
        never pay the per-level numpy dispatch overhead and wide ones never
        pay the per-transition interpreter overhead.

    Raises
    ------
    NoSolutionError   if ``budget`` prunes every path (tau < mu*).
    SearchTimeout     if a search step exceeds ``state_quota`` signatures or
                      the wall clock limit (with ``on_quota='raise'``).
    """
    use_bound = bnb and on_quota != "beam"
    tau = budget
    if use_bound:
        # the incumbent is a pure function of (graph, preplaced) — memoized
        # on the instance so budget meta-search rounds don't re-run the
        # heuristics (dropped on pickle, like the other derived caches)
        incumbents = g.__dict__.setdefault("_incumbents", {})
        inc_key = tuple(sorted(preplaced))
        inc_peak = incumbents.get(inc_key)
        if inc_peak is None:
            from repro.core.heuristics import best_heuristic_schedule

            inc_peak = best_heuristic_schedule(
                g, preplaced=preplaced).peak_bytes
            incumbents[inc_key] = inc_peak
        tau = inc_peak if budget is None else min(budget, inc_peak)

    kw = dict(
        tau=tau,
        state_quota=state_quota,
        wall_clock_limit_s=wall_clock_limit_s,
        preplaced=preplaced,
        on_quota=on_quota,
        use_bound=use_bound,
    )
    little = sys.byteorder == "little"
    if engine == "auto":
        try:
            res = _dp_schedule_python(
                g, spill_cap=_AUTO_SPILL_TRANSITIONS if little else None, **kw
            )
        except _EngineSpill:
            res = _dp_schedule_numpy(g, **kw)
    elif engine == "numpy":
        res = _dp_schedule_numpy(g, **kw)
    elif engine == "python":
        res = _dp_schedule_python(g, **kw)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    costs = node_costs(g)
    res.makespan = sum(costs[u] for u in res.order)
    return res


def _dp_schedule_python(
    g: Graph,
    *,
    tau: int | None = None,
    state_quota: int | None = None,
    wall_clock_limit_s: float | None = None,
    preplaced: Sequence[int] = (),
    on_quota: str = "raise",
    use_bound: bool = False,
    spill_cap: int | None = None,
) -> ScheduleResult:
    """Scalar reference DP: one Python iteration per state transition."""
    t0 = time.perf_counter()
    n = len(g)
    pre = frozenset(preplaced)
    to_schedule = [i for i in range(n) if i not in pre]
    if not to_schedule:
        return ScheduleResult([], 0, 0, 0, 0, 0.0)

    sizes = g.sizes
    pred_mask = g.pred_mask
    succ_mask = g.succ_mask
    succs = g.succs
    # flat per-node transition tables (hot loop works on ints/tuples only)
    net_alloc = [0] * n          # size - aliased bytes
    alloc_pos = [0] * n          # max(net_alloc, 0): bytes the arena must find
    dealloc_preds: list[tuple[tuple[int, int], ...]] = [()] * n
    for u in range(n):
        nd = g.nodes[u]
        net_alloc[u] = sizes[u] - sum(sizes[p] for p in nd.alias_preds)
        alloc_pos[u] = max(net_alloc[u], 0)
        dealloc_preds[u] = tuple(
            (p, sizes[p]) for p in nd.preds if p not in nd.alias_preds
        )

    lbt = _bound_tables(g) if use_bound and tau is not None else None
    lb_cache: dict[int, int] = {}

    def _lb(mask: int) -> int:
        """max over remaining nodes of unavoidable resident bytes."""
        v = lb_cache.get(mask)
        if v is not None:
            return v
        best = 0
        need = lbt.need
        slb = lbt.static_lb
        for u in to_schedule:
            if mask >> u & 1:
                continue
            s = slb[u]
            m = mask & need[u]
            while m:
                b = m & -m
                m ^= b
                s += sizes[b.bit_length() - 1]
            if s > best:
                best = s
                if best > tau:
                    break          # prune decision already determined
        lb_cache[mask] = best
        return best

    pre_mask = 0
    mu0 = 0
    for p in pre:
        pre_mask |= 1 << p
        mu0 += sizes[p]

    full_mask = pre_mask
    for u in to_schedule:
        full_mask |= 1 << u

    frontier0 = 0
    for u in to_schedule:
        if pred_mask[u] & pre_mask == pred_mask[u]:
            frontier0 |= 1 << u

    # level: mask -> (mu, peak, water, frontier)
    # parents: mask -> (prev_mask, node)
    level: dict[int, tuple[int, int, int, int]] = {
        pre_mask: (mu0, mu0, mu0, frontier0)
    }
    parents: dict[int, tuple[int, int]] = {}
    expanded = 0
    n_signatures = 1

    for _step in range(len(to_schedule)):
        nxt: dict[int, tuple[int, int, int, int]] = {}
        level_tr = 0
        for mask, (mu, peak, water, frontier) in level.items():
            # generate the state's transitions; the eager-move dominance
            # (DESIGN.md §8) keeps only the first (lowest-id) ready node
            # whose transient fits under the running peak and whose
            # deallocations cover its allocation — its child state dominates
            # every sibling, so the rest of the frontier is dropped.
            trans: list[tuple[int, int, int, int, int]] = []
            f = frontier
            while f:
                ubit = f & -f
                f ^= ubit
                u = ubit.bit_length() - 1
                new_mu = mu + net_alloc[u]
                tpeak = new_mu           # transient before deallocations
                new_mask = mask | ubit
                for p, psz in dealloc_preds[u]:
                    if succ_mask[p] & new_mask == succ_mask[p]:
                        new_mu -= psz
                if use_bound and tpeak <= peak and new_mu <= mu:
                    trans = [(u, ubit, new_mask, new_mu, tpeak)]
                    break
                trans.append((u, ubit, new_mask, new_mu, tpeak))
            expanded += len(trans)
            level_tr += len(trans)
            for u, ubit, new_mask, new_mu, tpeak in trans:
                new_peak = peak if peak >= tpeak else tpeak
                if tau is not None and new_peak > tau:
                    continue  # pruned (budget / incumbent bound)
                # arena-watermark estimate: reuse hole bytes (water - mu) if
                # they cover the allocation, else grow the arena top
                s = alloc_pos[u]
                new_water = water if water - mu >= s else water + s
                cur = nxt.get(new_mask)
                if cur is None:
                    new_frontier = frontier ^ ubit
                    for s2 in succs[u]:
                        pm = pred_mask[s2]
                        if pm & new_mask == pm:
                            new_frontier |= 1 << s2
                    nxt[new_mask] = (new_mu, new_peak, new_water, new_frontier)
                    parents[new_mask] = (mask, u)
                    if (
                        lbt is None
                        and state_quota is not None
                        and on_quota == "raise"
                        and len(nxt) > state_quota
                    ):
                        # without a lower-bound filter nothing can shrink
                        # this level anymore: abort before materializing it
                        raise SearchTimeout(
                            f"step {_step}: memo > quota {state_quota}"
                        )
                elif (new_peak, new_mu, new_water) < (cur[1], cur[0], cur[2]):
                    nxt[new_mask] = (new_mu, new_peak, new_water, cur[3])
                    parents[new_mask] = (mask, u)
            if spill_cap is not None and level_tr > spill_cap:
                raise _EngineSpill
        # the admissible lower bound runs on wide levels only (it exists to
        # stop blowups; narrow levels aren't one) — stale `parents` entries
        # of pruned masks are unreachable and harmless
        if lbt is not None and len(nxt) > _LB_MIN_STATES:
            nxt = {m: v for m, v in nxt.items() if _lb(m) <= tau}
        if (
            state_quota is not None
            and on_quota == "raise"
            and len(nxt) > state_quota
        ):
            raise SearchTimeout(
                f"step {_step}: memo > quota {state_quota}"
            )
        if (
            state_quota is not None
            and on_quota == "beam"
            and len(nxt) > state_quota
        ):
            keep = sorted(
                nxt.items(), key=lambda kv: (kv[1][1], kv[1][0], kv[1][2])
            )
            nxt = dict(keep[:state_quota])
        if not nxt:
            raise NoSolutionError(
                f"budget {tau} prunes all paths at step {_step} "
                f"(graph {g.name!r})"
            )
        if (
            wall_clock_limit_s is not None
            and time.perf_counter() - t0 > wall_clock_limit_s
        ):
            raise SearchTimeout(f"wall clock limit {wall_clock_limit_s}s hit")
        n_signatures += len(nxt)
        level = nxt

    (final_mask, (final_mu, final_peak, final_water, _)), = level.items()
    assert final_mask == full_mask
    order: list[int] = []
    mask = final_mask
    while mask != pre_mask:
        mask, u = parents[mask]
        order.append(u)
    order.reverse()
    return ScheduleResult(
        order=order,
        peak_bytes=final_peak,
        final_bytes=final_mu,
        n_states_expanded=expanded,
        n_signatures=n_signatures,
        wall_time_s=time.perf_counter() - t0,
        arena_est_bytes=final_water,
        exact=on_quota != "beam",
    )


def _dp_schedule_numpy(
    g: Graph,
    *,
    tau: int | None = None,
    state_quota: int | None = None,
    wall_clock_limit_s: float | None = None,
    preplaced: Sequence[int] = (),
    on_quota: str = "raise",
    use_bound: bool = False,
) -> ScheduleResult:
    """Vectorized bitmask DP over whole levels at once.

    A level is the set of DP states with the same number of scheduled nodes.
    State signatures are rows of packed uint64 words (``Graph.masks()``), so
    one level is an ``(S, words)`` array and the per-transition work of the
    reference loop becomes ~a dozen batched numpy ops:

      1. unpack every state's ready-set into (state, node) transition pairs,
      2. batched alloc (``mu + net_alloc``) and dealloc: a predecessor is
         freed iff its successor mask is a subset of the new signature
         (CSR repeat/gather/reduceat over the pred-edge table),
      3. eager-move dominance: per source state, if any transition fits
         under the running peak without growing the footprint, keep only the
         first such transition (``minimum.reduceat`` over the state groups),
      4. bound prune (``new_peak > tau``), then signature dedup via one
         stable lexsort over (mask words, peak, water) — exactly the
         reference loop's per-signature winner,
      5. admissible lower bound on the surviving signatures: one float64
         matmul of the unpacked masks against the need-weight table,
      6. batched frontier refill over the succ-edge table.
    """
    if sys.byteorder != "little":
        # unpackbits(view(uint8), bitorder='little') relies on little-endian
        # uint64 layout; on big-endian hosts bits would map to wrong nodes
        raise RuntimeError(
            "engine='numpy' requires a little-endian host; use engine='python'"
        )
    t0 = time.perf_counter()
    n = len(g)
    pre = frozenset(preplaced)
    n_free = n - len(pre)
    if n_free == 0:
        return ScheduleResult([], 0, 0, 0, 0, 0.0)

    bt = g.masks()
    W = bt.words
    u64 = np.uint64
    lbt = _bound_tables(g) if use_bound and tau is not None else None

    pre_mask = np.zeros(W, dtype=u64)
    mu0 = 0
    for p in pre:
        pre_mask[p // 64] |= u64(1) << u64(p % 64)
        mu0 += g.sizes[p]
    full_mask = pre_mask.copy()
    for u in range(n):
        if u not in pre:
            full_mask[u // 64] |= u64(1) << u64(u % 64)
    frontier0 = np.zeros(W, dtype=u64)
    for u in range(n):
        if u not in pre and (bt.pred_mask[u] & ~pre_mask).max(initial=0) == 0:
            frontier0[u // 64] |= u64(1) << u64(u % 64)

    # current level (all states at the same depth); single-word graphs keep
    # signatures/frontiers as 1-D uint64 arrays, wider ones as (S, W) rows
    word1 = W == 1
    if word1:
        masks = pre_mask.copy()                          # (S,)
        frontier = frontier0.copy()
    else:
        masks = np.ascontiguousarray(pre_mask[None, :])  # (S, W)
        frontier = np.ascontiguousarray(frontier0[None, :])
    mu = np.array([mu0], dtype=np.int64)
    peak = np.array([mu0], dtype=np.int64)
    water = np.array([mu0], dtype=np.int64)   # arena-watermark estimate

    # per-level winner arrays for schedule reconstruction: at level L,
    # state i was reached by scheduling node_hist[L][i] in state
    # from_hist[L][i] of level L-1
    node_hist: list[np.ndarray] = []
    from_hist: list[np.ndarray] = []
    expanded = 0
    n_signatures = 1

    row_bits = 64 * W            # unpacked row width (a power of two iff
    row_shift = row_bits.bit_length() - 1     # W is one: the hot path)
    row_pow2 = row_bits & (row_bits - 1) == 0

    def _csr_expand(u_sel, table_len, table_off):
        """(rows, flat, row_rep, offs) expanding u_sel against a CSR table."""
        cnt = table_len[u_sel]
        rows = np.flatnonzero(cnt)
        if not len(rows):
            return rows, rows, rows, rows
        cnt_nz = cnt[rows]
        ends = np.cumsum(cnt_nz)
        offs = ends - cnt_nz
        pos = np.arange(int(ends[-1])) - np.repeat(offs, cnt_nz)
        flat = np.repeat(table_off[u_sel[rows]], cnt_nz) + pos
        row_rep = np.repeat(rows, cnt_nz)
        return rows, flat, row_rep, offs

    for _step in range(n_free):
        # 1. all (state, node) transitions of this level: unpack the packed
        # frontiers to one flat bit array; flat position p encodes
        # (state, node) = divmod(p, 64W).  Bits past n are always zero, so
        # no trimming is needed.
        bits = np.unpackbits(
            np.ascontiguousarray(frontier).view(np.uint8),
            bitorder="little",
        )
        tpos = np.flatnonzero(bits)
        if row_pow2:
            state_idx = tpos >> row_shift
            u_arr = tpos & (row_bits - 1)
        else:
            state_idx = tpos // row_bits
            u_arr = tpos - state_idx * row_bits

        # 2. batched alloc + dealloc for *every* transition (the dominance
        # test needs the post-dealloc footprint before any pruning)
        tpeak_tr = mu[state_idx] + bt.net_alloc[u_arr]   # transient
        if word1:
            new_mask = masks[state_idx] | bt.node_bit1[u_arr]
        else:
            new_mask = masks[state_idx] | bt.node_bit[u_arr]
        new_mu = tpeak_tr.copy()
        rows, flat, row_rep, offs = _csr_expand(u_arr, bt.pe_len, bt.pe_off)
        if len(rows):
            if word1:
                tgt = bt.pe_tgt1[flat]
                hit = (new_mask[row_rep] & tgt) == tgt
            else:
                tgt = bt.pe_tgt[flat]
                hit = ((new_mask[row_rep] & tgt) == tgt).all(axis=1)
            new_mu[rows] -= np.add.reduceat(
                np.where(hit, bt.pe_size[flat], 0), offs)

        # 3. eager-move dominance: per state, keep only the first transition
        # that fits under the running peak without growing the footprint
        if use_bound and len(u_arr):
            qual = (tpeak_tr <= peak[state_idx]) & (new_mu <= mu[state_idx])
            if qual.any():
                T = len(u_arr)
                starts = np.flatnonzero(
                    np.r_[True, state_idx[1:] != state_idx[:-1]])
                gid = np.cumsum(
                    np.r_[False, state_idx[1:] != state_idx[:-1]])
                qpos = np.where(qual, np.arange(T), T)
                firstq = np.minimum.reduceat(qpos, starts)
                keep = (firstq[gid] == T) | (np.arange(T) == firstq[gid])
                state_idx, u_arr = state_idx[keep], u_arr[keep]
                tpeak_tr, new_mu = tpeak_tr[keep], new_mu[keep]
                new_mask = new_mask[keep]
        expanded += len(u_arr)

        # 4. bound prune (budget / incumbent)
        new_peak = np.maximum(peak[state_idx], tpeak_tr)
        if tau is not None:
            keep = new_peak <= tau
            u_arr, state_idx = u_arr[keep], state_idx[keep]
            new_mu, new_peak = new_mu[keep], new_peak[keep]
            new_mask = new_mask[keep]
        if len(u_arr) == 0:
            raise NoSolutionError(
                f"budget {tau} prunes all paths at step {_step} "
                f"(graph {g.name!r})"
            )
        # arena-watermark estimate: reuse hole bytes (water - mu) when they
        # cover the allocation, else grow the arena top (see module docstring)
        s_arr = bt.alloc_pos[u_arr]
        water_tr = water[state_idx]
        new_water = water_tr + np.where(
            water_tr - mu[state_idx] >= s_arr, 0, s_arr
        )

        # 5. dedup signatures: the footprint mu is a pure function of the
        # signature mask, so transitions reaching the same mask differ only
        # in (peak, water).  One stable lexsort with the mask words as
        # primary keys and (peak, water) as tie-breaks groups equal masks
        # with the lexicographically-best transition first — exactly the
        # reference loop's strictly-better-replaces rule (earliest
        # transition wins among full ties, as lexsort is stable).
        firsts = np.empty(len(u_arr), dtype=bool)
        firsts[0] = True
        if word1:
            order = np.lexsort((new_water, new_peak, new_mask))
            sorted_mask = new_mask[order]
            np.not_equal(sorted_mask[1:], sorted_mask[:-1], out=firsts[1:])
        else:
            order = np.lexsort((new_water, new_peak) + tuple(new_mask.T))
            sorted_mask = new_mask[order]
            np.any(sorted_mask[1:] != sorted_mask[:-1], axis=1, out=firsts[1:])
        winners = order[np.flatnonzero(firsts)]

        state_w = state_idx[winners]
        u_w = u_arr[winners]
        mask_w = new_mask[winners]
        peak_w = new_peak[winners]
        mu_w = new_mu[winners]
        water_w = new_water[winners]

        # 6. admissible lower bound on the deduped signatures (one matmul;
        # wide levels only — the same rule as the reference loop)
        if lbt is not None and len(u_w) > _LB_MIN_STATES:
            mbits = np.unpackbits(
                np.ascontiguousarray(mask_w).view(np.uint8),
                bitorder="little",
            ).reshape(len(u_w), row_bits)[:, :n].astype(np.float64)
            lb = mbits @ lbt.need_w + lbt.static_lb_np
            np.copyto(lb, -1.0, where=mbits > 0.5)   # only remaining nodes
            keep = lb.max(axis=1) <= tau
            if not keep.all():
                state_w, u_w = state_w[keep], u_w[keep]
                mask_w, peak_w = mask_w[keep], peak_w[keep]
                mu_w, water_w = mu_w[keep], water_w[keep]
            if len(u_w) == 0:
                raise NoSolutionError(
                    f"budget {tau} prunes all paths at step {_step} "
                    f"(graph {g.name!r})"
                )
        n_uniq = len(u_w)
        if (
            state_quota is not None
            and on_quota == "raise"
            and n_uniq > state_quota
        ):
            raise SearchTimeout(f"step {_step}: memo > quota {state_quota}")

        # 7. batched frontier refill over the succ-edge table: a successor
        # enters the frontier iff all its preds are in the new signature
        if word1:
            frontier_w = frontier[state_w] ^ bt.node_bit1[u_w]
        else:
            frontier_w = frontier[state_w] ^ bt.node_bit[u_w]
        rows, flat, row_rep, offs = _csr_expand(u_w, bt.se_len, bt.se_off)
        if len(rows):
            if word1:
                tgt = bt.se_tgt1[flat]
                hit = (mask_w[row_rep] & tgt) == tgt
                frontier_w[rows] |= np.bitwise_or.reduceat(
                    np.where(hit, bt.se_bit1[flat], u64(0)), offs)
            else:
                tgt = bt.se_tgt[flat]
                hit = ((mask_w[row_rep] & tgt) == tgt).all(axis=1)
                frontier_w[rows] |= np.bitwise_or.reduceat(
                    np.where(hit[:, None], bt.se_bit[flat], u64(0)),
                    offs, axis=0)

        # 8. beam trim (needs the post-dealloc footprint for its tie-break)
        if (
            state_quota is not None
            and on_quota == "beam"
            and len(u_w) > state_quota
        ):
            best = np.lexsort((water_w, mu_w, peak_w))[: state_quota]
            state_w, u_w = state_w[best], u_w[best]
            mask_w = mask_w[best]
            peak_w, mu_w = peak_w[best], mu_w[best]
            water_w = water_w[best]
            frontier_w = frontier_w[best]
        if (
            wall_clock_limit_s is not None
            and time.perf_counter() - t0 > wall_clock_limit_s
        ):
            raise SearchTimeout(f"wall clock limit {wall_clock_limit_s}s hit")
        n_signatures += len(u_w)

        node_hist.append(u_w)
        from_hist.append(state_w)
        masks, mu, peak, frontier = mask_w, mu_w, peak_w, frontier_w
        water = water_w

    assert len(mu) == 1 and (masks if word1 else masks[0]).reshape(-1).tolist() \
        == full_mask.tolist()
    order_out: list[int] = []
    idx = 0
    for lvl in range(n_free - 1, -1, -1):
        order_out.append(int(node_hist[lvl][idx]))
        idx = int(from_hist[lvl][idx])
    order_out.reverse()
    return ScheduleResult(
        order=order_out,
        peak_bytes=int(peak[0]),
        final_bytes=int(mu[0]),
        n_states_expanded=expanded,
        n_signatures=n_signatures,
        wall_time_s=time.perf_counter() - t0,
        arena_est_bytes=int(water[0]),
        exact=on_quota != "beam",
    )


def brute_force_schedule(
    g: Graph, preplaced: Sequence[int] = ()
) -> ScheduleResult:
    """Exhaustive search over all topological orderings (tests only)."""
    t0 = time.perf_counter()
    n = len(g)
    pre = set(preplaced)
    best_order: list[int] | None = None
    best = (1 << 62, 1 << 62)
    order: list[int] = []
    count = 0

    indeg = [0] * n
    for nd in g.nodes:
        for p in nd.preds:
            if p not in pre:
                indeg[nd.id] += 1
    avail = sorted(
        i for i in range(n) if i not in pre and indeg[i] == 0
    )

    def rec(avail: list[int]) -> None:
        nonlocal best, best_order, count
        if len(order) == n - len(pre):
            count += 1
            sim = simulate_schedule(g, order, preplaced=tuple(pre))
            key = (sim.peak_bytes, sim.final_bytes)
            if key < best:
                best = key
                best_order = list(order)
            return
        for i, u in enumerate(list(avail)):
            order.append(u)
            newly = []
            for v in g.succs[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    newly.append(v)
            rec(avail[:i] + avail[i + 1 :] + newly)
            for v in g.succs[u]:
                indeg[v] += 1
            order.pop()

    rec(avail)
    assert best_order is not None
    sim = simulate_schedule(g, best_order, preplaced=tuple(pre))
    costs = node_costs(g)
    return ScheduleResult(
        order=best_order,
        peak_bytes=sim.peak_bytes,
        final_bytes=sim.final_bytes,
        n_states_expanded=count,
        n_signatures=count,
        wall_time_s=time.perf_counter() - t0,
        makespan=sum(costs[u] for u in best_order),
    )


# ---------------------------------------------------------------------------
# Latency x memory Pareto frontier (width-W time-slot model, DESIGN.md §12)
# ---------------------------------------------------------------------------


def node_costs(g: Graph) -> list[int]:
    """Per-node surrogate latency cost (the rewriter's FLOPs model).

    Inputs cost 0, so co-issuing graph inputs is free; every compute op
    costs at least 1.  The import is deferred because the rewriter imports
    this module.
    """
    from repro.core.rewriter import node_flops

    return [node_flops(g, u) for u in range(len(g))]


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    """One non-dominated (makespan, peak) schedule on the frontier."""

    steps: tuple[tuple[int, ...], ...]  # time slots; each an antichain
    makespan: int                       # sum over steps of max member cost
    peak_bytes: int                     # step-model peak (simulate_steps)
    final_bytes: int
    width: int                          # max |step| actually used

    @property
    def order(self) -> list[int]:
        """The steps flattened to a serial execution order."""
        return [u for step in self.steps for u in step]


@dataclasses.dataclass
class ParetoFrontier:
    """Full latency-vs-peak frontier of a graph under width-W concurrency.

    ``points`` is sorted by strictly increasing makespan and strictly
    decreasing peak: ``points[0]`` is the fastest schedule, ``points[-1]``
    the serial-DP-peak endpoint (the latency-unconstrained minimum peak —
    co-issuing ops can never *reduce* peak below the serial optimum because
    any step schedule serializes without raising its peak, DESIGN.md §12).
    """

    points: list[ParetoPoint]
    max_width: int
    latency_budget: int | None
    n_states_expanded: int
    n_signatures: int
    wall_time_s: float
    exact: bool = True

    def pairs(self) -> tuple[tuple[int, int], ...]:
        return tuple((p.makespan, p.peak_bytes) for p in self.points)

    def best_under(self, latency_budget: int | None = None) -> ParetoPoint:
        """Min-peak point with makespan <= budget (None = unconstrained)."""
        pts = self.points if latency_budget is None else [
            p for p in self.points if p.makespan <= latency_budget]
        if not pts:
            raise NoSolutionError(
                f"no frontier point within latency budget {latency_budget} "
                f"(fastest point has makespan {self.points[0].makespan})")
        return pts[-1]

    @property
    def min_makespan(self) -> ParetoPoint:
        return self.points[0]

    @property
    def min_peak(self) -> ParetoPoint:
        return self.points[-1]


def _greedy_packed_steps(
    g: Graph, max_width: int, preplaced: Sequence[int], costs: Sequence[int]
) -> list[tuple[int, ...]]:
    """Deterministic maximal-width longest-cost-first step schedule.

    Not optimal in either objective — it exists to seed the Pareto search
    with a low-makespan incumbent (maximal packing is a decent makespan
    upper bound) whose (makespan, peak) prunes high-peak state families.
    """
    n = len(g)
    pre = set(preplaced)
    indeg = [0] * n
    for nd in g.nodes:
        indeg[nd.id] += sum(1 for p in nd.preds if p not in pre)
    ready = {u for u in range(n) if u not in pre and indeg[u] == 0}
    steps: list[tuple[int, ...]] = []
    while ready:
        pick = sorted(ready, key=lambda u: (-costs[u], u))[:max_width]
        steps.append(tuple(sorted(pick)))
        for u in pick:
            ready.discard(u)
            for v in g.succs[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.add(v)
    return steps


def steps_makespan(
    g: Graph,
    steps: Sequence[Sequence[int]],
    costs: Sequence[int] | None = None,
) -> int:
    """Surrogate makespan of a step schedule: sum of per-step max costs."""
    if costs is None:
        costs = node_costs(g)
    return sum(max(costs[u] for u in step) for step in steps if step)


def pareto_schedule(
    g: Graph,
    *,
    max_width: int = 2,
    latency_budget: int | None = None,
    budget: int | None = None,
    preplaced: Sequence[int] = (),
    state_quota: int | None = None,
    on_quota: str = "raise",
    costs: Sequence[int] | None = None,
) -> ParetoFrontier:
    """Exact latency-vs-peak Pareto frontier under width-W concurrency.

    Extends the signature DP with a time dimension: a transition schedules a
    non-empty *antichain* of up to ``max_width`` ready nodes as one step
    whose duration is the max member cost and whose transient claims every
    member's output before any deallocation lands (the step model of
    :func:`repro.core.graph.simulate_steps`).  Footprint ``mu`` stays a pure
    function of the scheduled-set mask, so keeping the per-mask Pareto set
    of ``(makespan, peak)`` labels is exact — the two-objective analogue of
    the serial DP's single ``(peak, mu, water)`` winner.

    Exactness-preserving prunes: per-mask label dominance; a latency-budget
    cut using the admissible remaining-makespan bound ``max(critical-path
    tail, ceil(remaining cost / W))``; and an incumbent cut against two
    complete seed points (the exact serial DP order and a greedy max-packed
    schedule) — a label whose every completion is weakly dominated by a seed
    point is dropped, and both seeds re-enter the final candidate set so
    boundary ties survive.

    ``max_width=1`` delegates to :func:`dp_schedule`, reproducing today's
    serial schedule bit-for-bit as a single-point frontier.  ``budget`` caps
    peak bytes (the paper's tau); ``latency_budget`` caps makespan.
    ``on_quota='beam'`` trims each DP level to the ``state_quota`` best
    labels by ``(peak, makespan)`` and marks the frontier inexact — the
    serial endpoint stays exact regardless, because the seed point is the
    exact serial DP's.
    """
    if max_width < 1:
        raise ValueError(f"max_width must be >= 1, got {max_width}")
    if on_quota not in ("raise", "beam"):
        raise ValueError(f"unknown on_quota {on_quota!r}")
    t0 = time.perf_counter()
    costs = list(costs) if costs is not None else node_costs(g)
    from repro.core.graph import simulate_steps

    def _serial_seed() -> ScheduleResult:
        # try the exact search first even in beam mode: dp_schedule flags
        # every beam-mode result inexact whether or not a trim happened
        try:
            return dp_schedule(
                g, budget=budget, state_quota=state_quota,
                preplaced=preplaced, on_quota="raise")
        except SearchTimeout:
            if on_quota != "beam":
                raise
            return dp_schedule(
                g, budget=budget, state_quota=state_quota,
                preplaced=preplaced, on_quota="beam")

    if max_width == 1:
        res = _serial_seed()
        if latency_budget is not None and res.makespan > latency_budget:
            raise NoSolutionError(
                f"latency budget {latency_budget} below the serial makespan "
                f"{res.makespan} and max_width=1 allows no packing")
        point = ParetoPoint(
            steps=tuple((u,) for u in res.order),
            makespan=res.makespan,
            peak_bytes=res.peak_bytes,
            final_bytes=res.final_bytes,
            width=1,
        )
        return ParetoFrontier(
            points=[point], max_width=1, latency_budget=latency_budget,
            n_states_expanded=res.n_states_expanded,
            n_signatures=res.n_signatures,
            wall_time_s=time.perf_counter() - t0, exact=res.exact)

    import itertools

    n = len(g)
    W = max_width
    pre = frozenset(preplaced)
    to_schedule = [u for u in range(n) if u not in pre]
    if not to_schedule:
        # nothing to place: a single empty schedule (dp_schedule semantics —
        # preplaced residents are the caller's bytes, not this schedule's)
        point = ParetoPoint(steps=(), makespan=0, peak_bytes=0,
                            final_bytes=0, width=1)
        return ParetoFrontier(
            points=[point], max_width=W, latency_budget=latency_budget,
            n_states_expanded=0, n_signatures=0,
            wall_time_s=time.perf_counter() - t0, exact=True)
    sizes = g.sizes
    pred_mask = g.pred_mask
    succ_mask = g.succ_mask
    succs = g.succs

    net_alloc = [0] * n
    alloc_pos = [0] * n
    dealloc_preds: list[tuple[tuple[int, int], ...]] = [()] * n
    for u in range(n):
        nd = g.nodes[u]
        net_alloc[u] = sizes[u] - sum(sizes[p] for p in nd.alias_preds)
        alloc_pos[u] = max(net_alloc[u], 0)
        dealloc_preds[u] = tuple(
            (p, sizes[p]) for p in nd.preds if p not in nd.alias_preds
        )

    # critical-path tails over the surrogate cost (admissible makespan LB)
    tail = [0] * n
    for u in range(n - 1, -1, -1):
        if u in pre:
            continue
        tail[u] = costs[u] + max(
            (tail[v] for v in succs[u] if v not in pre), default=0)

    pre_mask = 0
    mu0 = 0
    for p in pre:
        pre_mask |= 1 << p
        mu0 += sizes[p]
    full_mask = pre_mask
    for u in to_schedule:
        full_mask |= 1 << u
    frontier0 = 0
    for u in to_schedule:
        if pred_mask[u] & pre_mask == pred_mask[u]:
            frontier0 |= 1 << u
    total_cost = sum(costs[u] for u in to_schedule)

    # complete seed points pruning partial states from above; both re-enter
    # the final candidate set, so a pruned boundary tie is never lost
    serial = _serial_seed()
    exact = serial.exact
    seed_cands: list[tuple[int, int, tuple[tuple[int, ...], ...]]] = [
        (serial.makespan, serial.peak_bytes,
         tuple((u,) for u in serial.order)),
    ]
    if to_schedule:
        packed = _greedy_packed_steps(g, W, preplaced, costs)
        psim = simulate_steps(g, packed, preplaced=preplaced)
        seed_cands.append(
            (steps_makespan(g, packed, costs), psim.peak_bytes,
             tuple(packed)))
    seed_pairs = [(ms, pk) for ms, pk, _ in seed_cands]

    def _ms_lb(mask: int, rem_cost: int) -> int:
        """Admissible lower bound on the remaining makespan."""
        best = 0
        for u in to_schedule:
            if not mask >> u & 1 and tail[u] > best:
                best = tail[u]
        return max(best, -(-rem_cost // W))

    # label = (makespan, peak, parent_label | None, step_tuple); the parent
    # reference survives per-mask Pareto evictions, so reconstruction never
    # chases a reindexed list
    MU, FRONT, LB, LABELS = 0, 1, 2, 3
    root = (0, mu0, None, ())
    buckets: dict[int, dict[int, list]] = {
        len(pre): {pre_mask: [mu0, frontier0,
                              _ms_lb(pre_mask, total_cost), [root]]}
    }
    rem_costs: dict[int, int] = {pre_mask: total_cost}
    expanded = 0
    n_signatures = 1

    k0 = len(pre)
    for k in range(k0, n):
        bucket = buckets.pop(k, None)
        if not bucket:
            continue
        total_labels = sum(len(e[LABELS]) for e in bucket.values())
        if state_quota is not None and total_labels > state_quota:
            if on_quota == "raise":
                raise SearchTimeout(
                    f"pareto level {k - k0}: {total_labels} labels > "
                    f"quota {state_quota}")
            flat = sorted(
                ((lab[1], lab[0], mask, lab)
                 for mask, e in bucket.items() for lab in e[LABELS]),
                key=lambda t: t[:3])
            for e in bucket.values():
                e[LABELS] = []
            for _, _, mask, lab in flat[:state_quota]:
                bucket[mask][LABELS].append(lab)
            exact = False
        n_signatures += total_labels
        for mask, ent in bucket.items():
            mu, frontier, labels = ent[MU], ent[FRONT], ent[LABELS]
            if not labels:
                continue
            ready = []
            f = frontier
            while f:
                b = f & -f
                f ^= b
                ready.append(b.bit_length() - 1)
            rem = rem_costs[mask]
            for size in range(1, min(W, len(ready)) + 1):
                for S in itertools.combinations(ready, size):
                    sbits = 0
                    dur = 0
                    sum_pos = 0
                    sum_net = 0
                    for u in S:
                        sbits |= 1 << u
                        if costs[u] > dur:
                            dur = costs[u]
                        sum_pos += alloc_pos[u]
                        sum_net += net_alloc[u]
                    new_mask = mask | sbits
                    freed = 0
                    seen_preds = set()
                    for u in S:
                        for p, psz in dealloc_preds[u]:
                            if p in seen_preds:
                                continue
                            seen_preds.add(p)
                            if succ_mask[p] & new_mask == succ_mask[p]:
                                freed += psz
                    new_mu = mu + sum_net - freed
                    tpeak = mu + sum_pos
                    nk = k + size
                    nb = buckets.setdefault(nk, {})
                    nent = nb.get(new_mask)
                    if nent is None:
                        nf = frontier ^ sbits
                        for u in S:
                            for v in succs[u]:
                                pm = pred_mask[v]
                                if pm & new_mask == pm:
                                    nf |= 1 << v
                        nrem = rem - sum(costs[u] for u in S)
                        rem_costs[new_mask] = nrem
                        nent = nb[new_mask] = [
                            new_mu, nf, _ms_lb(new_mask, nrem), []]
                    lb_ms = nent[LB]
                    nlabels = nent[LABELS]
                    for lab in labels:
                        expanded += 1
                        new_ms = lab[0] + dur
                        new_peak = lab[1] if lab[1] >= tpeak else tpeak
                        if budget is not None and new_peak > budget:
                            continue
                        floor_ms = new_ms + lb_ms
                        if (latency_budget is not None
                                and floor_ms > latency_budget):
                            continue
                        if any(new_peak >= ipk and floor_ms >= ims
                               for ims, ipk in seed_pairs):
                            continue  # every completion covered by a seed
                        dominated = False
                        for cur in nlabels:
                            if cur[0] <= new_ms and cur[1] <= new_peak:
                                dominated = True
                                break
                        if dominated:
                            continue
                        nlabels[:] = [
                            cur for cur in nlabels
                            if not (new_ms <= cur[0] and new_peak <= cur[1])]
                        nlabels.append((new_ms, new_peak, lab, S))

    cands = list(seed_cands)
    final_bucket = buckets.get(n, {})
    for lab in final_bucket.get(full_mask, [None, None, None, []])[LABELS]:
        steps_rev: list[tuple[int, ...]] = []
        cur = lab
        while cur[2] is not None:
            steps_rev.append(cur[3])
            cur = cur[2]
        cands.append((lab[0], lab[1], tuple(reversed(steps_rev))))

    if latency_budget is not None:
        cands = [c for c in cands if c[0] <= latency_budget]
    if budget is not None:
        cands = [c for c in cands if c[1] <= budget]
    if not cands:
        raise NoSolutionError(
            f"no width-{W} schedule satisfies latency budget "
            f"{latency_budget} / peak budget {budget} (graph {g.name!r})")
    cands.sort(key=lambda c: (c[0], c[1]))
    points: list[ParetoPoint] = []
    last_peak = None
    for ms, pk, steps in cands:
        if last_peak is not None and pk >= last_peak:
            continue  # dominated, or an equal-makespan tie already kept
        last_peak = pk
        sim = simulate_steps(g, steps, preplaced=preplaced)
        assert sim.peak_bytes == pk and steps_makespan(g, steps, costs) == ms
        points.append(ParetoPoint(
            steps=steps, makespan=ms, peak_bytes=pk,
            final_bytes=sim.final_bytes,
            width=max((len(s) for s in steps), default=1)))
    return ParetoFrontier(
        points=points, max_width=W, latency_budget=latency_budget,
        n_states_expanded=expanded, n_signatures=n_signatures,
        wall_time_s=time.perf_counter() - t0, exact=exact)
