"""Dynamic-programming memory-aware scheduler (paper Algorithm 1).

The paper keys the memoization table on the *zero-indegree set* ``z`` of each
partial schedule.  ``z`` is a pure function of the set of already-scheduled
nodes, so we key on the canonical bitmask of the scheduled set — the classic
Held–Karp signature — which identifies exactly the same subproblems while
being O(1) to update.  For each signature we keep only the partial schedule
with the smallest ``mu_peak`` (ties broken on smaller ``mu``), which Theorem 1
of the paper proves sufficient for optimality.

Two pruning hooks implement the paper's speed machinery:

  * ``budget`` (tau)     — drop any transition whose ``mu_peak`` exceeds tau
                           (Section 3.2, Figure 8a).
  * ``state_quota``      — the per-search-step "timeout" T of Algorithm 2,
                           made deterministic: if a search step's memo grows
                           beyond the quota we raise :class:`SearchTimeout`
                           instead of measuring wall-clock.

``wall_clock_limit_s`` offers the paper's literal wall-clock T as well.

Beyond the paper (DESIGN.md §5): among signatures with equal ``mu_peak``
(and equal ``mu`` — the footprint is a pure function of the signature), the
DP prefers the partial schedule with the smaller *estimated arena watermark*
``water``: a per-state scalar modelling a first-fit allocator whose free
holes never coalesce — scheduling ``u`` reuses hole bytes when
``water - mu >= net_alloc(u)`` and otherwise grows the arena top.  Ties are
thereby broken toward fragmentation-free orders instead of arbitrary node
ids, which is what the offset allocator (``plan_arena``) realizes later.
The peak-optimality proof is untouched: ``water`` only orders equal-peak
winners.
"""

from __future__ import annotations

import dataclasses
import sys
import time
from typing import Sequence

import numpy as np

from repro.core.graph import Graph, simulate_schedule

# Below this node count the per-level numpy dispatch overhead outweighs the
# vectorization win; the scalar reference loop is faster on tiny segments.
_NUMPY_MIN_NODES = 24


class NoSolutionError(RuntimeError):
    """Budget tau is below the optimal peak: every path was pruned."""


class SearchTimeout(RuntimeError):
    """A search step exceeded its state quota / wall-clock limit."""


@dataclasses.dataclass
class ScheduleResult:
    order: list[int]
    peak_bytes: int
    final_bytes: int
    n_states_expanded: int
    n_signatures: int
    wall_time_s: float
    arena_est_bytes: int = 0   # DP's incremental arena-watermark estimate
                               # (0 when the producing path doesn't track it)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def dp_schedule(
    g: Graph,
    *,
    budget: int | None = None,
    state_quota: int | None = None,
    wall_clock_limit_s: float | None = None,
    preplaced: Sequence[int] = (),
    on_quota: str = "raise",
    engine: str = "auto",
) -> ScheduleResult:
    """Optimal-peak topological schedule of ``g`` via signature DP.

    ``on_quota='raise'`` is the paper's behaviour (Algorithm 2 reacts to the
    timeout).  ``on_quota='beam'`` instead keeps only the ``state_quota`` best
    signatures per step (lowest peak, then footprint) — no longer provably
    optimal, but bounded; the production fallback for very wide graphs
    (DESIGN.md §3).

    ``engine`` selects the DP implementation:

      * ``'python'`` — the scalar reference loop (one Python iteration per
        state transition).  Semantically the source of truth.
      * ``'numpy'``  — the vectorized bitmask engine: each DP level is a
        batch of packed-uint64 signature rows and every transition rule
        (alloc, budget prune, dealloc, frontier update, dedup) is evaluated
        for the whole level at once.  Identical results (same ``peak_bytes``
        and ``final_bytes``; ties may pick a different but equally-optimal
        order only when the two engines enumerate states differently —
        both are deterministic).
      * ``'auto'``   — ``'numpy'`` for graphs above ``_NUMPY_MIN_NODES``
        nodes, ``'python'`` for tiny ones where dispatch overhead dominates.

    Raises
    ------
    NoSolutionError   if ``budget`` prunes every path (tau < mu*).
    SearchTimeout     if a search step exceeds ``state_quota`` signatures or
                      the wall clock limit (with ``on_quota='raise'``).
    """
    if engine == "auto":
        engine = (
            "numpy"
            if len(g) > _NUMPY_MIN_NODES and sys.byteorder == "little"
            else "python"
        )
    if engine == "numpy":
        return _dp_schedule_numpy(
            g,
            budget=budget,
            state_quota=state_quota,
            wall_clock_limit_s=wall_clock_limit_s,
            preplaced=preplaced,
            on_quota=on_quota,
        )
    if engine != "python":
        raise ValueError(f"unknown engine {engine!r}")
    return _dp_schedule_python(
        g,
        budget=budget,
        state_quota=state_quota,
        wall_clock_limit_s=wall_clock_limit_s,
        preplaced=preplaced,
        on_quota=on_quota,
    )


def _dp_schedule_python(
    g: Graph,
    *,
    budget: int | None = None,
    state_quota: int | None = None,
    wall_clock_limit_s: float | None = None,
    preplaced: Sequence[int] = (),
    on_quota: str = "raise",
) -> ScheduleResult:
    """Scalar reference DP (the seed implementation, kept verbatim)."""
    t0 = time.perf_counter()
    n = len(g)
    pre = frozenset(preplaced)
    to_schedule = [i for i in range(n) if i not in pre]
    if not to_schedule:
        return ScheduleResult([], 0, 0, 0, 0, 0.0)

    sizes = g.sizes
    pred_mask = g.pred_mask
    succ_mask = g.succ_mask
    succs = g.succs
    # flat per-node transition tables (hot loop works on ints/tuples only)
    net_alloc = [0] * n          # size - aliased bytes
    alloc_pos = [0] * n          # max(net_alloc, 0): bytes the arena must find
    dealloc_preds: list[tuple[tuple[int, int], ...]] = [()] * n
    for u in range(n):
        nd = g.nodes[u]
        net_alloc[u] = sizes[u] - sum(sizes[p] for p in nd.alias_preds)
        alloc_pos[u] = max(net_alloc[u], 0)
        dealloc_preds[u] = tuple(
            (p, sizes[p]) for p in nd.preds if p not in nd.alias_preds
        )

    pre_mask = 0
    mu0 = 0
    for p in pre:
        pre_mask |= 1 << p
        mu0 += sizes[p]

    full_mask = pre_mask
    for u in to_schedule:
        full_mask |= 1 << u

    frontier0 = 0
    for u in to_schedule:
        if pred_mask[u] & pre_mask == pred_mask[u]:
            frontier0 |= 1 << u

    # level: mask -> (mu, peak, water, frontier)
    # parents: mask -> (prev_mask, node)
    level: dict[int, tuple[int, int, int, int]] = {
        pre_mask: (mu0, mu0, mu0, frontier0)
    }
    parents: dict[int, tuple[int, int]] = {}
    expanded = 0
    n_signatures = 1

    for _step in range(len(to_schedule)):
        nxt: dict[int, tuple[int, int, int, int]] = {}
        timed_out = False
        for mask, (mu, peak, water, frontier) in level.items():
            f = frontier
            while f:
                ubit = f & -f
                f ^= ubit
                u = ubit.bit_length() - 1
                expanded += 1
                new_mu = mu + net_alloc[u]
                new_peak = peak if peak >= new_mu else new_mu
                if budget is not None and new_peak > budget:
                    continue  # pruned (soft budget)
                # arena-watermark estimate: reuse hole bytes (water - mu) if
                # they cover the allocation, else grow the arena top
                s = alloc_pos[u]
                new_water = water if water - mu >= s else water + s
                new_mask = mask | ubit
                for p, psz in dealloc_preds[u]:
                    if succ_mask[p] & new_mask == succ_mask[p]:
                        new_mu -= psz
                cur = nxt.get(new_mask)
                if cur is None:
                    new_frontier = frontier ^ ubit
                    for s2 in succs[u]:
                        pm = pred_mask[s2]
                        if pm & new_mask == pm:
                            new_frontier |= 1 << s2
                    nxt[new_mask] = (new_mu, new_peak, new_water, new_frontier)
                    parents[new_mask] = (mask, u)
                    if (
                        state_quota is not None
                        and on_quota == "raise"
                        and len(nxt) > state_quota
                    ):
                        timed_out = True
                        break
                elif (new_peak, new_mu, new_water) < (cur[1], cur[0], cur[2]):
                    nxt[new_mask] = (new_mu, new_peak, new_water, cur[3])
                    parents[new_mask] = (mask, u)
            if timed_out:
                break
        if timed_out:
            raise SearchTimeout(
                f"step {_step}: memo > quota {state_quota}"
            )
        if (
            state_quota is not None
            and on_quota == "beam"
            and len(nxt) > state_quota
        ):
            keep = sorted(
                nxt.items(), key=lambda kv: (kv[1][1], kv[1][0], kv[1][2])
            )
            nxt = dict(keep[:state_quota])
        if not nxt:
            raise NoSolutionError(
                f"budget {budget} prunes all paths at step {_step} "
                f"(graph {g.name!r})"
            )
        if (
            wall_clock_limit_s is not None
            and time.perf_counter() - t0 > wall_clock_limit_s
        ):
            raise SearchTimeout(f"wall clock limit {wall_clock_limit_s}s hit")
        n_signatures += len(nxt)
        level = nxt

    (final_mask, (final_mu, final_peak, final_water, _)), = level.items()
    assert final_mask == full_mask
    order: list[int] = []
    mask = final_mask
    while mask != pre_mask:
        mask, u = parents[mask]
        order.append(u)
    order.reverse()
    return ScheduleResult(
        order=order,
        peak_bytes=final_peak,
        final_bytes=final_mu,
        n_states_expanded=expanded,
        n_signatures=n_signatures,
        wall_time_s=time.perf_counter() - t0,
        arena_est_bytes=final_water,
    )


def _dp_schedule_numpy(
    g: Graph,
    *,
    budget: int | None = None,
    state_quota: int | None = None,
    wall_clock_limit_s: float | None = None,
    preplaced: Sequence[int] = (),
    on_quota: str = "raise",
) -> ScheduleResult:
    """Vectorized bitmask DP over whole levels at once.

    A level is the set of DP states with the same number of scheduled nodes.
    State signatures are rows of packed uint64 words (``Graph.masks()``), so
    one level is an ``(S, words)`` array and the per-transition work of the
    reference loop becomes ~a dozen batched numpy ops:

      1. unpack every state's ready-set into (state, node) transition pairs,
      2. batched alloc (``mu + net_alloc``), peak update, budget prune,
      3. signature dedup via one stable lexsort over (mask words, peak,
         water), keeping exactly the reference loop's winner per signature
         (the footprint is a pure function of the mask, so only peak and the
         arena-watermark estimate can differ within a group),
      4. batched dealloc on the survivors: a predecessor is freed iff its
         successor mask is a subset of the new signature (single-word graphs
         test *all* node pairs with one ``(S, n)`` broadcast),
      5. batched frontier refill the same way.
    """
    if sys.byteorder != "little":
        # unpackbits(view(uint8), bitorder='little') relies on little-endian
        # uint64 layout; on big-endian hosts bits would map to wrong nodes
        raise RuntimeError(
            "engine='numpy' requires a little-endian host; use engine='python'"
        )
    t0 = time.perf_counter()
    n = len(g)
    pre = frozenset(preplaced)
    n_free = n - len(pre)
    if n_free == 0:
        return ScheduleResult([], 0, 0, 0, 0, 0.0)

    bt = g.masks()
    W = bt.words
    u64 = np.uint64

    pre_mask = np.zeros(W, dtype=u64)
    mu0 = 0
    for p in pre:
        pre_mask[p // 64] |= u64(1) << u64(p % 64)
        mu0 += g.sizes[p]
    full_mask = pre_mask.copy()
    for u in range(n):
        if u not in pre:
            full_mask[u // 64] |= u64(1) << u64(u % 64)
    frontier0 = np.zeros(W, dtype=u64)
    for u in range(n):
        if u not in pre and (bt.pred_mask[u] & ~pre_mask).max(initial=0) == 0:
            frontier0[u // 64] |= u64(1) << u64(u % 64)

    # current level (all states at the same depth); single-word graphs keep
    # signatures/frontiers as 1-D uint64 arrays, wider ones as (S, W) rows
    word1 = W == 1
    if word1:
        masks = pre_mask.copy()                          # (S,)
        frontier = frontier0.copy()
    else:
        masks = np.ascontiguousarray(pre_mask[None, :])  # (S, W)
        frontier = np.ascontiguousarray(frontier0[None, :])
    mu = np.array([mu0], dtype=np.int64)
    peak = np.array([mu0], dtype=np.int64)
    water = np.array([mu0], dtype=np.int64)   # arena-watermark estimate

    # per-level winner arrays for schedule reconstruction: at level L,
    # state i was reached by scheduling node_hist[L][i] in state
    # from_hist[L][i] of level L-1
    node_hist: list[np.ndarray] = []
    from_hist: list[np.ndarray] = []
    expanded = 0
    n_signatures = 1

    row_bits = 64 * W            # unpacked row width (a power of two iff
    row_shift = row_bits.bit_length() - 1     # W is one: the hot path)
    row_pow2 = row_bits & (row_bits - 1) == 0
    for _step in range(n_free):
        # 1. all (state, node) transitions of this level: unpack the packed
        # frontiers to one flat bit array; flat position p encodes
        # (state, node) = divmod(p, 64W).  Bits past n are always zero, so
        # no trimming is needed.
        bits = np.unpackbits(
            np.ascontiguousarray(frontier).view(np.uint8),
            bitorder="little",
        )
        tpos = np.flatnonzero(bits)
        if row_pow2:
            state_idx = tpos >> row_shift
            u_arr = tpos & (row_bits - 1)
        else:
            state_idx = tpos // row_bits
            u_arr = tpos - state_idx * row_bits
        expanded += len(u_arr)

        # 2. batched alloc + budget prune (O(transitions) scalar arrays)
        pre_mu = mu[state_idx] + bt.net_alloc[u_arr]
        new_peak = np.maximum(peak[state_idx], pre_mu)
        if budget is not None:
            keep = new_peak <= budget
            u_arr, state_idx = u_arr[keep], state_idx[keep]
            pre_mu, new_peak = pre_mu[keep], new_peak[keep]
        if len(u_arr) == 0:
            raise NoSolutionError(
                f"budget {budget} prunes all paths at step {_step} "
                f"(graph {g.name!r})"
            )
        # arena-watermark estimate: reuse hole bytes (water - mu) when they
        # cover the allocation, else grow the arena top (see module docstring)
        s_arr = bt.alloc_pos[u_arr]
        water_tr = water[state_idx]
        new_water = water_tr + np.where(
            water_tr - mu[state_idx] >= s_arr, 0, s_arr
        )

        # 3. dedup signatures first: the footprint mu is a pure function of
        # the signature mask, so transitions reaching the same mask differ
        # only in (peak, water).  One stable lexsort with the mask words as
        # primary keys and (peak, water) as tie-breaks groups equal masks
        # with the lexicographically-best transition first — exactly the
        # reference loop's strictly-better-replaces rule (earliest
        # transition wins among full ties, as lexsort is stable).
        firsts = np.empty(len(u_arr), dtype=bool)
        firsts[0] = True
        if word1:
            new_mask = masks[state_idx] | bt.node_bit1[u_arr]
            order = np.lexsort((new_water, new_peak, new_mask))
            sorted_mask = new_mask[order]
            np.not_equal(sorted_mask[1:], sorted_mask[:-1], out=firsts[1:])
        else:
            new_mask = masks[state_idx] | bt.node_bit[u_arr]
            order = np.lexsort((new_water, new_peak) + tuple(new_mask.T))
            sorted_mask = new_mask[order]
            np.any(sorted_mask[1:] != sorted_mask[:-1], axis=1, out=firsts[1:])
        starts = np.flatnonzero(firsts)
        n_uniq = len(starts)
        if (
            state_quota is not None
            and on_quota == "raise"
            and n_uniq > state_quota
        ):
            raise SearchTimeout(f"step {_step}: memo > quota {state_quota}")
        winners = order[starts]

        state_w = state_idx[winners]
        u_w = u_arr[winners]
        mask_w = new_mask[winners]
        peak_w = new_peak[winners]
        mu_w = pre_mu[winners]
        water_w = new_water[winners]
        if word1:
            frontier_w = frontier[state_w] ^ bt.node_bit1[u_w]
        else:
            frontier_w = frontier[state_w] ^ bt.node_bit[u_w]

        # 4. batched dealloc + frontier refill on the deduped level: expand
        # each survivor against its node's merged CSR edge table
        # (repeat/gather), test subset-of-signature per edge once, and fold
        # back per row with reduceat — bytes freed for pred edges, frontier
        # bits for successor edges.  A pred is freed iff all its consumers
        # are scheduled; a successor enters the frontier iff all its preds
        # are.
        cnt = bt.me_len[u_w]
        rows = np.flatnonzero(cnt)
        if len(rows):
            cnt_nz = cnt[rows]
            ends = np.cumsum(cnt_nz)
            offs = ends - cnt_nz
            # flat[i] = csr_off[u] + (position of i within its row)
            pos = np.arange(int(ends[-1])) - np.repeat(offs, cnt_nz)
            flat = np.repeat(bt.me_off[u_w[rows]], cnt_nz) + pos
            row_rep = np.repeat(rows, cnt_nz)
            if word1:
                tgt = bt.me_tgt1[flat]
                hit = (mask_w[row_rep] & tgt) == tgt
                mu_w[rows] -= np.add.reduceat(
                    np.where(hit, bt.me_size[flat], 0), offs)
                frontier_w[rows] |= np.bitwise_or.reduceat(
                    np.where(hit, bt.me_bit1[flat], u64(0)), offs)
            else:
                tgt = bt.me_tgt[flat]
                hit = ((mask_w[row_rep] & tgt) == tgt).all(axis=1)
                mu_w[rows] -= np.add.reduceat(
                    np.where(hit, bt.me_size[flat], 0), offs)
                frontier_w[rows] |= np.bitwise_or.reduceat(
                    np.where(hit[:, None], bt.me_bit[flat], u64(0)),
                    offs, axis=0)

        # 5. beam trim (needs the post-dealloc footprint for its tie-break)
        if (
            state_quota is not None
            and on_quota == "beam"
            and len(winners) > state_quota
        ):
            best = np.lexsort((water_w, mu_w, peak_w))[: state_quota]
            state_w, u_w = state_w[best], u_w[best]
            mask_w = mask_w[best]
            peak_w, mu_w = peak_w[best], mu_w[best]
            water_w = water_w[best]
            frontier_w = frontier_w[best]
        if (
            wall_clock_limit_s is not None
            and time.perf_counter() - t0 > wall_clock_limit_s
        ):
            raise SearchTimeout(f"wall clock limit {wall_clock_limit_s}s hit")
        n_signatures += len(u_w)

        node_hist.append(u_w)
        from_hist.append(state_w)
        masks, mu, peak, frontier = mask_w, mu_w, peak_w, frontier_w
        water = water_w

    assert len(mu) == 1 and (masks if word1 else masks[0]).reshape(-1).tolist() \
        == full_mask.tolist()
    order_out: list[int] = []
    idx = 0
    for lvl in range(n_free - 1, -1, -1):
        order_out.append(int(node_hist[lvl][idx]))
        idx = int(from_hist[lvl][idx])
    order_out.reverse()
    return ScheduleResult(
        order=order_out,
        peak_bytes=int(peak[0]),
        final_bytes=int(mu[0]),
        n_states_expanded=expanded,
        n_signatures=n_signatures,
        wall_time_s=time.perf_counter() - t0,
        arena_est_bytes=int(water[0]),
    )


def brute_force_schedule(
    g: Graph, preplaced: Sequence[int] = ()
) -> ScheduleResult:
    """Exhaustive search over all topological orderings (tests only)."""
    t0 = time.perf_counter()
    n = len(g)
    pre = set(preplaced)
    best_order: list[int] | None = None
    best = (1 << 62, 1 << 62)
    order: list[int] = []
    count = 0

    indeg = [0] * n
    for nd in g.nodes:
        for p in nd.preds:
            if p not in pre:
                indeg[nd.id] += 1
    avail = sorted(
        i for i in range(n) if i not in pre and indeg[i] == 0
    )

    def rec(avail: list[int]) -> None:
        nonlocal best, best_order, count
        if len(order) == n - len(pre):
            count += 1
            sim = simulate_schedule(g, order, preplaced=tuple(pre))
            key = (sim.peak_bytes, sim.final_bytes)
            if key < best:
                best = key
                best_order = list(order)
            return
        for i, u in enumerate(list(avail)):
            order.append(u)
            newly = []
            for v in g.succs[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    newly.append(v)
            rec(avail[:i] + avail[i + 1 :] + newly)
            for v in g.succs[u]:
                indeg[v] += 1
            order.pop()

    rec(avail)
    assert best_order is not None
    sim = simulate_schedule(g, best_order, preplaced=tuple(pre))
    return ScheduleResult(
        order=best_order,
        peak_bytes=sim.peak_bytes,
        final_bytes=sim.final_bytes,
        n_states_expanded=count,
        n_signatures=count,
        wall_time_s=time.perf_counter() - t0,
    )
