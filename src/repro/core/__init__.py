"""SERENITY core: memory-aware scheduling of irregularly wired neural networks.

Public API:

    Graph, Node, simulate_schedule          -- dataflow IR + footprint model
    dp_schedule, brute_force_schedule       -- Algorithm 1 + branch-and-bound
                                               pruning (+ oracle for tests)
    pareto_schedule, oracle_frontier        -- width-W latency x memory
                                               frontier + its ILP/differential
                                               oracle (DESIGN.md §12)
    adaptive_budget_schedule                -- Algorithm 2
    partition, partition_hierarchy          -- divide & conquer (flat and
    find_separators                            nested segment tree)
    plan, PlanConfig, Plan                  -- THE planning entry point:
                                               rewrite [+ recompute] + order
                                               + arena in one call (Fig. 4)
    rewrite_graph, annotate_inplace         -- identity rewriting + in-place
    rematerialize                           -- recompute-clone expansion
                                               (peak-vs-FLOPs frontier)
    plan_arena, plan_arena_best             -- offset allocation policies
    plan_arena_regions                      -- resident-state + transient
                                               two-region serving layout
    plan_shared_arena, plan_coresidency     -- co-residency: K plans in one
                                               buffer (multi-tenant pool)
    simulate_traffic                        -- Belady off-chip traffic model
    execute                                 -- run a schedule on the planned
                                               arena (realized footprint)
    schedule, schedule_order                -- deprecated kwarg shims onto
                                               plan()/PlanConfig
"""

from repro.core.allocator import (
    ArenaPlan,
    SharedArenaPlan,
    pin_transients,
    plan_arena,
    plan_arena_best,
    plan_arena_regions,
    plan_shared_arena,
    resident_bytes,
)
from repro.core.budget import adaptive_budget_schedule
from repro.core.executor import (
    ExecutionResult,
    ExecutorError,
    PlanProgram,
    RealizedTracker,
    compile_plan,
    execute_plan,
    reference_fn,
    run_reference,
)
from repro.core.graph import (
    Graph,
    GraphError,
    Node,
    SimResult,
    simulate_schedule,
    simulate_steps,
)
from repro.core.ilp_oracle import OracleError, has_ilp_solver, oracle_frontier
from repro.core.heuristics import (
    BASELINES,
    best_heuristic_schedule,
    dfs_schedule,
    greedy_schedule,
    kahn_schedule,
)
from repro.core.partition import (
    PartitionNode,
    Segment,
    find_separators,
    partition,
    partition_hierarchy,
)
from repro.core.plancache import (
    PlanCache,
    canonical_hash,
    default_cache,
    labeled_fingerprint,
    translate_order,
    wl_colors,
)
from repro.core.rewriter import (
    FusedRegion,
    RecomputeReport,
    RewriteReport,
    annotate_inplace,
    fuse_alias_chains,
    graph_flops,
    node_flops,
    recompute_provenance,
    rematerialize,
    rewrite_graph,
)
from repro.core.scheduler import (
    NoSolutionError,
    ParetoFrontier,
    ParetoPoint,
    ScheduleResult,
    SearchTimeout,
    brute_force_schedule,
    dp_schedule,
    node_costs,
    pareto_schedule,
    steps_makespan,
)
from repro.core.serenity import (
    OrderResult,
    Plan,
    PlanConfig,
    SerenityResult,
    execute,
    plan,
    plan_coresidency,
    schedule,
    schedule_order,
)
from repro.core.traffic import TrafficResult, simulate_traffic

__all__ = [
    "ArenaPlan",
    "BASELINES",
    "ExecutionResult",
    "ExecutorError",
    "FusedRegion",
    "Graph",
    "GraphError",
    "Node",
    "NoSolutionError",
    "OracleError",
    "OrderResult",
    "ParetoFrontier",
    "ParetoPoint",
    "PartitionNode",
    "Plan",
    "PlanCache",
    "PlanConfig",
    "PlanProgram",
    "RealizedTracker",
    "RecomputeReport",
    "RewriteReport",
    "ScheduleResult",
    "SearchTimeout",
    "Segment",
    "SerenityResult",
    "SharedArenaPlan",
    "SimResult",
    "TrafficResult",
    "adaptive_budget_schedule",
    "best_heuristic_schedule",
    "annotate_inplace",
    "brute_force_schedule",
    "canonical_hash",
    "compile_plan",
    "default_cache",
    "dfs_schedule",
    "dp_schedule",
    "execute",
    "execute_plan",
    "find_separators",
    "fuse_alias_chains",
    "graph_flops",
    "has_ilp_solver",
    "labeled_fingerprint",
    "greedy_schedule",
    "kahn_schedule",
    "node_costs",
    "node_flops",
    "oracle_frontier",
    "pareto_schedule",
    "partition",
    "partition_hierarchy",
    "pin_transients",
    "plan",
    "plan_arena",
    "plan_arena_best",
    "plan_arena_regions",
    "plan_coresidency",
    "plan_shared_arena",
    "recompute_provenance",
    "reference_fn",
    "rematerialize",
    "resident_bytes",
    "rewrite_graph",
    "run_reference",
    "schedule",
    "schedule_order",
    "simulate_schedule",
    "simulate_steps",
    "simulate_traffic",
    "steps_makespan",
    "translate_order",
    "wl_colors",
]
