"""SERENITY core: memory-aware scheduling of irregularly wired neural networks.

Public API:

    Graph, Node, simulate_schedule          -- dataflow IR + footprint model
    dp_schedule, brute_force_schedule       -- Algorithm 1 (+ oracle for tests)
    adaptive_budget_schedule                -- Algorithm 2
    partition, find_separators              -- divide & conquer
    rewrite_graph, annotate_inplace         -- identity rewriting + in-place
    plan_arena, plan_arena_best             -- offset allocation policies
    simulate_traffic                        -- Belady off-chip traffic model
    schedule                                -- end-to-end pipeline (Fig. 4)
    execute                                 -- run a schedule on the planned
                                               arena (realized footprint)
"""

from repro.core.allocator import ArenaPlan, plan_arena, plan_arena_best
from repro.core.budget import adaptive_budget_schedule
from repro.core.executor import (
    ExecutionResult,
    ExecutorError,
    RealizedTracker,
    execute_plan,
    run_reference,
)
from repro.core.graph import Graph, GraphError, Node, SimResult, simulate_schedule
from repro.core.heuristics import (
    BASELINES,
    dfs_schedule,
    greedy_schedule,
    kahn_schedule,
)
from repro.core.partition import Segment, find_separators, partition
from repro.core.plancache import (
    PlanCache,
    canonical_hash,
    default_cache,
    labeled_fingerprint,
)
from repro.core.rewriter import RewriteReport, annotate_inplace, rewrite_graph
from repro.core.scheduler import (
    NoSolutionError,
    ScheduleResult,
    SearchTimeout,
    brute_force_schedule,
    dp_schedule,
)
from repro.core.serenity import SerenityResult, execute, schedule
from repro.core.traffic import TrafficResult, simulate_traffic

__all__ = [
    "ArenaPlan",
    "BASELINES",
    "ExecutionResult",
    "ExecutorError",
    "Graph",
    "GraphError",
    "Node",
    "NoSolutionError",
    "PlanCache",
    "RealizedTracker",
    "RewriteReport",
    "ScheduleResult",
    "SearchTimeout",
    "Segment",
    "SerenityResult",
    "SimResult",
    "TrafficResult",
    "adaptive_budget_schedule",
    "annotate_inplace",
    "brute_force_schedule",
    "canonical_hash",
    "default_cache",
    "dfs_schedule",
    "dp_schedule",
    "execute",
    "execute_plan",
    "find_separators",
    "labeled_fingerprint",
    "greedy_schedule",
    "kahn_schedule",
    "partition",
    "plan_arena",
    "plan_arena_best",
    "rewrite_graph",
    "run_reference",
    "schedule",
    "simulate_schedule",
    "simulate_traffic",
]
