"""SERENITY end-to-end scheduling pipeline (paper Fig. 4) and executor.

    graph  ->  [identity graph rewriting]  ->  divide-and-conquer
           ->  per-segment adaptive-soft-budgeted DP  ->  combine
           ->  (peak footprint, arena plan, schedule)
           ->  execute: run the schedule against the planned arena

``schedule`` plans; ``execute`` realizes the plan on one donated arena
buffer and measures that the footprint the device would reserve equals the
planned bytes (DESIGN.md §6).  These are the public entry points the rest
of the framework uses.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.core.allocator import ArenaPlan, plan_arena_best
from repro.core.budget import BudgetSearchStats, adaptive_budget_schedule
from repro.core.executor import ExecutionResult, ExecutorError, execute_plan
from repro.core.graph import Graph, simulate_schedule
from repro.core.heuristics import BASELINES, kahn_schedule
from repro.core.partition import Segment, partition
from repro.core.plancache import PlanCache, resolve as _resolve_cache
from repro.core.rewriter import RewriteReport, annotate_inplace, rewrite_graph
from repro.core.scheduler import ScheduleResult, dp_schedule


@dataclasses.dataclass
class SerenityResult:
    graph: Graph                       # possibly rewritten graph actually scheduled
    order: list[int]
    peak_bytes: int                    # paper's footprint model (no allocator)
    arena: ArenaPlan                   # footprint through the linear allocator
    segments: list[Segment]
    rewrite_report: RewriteReport | None
    budget_stats: list[BudgetSearchStats]
    wall_time_s: float
    baseline_peaks: dict[str, int]     # heuristic peaks on the same graph

    @property
    def arena_bytes(self) -> int:
        return self.arena.arena_bytes


def schedule(
    g: Graph,
    *,
    rewrite: bool = True,
    inplace: bool = True,
    divide_and_conquer: bool = True,
    adaptive_budget: bool = True,
    state_quota: int = 20_000,
    exact_threshold: int = 18,
    compute_baselines: bool = True,
    engine: str = "auto",
    cache: "PlanCache | bool | None" = True,
) -> SerenityResult:
    """Run the full SERENITY pipeline on graph ``g``.

    Args:
      g: the dataflow graph to schedule (node sizes in *bytes*).
      rewrite: apply the paper's identity graph rewrites first (partial
        convs, concat views, fused-proj distribution); the returned
        ``SerenityResult.graph`` is the rewritten graph actually scheduled.
      inplace: with ``rewrite=True``, additionally mark in-place-eligible
        elementwise ops (:func:`~repro.core.rewriter.annotate_inplace`) so
        unary chains share one buffer end-to-end.
      divide_and_conquer: split at single-node separators and schedule each
        segment independently (paper Section 3.2).
      adaptive_budget: run the Algorithm 2 soft-budget meta-search on large
        segments instead of one unbudgeted DP.
      state_quota: deterministic stand-in for Algorithm 2's per-step
        timeout — maximum DP signatures per level before a step aborts.
      exact_threshold: segments with at most this many nodes skip the budget
        meta-search and run the exact DP directly (cheaper than a
        meta-search).
      compute_baselines: also evaluate the heuristic baselines (Kahn/greedy/
        DFS peaks, in bytes) on the final graph.
      engine: DP implementation (see :func:`repro.core.scheduler.dp_schedule`).
      cache: content-addressed plan memoization.  ``True`` (default) uses
        the process-wide :class:`~repro.core.plancache.PlanCache`; pass a
        :class:`PlanCache` to control capacity/disk placement, or ``False``
        to always recompute.  A hit returns the cold run's
        ``SerenityResult`` zero-copy (same order, same peaks, same arena
        plan — including the chosen allocator policy and offsets) in
        O(graph hash) time — treat cached results as immutable.

    Returns:
      A :class:`SerenityResult`: the (possibly rewritten) graph, the chosen
      ``order``, ``peak_bytes`` (liveness-model peak, bytes), the packed
      ``arena`` plan (``arena_bytes`` = bytes a device must reserve), the
      divide-and-conquer segments, rewrite/budget/baseline reports and the
      scheduling wall time in seconds.
    """
    pc = _resolve_cache(cache)
    cache_opts = (
        "serenity.schedule", rewrite, inplace, divide_and_conquer,
        adaptive_budget, state_quota, exact_threshold, compute_baselines,
        engine,
    )
    if pc is not None:
        hit = pc.get(g, cache_opts)
        if hit is not None:
            return hit

    t0 = time.perf_counter()
    g_in = g                      # cache key addresses the pre-rewrite graph
    report: RewriteReport | None = None
    if rewrite:
        g, report = rewrite_graph(g)
        if inplace:
            g, report.n_inplace = annotate_inplace(g)

    segments = (
        partition(g)
        if divide_and_conquer
        else [Segment(node_ids=g.topo_order(), boundary_in=[])]
    )

    order: list[int] = []
    budget_stats: list[BudgetSearchStats] = []
    for seg in segments:
        sub_ids = sorted(set(seg.node_ids) | set(seg.boundary_in))
        sub, idmap = g.induced_subgraph(sub_ids)
        inv = {v: k for k, v in idmap.items()}
        pre = tuple(idmap[b] for b in seg.boundary_in)
        n_free = len(sub) - len(pre)
        if n_free <= exact_threshold or not adaptive_budget:
            res = dp_schedule(sub, preplaced=pre, engine=engine)
        else:
            # Seed the meta-search with the tightest *feasible* budget any
            # heuristic achieves (beyond-paper: the paper seeds with Kahn
            # only).  Feasible taus can only shrink the search space.
            tau0 = min(fn(sub, preplaced=pre).peak_bytes
                       for fn in (kahn_schedule, BASELINES["greedy"],
                                  BASELINES["dfs"]))
            res, stats = adaptive_budget_schedule(
                sub, state_quota=state_quota, preplaced=pre, tau_max=tau0,
                engine=engine,
            )
            budget_stats.append(stats)
        order.extend(inv[u] for u in res.order)

    sim = simulate_schedule(g, order)
    arena = plan_arena_best(g, order)
    baselines: dict[str, int] = {}
    if compute_baselines:
        for name, fn in BASELINES.items():
            baselines[name] = fn(g).peak_bytes
    result = SerenityResult(
        graph=g,
        order=order,
        peak_bytes=sim.peak_bytes,
        arena=arena,
        segments=segments,
        rewrite_report=report,
        budget_stats=budget_stats,
        wall_time_s=time.perf_counter() - t0,
        baseline_peaks=baselines,
    )
    if pc is not None:
        pc.put(g_in, cache_opts, result)
    return result


def execute(
    g: Graph,
    inputs=None,
    plan: ArenaPlan | None = None,
    *,
    order: Sequence[int] | None = None,
    impl: str = "auto",
    interpret: bool = False,
    arena=None,
    jit: bool = False,
    strict: bool = True,
    **schedule_kw,
) -> ExecutionResult:
    """Schedule (if needed) and run ``g`` on the planned arena.

    The plan→execution closing move (DESIGN.md §6): every intermediate
    tensor lives as a slice of one donated arena buffer at its
    :class:`~repro.core.allocator.ArenaPlan` byte offset, and execution
    *measures* the realized footprint against the planned one.

    Args:
      g: graph to run.  When ``plan`` is ``None`` the full pipeline
        (:func:`schedule`, including rewriting) runs first and the rewritten
        graph is executed; when a ``plan`` is supplied, ``g`` must be the
        exact graph the plan was built from and ``order`` its schedule.
      inputs: values for the graph's input nodes — ``{name: array}``,
        ``{node_id: array}`` or a sequence in input-node order; flattened to
        float32.  Missing inputs get deterministic defaults.
      plan: an :class:`ArenaPlan` to realize (skips scheduling).
      order: the schedule ``plan`` was built from (required with ``plan``).
      impl / interpret / arena / jit / strict: forwarded to
        :func:`repro.core.executor.execute_plan` — slice-op dispatch
        (Pallas on TPU / XLA elsewhere), Pallas interpret mode, an optional
        donated float32 buffer, whole-program jit, and the
        realized-vs-planned assertion.
      **schedule_kw: forwarded to :func:`schedule` when planning here.

    Returns:
      :class:`~repro.core.executor.ExecutionResult` with the output values
      (flat float32, keyed by output-node name) and the measured
      ``realized_peak_bytes`` / ``realized_arena_bytes`` (both in bytes,
      asserted equal to the plan's ``peak_bytes`` / ``arena_bytes`` under
      ``strict``).
    """
    if plan is None:
        res = schedule(g, **schedule_kw)
        g, order, plan = res.graph, res.order, res.arena
    elif order is None:
        raise ExecutorError("execute: `order` is required when `plan` is "
                            "supplied (the schedule the plan was built from)")
    return execute_plan(g, order, plan, inputs, impl=impl,
                        interpret=interpret, arena=arena, jit=jit,
                        strict=strict)
