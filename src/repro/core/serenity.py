"""SERENITY end-to-end planning pipeline (paper Fig. 4) and executor.

    graph  ->  [identity graph rewriting]  ->  [rematerialization]
           ->  divide-and-conquer  ->  per-segment soft-budgeted DP
           ->  combine  ->  (peak footprint, arena plan, schedule)
           ->  execute: run the schedule against the planned arena

The public planning surface is one function and one config object:

    ``plan(graph, PlanConfig(...)) -> Plan``

``PlanConfig`` is a frozen dataclass holding every planning knob (rewrite,
recompute, scheduler choice, DP engine/budgets, arena policy); ``Plan``
bundles the scheduled graph, order, peaks, arena offsets and reports.
``execute`` realizes a plan on one donated arena buffer and measures that
the footprint the device would reserve equals the planned bytes
(DESIGN.md §6).

The pre-``PlanConfig`` entry points (``schedule``, ``schedule_order``,
``plan_coresidency`` with loose kwargs) keep working as deprecation shims:
each warns ``DeprecationWarning`` once per process and maps its kwargs onto
the equivalent ``PlanConfig``, producing an identical plan (and hitting the
same cache entries).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Sequence

from repro.core.allocator import (
    ArenaPlan,
    SharedArenaPlan,
    plan_arena,
    plan_arena_best,
    plan_arena_regions,
    plan_shared_arena,
)
from repro.core.budget import BudgetSearchStats, adaptive_budget_schedule
from repro.core.executor import ExecutionResult, ExecutorError, execute_plan
from repro.core.graph import Graph, simulate_schedule
from repro.core.heuristics import BASELINES, kahn_schedule
from repro.core.partition import Segment, partition_hierarchy
from repro.core.plancache import (
    PlanCache,
    resolve as _resolve_cache,
    translate_order,
)
from repro.core.rewriter import (
    RecomputeReport,
    RewriteReport,
    annotate_inplace,
    rematerialize,
    rewrite_graph,
)
from repro.core.graph import simulate_steps
from repro.core.scheduler import (
    ParetoFrontier,
    ScheduleResult,
    SearchTimeout,
    dp_schedule,
    node_costs,
    pareto_schedule,
)


_SCHEDULERS = ("dp", "kahn")
_ON_TIMEOUT = ("adaptive", "raise")
_OBJECTIVES = ("peak", "pareto")


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """Every planning knob, in one frozen, hashable, serializable object.

    Field groups mirror the pipeline stages (DESIGN.md §10):

    rewriting
      ``rewrite``: apply the paper's identity graph rewrites (partial convs,
      concat views, fused-proj distribution).  ``inplace``: additionally
      mark in-place-eligible elementwise ops so unary chains share one
      buffer (applied after rematerialization — cloning changes consumer
      counts and hence in-place eligibility).

    rematerialization
      ``recompute``: expand the graph with recompute clones
      (:func:`~repro.core.rewriter.rematerialize`) before ordering, trading
      up to ``flops_budget``x surrogate FLOPs for a lower schedulable peak.
      ``recompute_beam`` / ``recompute_rounds`` / ``recompute_quota`` bound
      the clone-set beam search (states kept per round / beam rounds / DP
      state quota per candidate evaluation).

    ordering
      ``scheduler``: ``'dp'`` runs the hierarchical exact pipeline;
      ``'kahn'`` takes the memory-greedy topological order outright — the
      right choice for graphs the DP models badly (e.g. serving decode
      state: dozens of isolated persistent buffers make the DP's bitmask
      space explode with nothing to gain).  The remaining knobs parameterize
      the DP: divide and conquer, the Algorithm 2 soft-budget fallback and
      its ``state_quota``, the ``exact_threshold`` below which cells skip
      the meta-search, the DP ``engine``, branch-and-bound (``bnb``), an
      optional hard peak budget ``tau`` (bytes), and the quota-exhaustion
      policy ``on_timeout`` (``'adaptive'`` or ``'raise'``).

    multi-objective
      ``objective='pareto'`` switches ordering to the two-objective
      time-slot DP (:func:`~repro.core.scheduler.pareto_schedule`): up to
      ``max_width`` ready ops execute per step, the full latency-vs-peak
      frontier lands in ``Plan.schedule_frontier``, and the realized plan
      is the min-peak point whose makespan fits ``latency_budget`` (bytes
      budget still via ``tau``).  Requires ``scheduler='dp'``;
      ``max_width`` / ``latency_budget`` are rejected under the default
      ``objective='peak'`` so a serial config can never silently mean two
      things.

    arena
      ``arena_policy``: offset-allocator placement policy (``'best'`` races
      them all).  ``resident``: node ids pinned live across the whole
      schedule at the bottom of the arena
      (:func:`~repro.core.allocator.plan_arena_regions` layout — the
      serving decode-state shape).

    reporting
      ``compute_baselines``: also evaluate the heuristic baselines on the
      final graph.
    """

    # -- graph rewriting --
    rewrite: bool = True
    inplace: bool = True
    # -- rematerialization --
    recompute: bool = False
    flops_budget: float = 1.3
    recompute_beam: int = 4
    recompute_rounds: int = 6
    recompute_quota: int = 800
    # -- ordering --
    scheduler: str = "dp"
    divide_and_conquer: bool = True
    adaptive_budget: bool = True
    state_quota: int | None = 20_000
    exact_threshold: int = 18
    engine: str = "auto"
    bnb: bool = True
    tau: int | None = None
    on_timeout: str = "adaptive"
    # -- multi-objective (latency x memory, DESIGN.md §12) --
    objective: str = "peak"
    max_width: int = 1
    latency_budget: int | None = None
    # -- arena --
    arena_policy: str = "best"
    resident: tuple[int, ...] = ()
    # -- reporting --
    compute_baselines: bool = True

    def __post_init__(self):
        if self.scheduler not in _SCHEDULERS:
            raise ValueError(
                f"PlanConfig.scheduler must be one of {_SCHEDULERS}, "
                f"got {self.scheduler!r}")
        if self.on_timeout not in _ON_TIMEOUT:
            raise ValueError(
                f"PlanConfig.on_timeout must be one of {_ON_TIMEOUT}, "
                f"got {self.on_timeout!r}")
        if self.flops_budget < 1.0:
            raise ValueError("PlanConfig.flops_budget must be >= 1.0 "
                             f"(got {self.flops_budget})")
        if self.objective not in _OBJECTIVES:
            raise ValueError(
                f"PlanConfig.objective must be one of {_OBJECTIVES}, "
                f"got {self.objective!r}")
        if self.max_width < 1:
            raise ValueError("PlanConfig.max_width must be >= 1 "
                             f"(got {self.max_width})")
        if self.objective == "pareto":
            if self.scheduler != "dp":
                raise ValueError(
                    "PlanConfig.objective='pareto' requires scheduler='dp' "
                    f"(got {self.scheduler!r})")
        elif self.max_width != 1 or self.latency_budget is not None:
            raise ValueError(
                "PlanConfig.max_width/latency_budget only apply under "
                "objective='pareto'")
        object.__setattr__(self, "resident", tuple(self.resident))

    def replace(self, **changes) -> "PlanConfig":
        """A copy with the given fields changed."""
        return dataclasses.replace(self, **changes)

    def cache_key(self) -> tuple:
        """Name-keyed serialized form for plan-cache addressing.

        Every field appears as a ``(name, value)`` pair, so adding a config
        field changes the key *shape* (clean cache miss) instead of
        silently aliasing entries the way positional option tuples did.
        """
        return tuple(sorted(dataclasses.asdict(self).items()))


@dataclasses.dataclass
class SerenityResult:
    """A complete plan: the scheduled graph, its order, peaks and arena.

    ``Plan`` is the preferred alias; :func:`plan` is the entry point that
    produces it.
    """

    graph: Graph                       # possibly rewritten graph actually scheduled
    order: list[int]
    peak_bytes: int                    # paper's footprint model (no allocator)
    arena: ArenaPlan                   # footprint through the linear allocator
    segments: list[Segment]
    rewrite_report: RewriteReport | None
    budget_stats: list[BudgetSearchStats]
    wall_time_s: float
    baseline_peaks: dict[str, int]     # heuristic peaks on the same graph
    exact: bool = True                 # every segment solved by the exact DP
    n_states_expanded: int = 0         # DP transitions summed over segments
    seg_cache_hits: int = 0            # segments replayed from the plan cache
    config: "PlanConfig | None" = None           # the config that built this
    recompute_report: "RecomputeReport | None" = None
    steps: "tuple[tuple[int, ...], ...] | None" = None  # width-W time slots
                                       # (objective='pareto'; None = serial)
    makespan: int = 0                  # surrogate-cost makespan of the order
    schedule_frontier: "ParetoFrontier | None" = None   # latency-vs-peak
                                       # frontier (objective='pareto' only)

    @property
    def arena_bytes(self) -> int:
        return self.arena.arena_bytes

    @property
    def pareto_frontier(self) -> tuple[tuple[float, int, int], ...]:
        """Recompute peak-vs-FLOPs frontier: (flops_ratio, peak_bytes,
        n_clones) points, or ``()`` when planned without recompute."""
        if self.recompute_report is None:
            return ()
        return self.recompute_report.frontier

    @property
    def latency_frontier(self) -> tuple[tuple[int, int], ...]:
        """Latency-vs-peak frontier: (makespan, peak_bytes) points sorted
        by makespan, or ``()`` when planned without ``objective='pareto'``.
        Distinct from :attr:`pareto_frontier`, the recompute FLOPs-vs-peak
        trade-off."""
        if self.schedule_frontier is None:
            return ()
        return self.schedule_frontier.pairs()

    @property
    def flops_ratio(self) -> float:
        """Executed/base surrogate-FLOPs ratio (1.0 = no recompute)."""
        if self.recompute_report is None:
            return 1.0
        return self.recompute_report.flops_ratio


Plan = SerenityResult


@dataclasses.dataclass
class SegmentPlan:
    """Cached DP result for one partition cell (anonymized subgraph)."""

    graph: Graph                       # the anonymized segment subgraph
    preplaced: tuple[int, ...]         # boundary ids within that subgraph
    result: ScheduleResult


@dataclasses.dataclass
class OrderResult:
    """A memory-optimal order for a whole graph, segment by segment."""

    order: list[int]
    exact: bool
    n_states_expanded: int
    n_signatures: int
    segments: list[Segment]
    seg_cache_hits: int
    budget_stats: list[BudgetSearchStats]


# Entry points that already delivered their DeprecationWarning this process
# (one warning per entry point, not per call).  Tests reset via
# _reset_deprecation_warnings().
_DEPRECATION_WARNED: set[str] = set()


def _warn_deprecated(entry: str, replacement: str) -> None:
    if entry in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(entry)
    warnings.warn(
        f"{entry} is deprecated; use {replacement}",
        DeprecationWarning, stacklevel=3)


def _reset_deprecation_warnings() -> None:
    """Forget which entry points already warned (test hook)."""
    _DEPRECATION_WARNED.clear()


def _order_graph(g: Graph, config: PlanConfig,
                 cache: PlanCache | None) -> OrderResult:
    """Hierarchically decompose ``g`` and DP-schedule each cell once.

    The nested segment tree (:func:`repro.core.partition.partition_hierarchy`)
    reduces the graph to leaf cells; each leaf's *anonymized* subgraph is
    DP-scheduled with the branch-and-bound search and memoized in the plan
    cache, so structurally identical cells — stacked RandWire/DARTS stages
    repeat — schedule once and replay (``seg_cache_hits``).  A relabeled
    isomorphic cell additionally tries the cache's canonical (WL) tier and
    rewrites the stored order through the color bijection
    (:func:`repro.core.plancache.translate_order`).

    Large cells run the branch-and-bound DP under ``config.state_quota``;
    ``config.on_timeout`` picks the quota-exhaustion policy: ``'adaptive'``
    falls back to the Algorithm 2 budget meta-search — and, if even that
    capitulates to a heuristic order, to a bounded per-cell beam, keeping
    the better of the two inexact orders — while ``'raise'`` propagates
    :class:`~repro.core.scheduler.SearchTimeout` to the caller.  ``exact``
    reports whether every cell was solved exactly.  When ``cache`` is None
    an ephemeral per-call cache still provides in-run cell reuse.
    """
    if config.divide_and_conquer:
        leaves = partition_hierarchy(g).leaves()
        segments = [Segment(node_ids=list(lf.node_ids),
                            boundary_in=list(lf.boundary_in))
                    for lf in leaves]
    else:
        segments = [Segment(node_ids=g.topo_order(), boundary_in=[])]

    engine, state_quota = config.engine, config.state_quota
    seg_cache = cache if cache is not None else PlanCache(capacity=64)
    order: list[int] = []
    budget_stats: list[BudgetSearchStats] = []
    exact = True
    expanded = 0
    n_signatures = 0
    hits = 0
    for seg in segments:
        sub_ids = sorted(set(seg.node_ids) | set(seg.boundary_in))
        sub, idmap = g.induced_subgraph(sub_ids, anonymize=True)
        inv = {v: k for k, v in idmap.items()}
        pre = tuple(sorted(idmap[b] for b in seg.boundary_in))
        opts = ("dp_segment", pre, engine, state_quota,
                config.exact_threshold, config.adaptive_budget, config.bnb,
                config.tau)
        seg_plan = seg_cache.get(sub, opts)
        if seg_plan is None:
            iso = seg_cache.get_canonical(sub, opts)
            if isinstance(iso, SegmentPlan):
                k = len(iso.result.order)
                translated = translate_order(
                    iso.graph, sub,
                    list(iso.result.order) + list(iso.preplaced))
                if translated is not None and \
                        sorted(translated[k:]) == sorted(pre):
                    seg_plan = SegmentPlan(
                        graph=sub, preplaced=pre,
                        result=dataclasses.replace(
                            iso.result, order=translated[:k]),
                    )
                    seg_cache.put(sub, opts, seg_plan)
        if seg_plan is not None:
            hits += 1
            res = seg_plan.result
            searched = False
        else:
            searched = True
            n_free = len(sub) - len(pre)
            if n_free <= config.exact_threshold or not config.adaptive_budget:
                res = dp_schedule(sub, preplaced=pre, engine=engine,
                                  bnb=config.bnb, budget=config.tau)
            else:
                try:
                    res = dp_schedule(sub, preplaced=pre, engine=engine,
                                      state_quota=state_quota,
                                      bnb=config.bnb, budget=config.tau)
                except SearchTimeout:
                    if config.on_timeout == "raise":
                        raise
                    # Algorithm 2 fallback: budget meta-search with quota
                    # escalation (terminates; may capitulate to a heuristic
                    # order, which clears the `exact` flag)
                    res, stats = adaptive_budget_schedule(
                        sub, state_quota=state_quota, preplaced=pre,
                        engine=engine,
                    )
                    budget_stats.append(stats)
                    if not res.exact:
                        # meta-search capitulated to a heuristic order: a
                        # bounded beam usually does better — keep the lower
                        # peak (both are inexact)
                        beam = dp_schedule(sub, preplaced=pre, engine=engine,
                                           state_quota=state_quota,
                                           on_quota="beam")
                        if beam.peak_bytes < res.peak_bytes:
                            res = beam
            seg_cache.put(sub, opts, SegmentPlan(sub, pre, res))
        order.extend(inv[u] for u in res.order)
        exact = exact and res.exact
        if searched:          # replayed cells did no search work
            expanded += res.n_states_expanded
            n_signatures += res.n_signatures
    return OrderResult(
        order=order,
        exact=exact,
        n_states_expanded=expanded,
        n_signatures=n_signatures,
        segments=segments,
        seg_cache_hits=hits,
        budget_stats=budget_stats,
    )


def schedule_order(
    g: Graph,
    *,
    divide_and_conquer: bool = True,
    adaptive_budget: bool = True,
    state_quota: int | None = 20_000,
    exact_threshold: int = 18,
    engine: str = "auto",
    cache: PlanCache | None = None,
    on_timeout: str = "adaptive",
) -> OrderResult:
    """Deprecated shim: order ``g`` with kwargs instead of a `PlanConfig`.

    Maps its kwargs onto :class:`PlanConfig` and runs the same hierarchical
    ordering pipeline :func:`plan` uses.  Warns ``DeprecationWarning`` once
    per process.
    """
    _warn_deprecated(
        "serenity.schedule_order(**kwargs)",
        "serenity.plan(graph, PlanConfig(...)) and Plan.order")
    config = PlanConfig(
        divide_and_conquer=divide_and_conquer,
        adaptive_budget=adaptive_budget,
        state_quota=state_quota,
        exact_threshold=exact_threshold,
        engine=engine,
        on_timeout=on_timeout,
    )
    return _order_graph(g, config, cache)


def plan(
    g: Graph,
    config: PlanConfig | None = None,
    *,
    order: Sequence[int] | None = None,
    cache: "PlanCache | bool | None" = True,
) -> Plan:
    """Run the full SERENITY planning pipeline on graph ``g``.

    The one planning entry point: rewrite (+ optional rematerialization) →
    order (hierarchical exact DP, or the Kahn heuristic, per
    ``config.scheduler``) → arena offsets, bundled into a single
    :class:`Plan`.

    Args:
      g: the dataflow graph to plan (node sizes in *bytes*).
      config: a :class:`PlanConfig`; ``None`` means ``PlanConfig()`` (all
        defaults: rewrite + in-place + hierarchical exact DP + best-of
        arena policies, no recompute).
      order: pre-computed schedule of ``g`` to pack an arena for, skipping
        the rewrite and ordering stages entirely (the resulting plan's
        ``exact`` flag is False — nothing was proven about the order).
      cache: content-addressed plan memoization.  ``True`` (default) uses
        the process-wide :class:`~repro.core.plancache.PlanCache`; pass a
        :class:`PlanCache` to control capacity/disk placement, or ``False``
        to always recompute.  Keys derive from ``config.cache_key()`` —
        name-keyed, so the legacy shims and direct calls with equivalent
        configs hit the same entries.  A hit returns the cold run's
        :class:`Plan` zero-copy — treat cached plans as immutable.

    Returns:
      A :class:`Plan`: the (possibly rewritten/expanded) graph actually
      scheduled, the chosen ``order``, ``peak_bytes`` (liveness-model peak,
      bytes), the packed ``arena`` plan (``arena_bytes`` = bytes a device
      must reserve), segments, rewrite/recompute/budget/baseline reports,
      the originating ``config`` and the planning wall time in seconds.
      With ``config.recompute``, ``plan.pareto_frontier`` holds the
      peak-vs-FLOPs frontier and ``plan.graph`` contains the executable
      recompute clones of its lowest-peak point.
    """
    if config is None:
        config = PlanConfig()
    if order is not None and config.objective == "pareto":
        raise ValueError("plan: a pre-computed order cannot be combined "
                         "with objective='pareto' (the frontier chooses "
                         "the order)")
    pc = _resolve_cache(cache)
    cache_opts = ("serenity.plan", config.cache_key())
    if order is not None:
        order = list(order)
        cache_opts += (("order", tuple(order)),)
    if pc is not None:
        hit = pc.get(g, cache_opts)
        if hit is not None:
            return hit

    t0 = time.perf_counter()
    g_in = g                      # cache key addresses the pre-rewrite graph
    rewrite_report: RewriteReport | None = None
    recompute_report: RecomputeReport | None = None
    if order is None:
        if config.rewrite:
            g, rewrite_report = rewrite_graph(g)
        if config.recompute:
            g, recompute_report = rematerialize(
                g,
                flops_budget=config.flops_budget,
                beam_width=config.recompute_beam,
                max_rounds=config.recompute_rounds,
                eval_quota=config.recompute_quota,
                inplace=config.inplace,
            )
        # in-place marking runs after cloning: a recompute clone changes its
        # original's consumer count, which changes in-place eligibility
        if config.inplace and (config.rewrite or config.recompute):
            g, n_inplace = annotate_inplace(g)
            if rewrite_report is not None:
                rewrite_report.n_inplace = n_inplace

    steps: "tuple[tuple[int, ...], ...] | None" = None
    frontier: ParetoFrontier | None = None
    if order is not None:
        ores = OrderResult(order=order, exact=False, n_states_expanded=0,
                           n_signatures=0, segments=[], seg_cache_hits=0,
                           budget_stats=[])
    elif config.objective == "pareto":
        # direct two-objective DP on the whole (rewritten) graph: the
        # frontier's serial endpoint is seeded from the exact serial DP, so
        # it equals the hierarchical pipeline's peak even if the Pareto
        # level search gets beam-trimmed (DESIGN.md §12)
        frontier = pareto_schedule(
            g,
            max_width=config.max_width,
            latency_budget=config.latency_budget,
            budget=config.tau,
            state_quota=config.state_quota,
            on_quota="beam" if config.on_timeout == "adaptive" else "raise",
        )
        point = frontier.best_under(config.latency_budget)
        steps = point.steps
        ores = OrderResult(order=point.order, exact=frontier.exact,
                           n_states_expanded=frontier.n_states_expanded,
                           n_signatures=frontier.n_signatures, segments=[],
                           seg_cache_hits=0, budget_stats=[])
    elif config.scheduler == "kahn":
        ores = OrderResult(order=kahn_schedule(g).order, exact=False,
                           n_states_expanded=0, n_signatures=0, segments=[],
                           seg_cache_hits=0, budget_stats=[])
    else:
        ores = _order_graph(g, config, pc)

    if steps is not None:
        sim = simulate_steps(g, steps)
    else:
        sim = simulate_schedule(g, ores.order)
    if config.resident:
        arena = plan_arena_regions(g, ores.order,
                                   resident=list(config.resident),
                                   steps=steps)
    elif config.arena_policy == "best":
        arena = plan_arena_best(g, ores.order, steps=steps)
    else:
        arena = plan_arena(g, ores.order, policy=config.arena_policy,
                           steps=steps)
    baselines: dict[str, int] = {}
    if config.compute_baselines:
        for name, fn in BASELINES.items():
            baselines[name] = fn(g).peak_bytes
    costs = node_costs(g)
    if steps is not None:
        makespan = sum(max(costs[u] for u in st) for st in steps if st)
    else:
        makespan = sum(costs[u] for u in ores.order)
    result = Plan(
        graph=g,
        order=ores.order,
        peak_bytes=sim.peak_bytes,
        arena=arena,
        segments=ores.segments,
        rewrite_report=rewrite_report,
        budget_stats=ores.budget_stats,
        wall_time_s=time.perf_counter() - t0,
        baseline_peaks=baselines,
        exact=ores.exact,
        n_states_expanded=ores.n_states_expanded,
        seg_cache_hits=ores.seg_cache_hits,
        config=config,
        recompute_report=recompute_report,
        steps=steps,
        makespan=makespan,
        schedule_frontier=frontier,
    )
    if pc is not None:
        pc.put(g_in, cache_opts, result)
    return result


def schedule(
    g: Graph,
    *,
    rewrite: bool = True,
    inplace: bool = True,
    divide_and_conquer: bool = True,
    adaptive_budget: bool = True,
    state_quota: int = 20_000,
    exact_threshold: int = 18,
    compute_baselines: bool = True,
    engine: str = "auto",
    cache: "PlanCache | bool | None" = True,
) -> SerenityResult:
    """Deprecated shim: the pre-``PlanConfig`` pipeline entry point.

    Maps its kwargs onto the equivalent :class:`PlanConfig` and calls
    :func:`plan` — the result is identical (and hits the same cache
    entries).  Warns ``DeprecationWarning`` once per process.
    """
    _warn_deprecated("serenity.schedule(**kwargs)",
                     "serenity.plan(graph, PlanConfig(...))")
    return plan(g, _legacy_schedule_config(
        rewrite=rewrite, inplace=inplace,
        divide_and_conquer=divide_and_conquer,
        adaptive_budget=adaptive_budget, state_quota=state_quota,
        exact_threshold=exact_threshold,
        compute_baselines=compute_baselines, engine=engine,
    ), cache=cache)


def _legacy_schedule_config(**kwargs) -> PlanConfig:
    """The ``PlanConfig`` a legacy ``schedule(**kwargs)`` call maps onto."""
    return PlanConfig(**kwargs)


# `execute` has a parameter named `plan` (the arena plan to realize), so the
# planning function needs an unshadowed module-level alias there.
_plan = plan


def plan_coresidency(
    graphs: Sequence[Graph],
    budget: int | None = None,
    *,
    serialize: bool = True,
    config: PlanConfig | None = None,
    cache: "PlanCache | bool | None" = True,
    **schedule_kw,
) -> tuple[SharedArenaPlan, list[SerenityResult]]:
    """Plan each graph, then co-plan all their arenas into one buffer.

    The multi-tenant composition of the pipeline (DESIGN.md §9): each graph
    gets its own optimal schedule and standalone arena plan via
    :func:`plan`, and :func:`~repro.core.allocator.plan_shared_arena`
    overlaps the members' non-concurrent slack inside one joint buffer.
    Each returned ``members[i]`` plan can execute against the shared buffer
    directly (``execute_plan(res.graph, res.order, shared.members[i],
    arena=buf)``).

    Legacy ``schedule``-style kwargs are accepted as a deprecation shim
    (warns once) and map onto ``config``; passing both is an error.

    Returns ``(shared_plan, per-graph Plans)``; callers check
    ``shared_plan.fits(budget)`` for admission decisions.
    """
    if schedule_kw:
        if config is not None:
            raise TypeError("plan_coresidency: pass either config= or "
                            "legacy schedule kwargs, not both")
        _warn_deprecated(
            "plan_coresidency(**schedule_kwargs)",
            "plan_coresidency(graphs, budget, config=PlanConfig(...))")
        config = _legacy_schedule_config(**schedule_kw)
    results = [plan(g, config, cache=cache) for g in graphs]
    shared = plan_shared_arena([r.arena for r in results], budget,
                               serialize=serialize)
    return shared, results


def execute(
    g: Graph,
    inputs=None,
    plan: ArenaPlan | None = None,
    *,
    order: Sequence[int] | None = None,
    impl: str = "auto",
    interpret: bool = False,
    arena=None,
    jit: bool = False,
    strict: bool = True,
    fuse: bool = False,
    steps: "Sequence[Sequence[int]] | None" = None,
    config: PlanConfig | None = None,
    cache: "PlanCache | bool | None" = True,
    **schedule_kw,
) -> ExecutionResult:
    """Schedule (if needed) and run ``g`` on the planned arena.

    The plan→execution closing move (DESIGN.md §6): every intermediate
    tensor lives as a slice of one donated arena buffer at its
    :class:`~repro.core.allocator.ArenaPlan` byte offset, and execution
    *measures* the realized footprint against the planned one.

    Args:
      g: graph to run.  When ``plan`` is ``None`` the full pipeline
        (:func:`schedule`, including rewriting) runs first and the rewritten
        graph is executed; when a ``plan`` is supplied, ``g`` must be the
        exact graph the plan was built from and ``order`` its schedule.
      inputs: values for the graph's input nodes — ``{name: array}``,
        ``{node_id: array}`` or a sequence in input-node order; flattened to
        float32.  Missing inputs get deterministic defaults.
      plan: an :class:`ArenaPlan` to realize (skips scheduling).
      order: the schedule ``plan`` was built from (required with ``plan``).
      impl / interpret / arena / jit / strict / fuse: forwarded to
        :func:`repro.core.executor.execute_plan` — slice-op dispatch
        (Pallas on TPU / XLA elsewhere), Pallas interpret mode, an optional
        donated float32 buffer, whole-program jit, the
        realized-vs-planned assertion, and fused alias-chain execution
        (DESIGN.md §11).
      steps: width-W time slots the supplied ``plan`` was packed with
        (``Plan.steps`` of a pareto plan); ignored when planning here —
        the fresh plan's own steps are used.
      config / cache: forwarded to :func:`plan` when planning here.
      **schedule_kw: legacy ``schedule``-style kwargs (deprecation shim,
        warns once); mapped onto ``config`` — passing both is an error.

    Returns:
      :class:`~repro.core.executor.ExecutionResult` with the output values
      (flat float32, keyed by output-node name) and the measured
      ``realized_peak_bytes`` / ``realized_arena_bytes`` (both in bytes,
      asserted equal to the plan's ``peak_bytes`` / ``arena_bytes`` under
      ``strict``).
    """
    if plan is None:
        if schedule_kw:
            if config is not None:
                raise TypeError("execute: pass either config= or legacy "
                                "schedule kwargs, not both")
            _warn_deprecated(
                "execute(**schedule_kwargs)",
                "execute(g, config=PlanConfig(...))")
            config = _legacy_schedule_config(**schedule_kw)
        res = _plan(g, config, cache=cache)
        g, order, plan = res.graph, res.order, res.arena
        steps = res.steps  # pareto plans carry their width-W slots
    elif order is None:
        raise ExecutorError("execute: `order` is required when `plan` is "
                            "supplied (the schedule the plan was built from)")
    return execute_plan(g, order, plan, inputs, impl=impl,
                        interpret=interpret, arena=arena, jit=jit,
                        strict=strict, fuse=fuse, steps=steps)
