"""SERENITY end-to-end scheduling pipeline (paper Fig. 4) and executor.

    graph  ->  [identity graph rewriting]  ->  divide-and-conquer
           ->  per-segment adaptive-soft-budgeted DP  ->  combine
           ->  (peak footprint, arena plan, schedule)
           ->  execute: run the schedule against the planned arena

``schedule`` plans; ``execute`` realizes the plan on one donated arena
buffer and measures that the footprint the device would reserve equals the
planned bytes (DESIGN.md §6).  These are the public entry points the rest
of the framework uses.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.core.allocator import (
    ArenaPlan,
    SharedArenaPlan,
    plan_arena_best,
    plan_shared_arena,
)
from repro.core.budget import BudgetSearchStats, adaptive_budget_schedule
from repro.core.executor import ExecutionResult, ExecutorError, execute_plan
from repro.core.graph import Graph, simulate_schedule
from repro.core.heuristics import BASELINES
from repro.core.partition import Segment, partition_hierarchy
from repro.core.plancache import (
    PlanCache,
    resolve as _resolve_cache,
    translate_order,
)
from repro.core.rewriter import RewriteReport, annotate_inplace, rewrite_graph
from repro.core.scheduler import ScheduleResult, SearchTimeout, dp_schedule


@dataclasses.dataclass
class SerenityResult:
    graph: Graph                       # possibly rewritten graph actually scheduled
    order: list[int]
    peak_bytes: int                    # paper's footprint model (no allocator)
    arena: ArenaPlan                   # footprint through the linear allocator
    segments: list[Segment]
    rewrite_report: RewriteReport | None
    budget_stats: list[BudgetSearchStats]
    wall_time_s: float
    baseline_peaks: dict[str, int]     # heuristic peaks on the same graph
    exact: bool = True                 # every segment solved by the exact DP
    n_states_expanded: int = 0         # DP transitions summed over segments
    seg_cache_hits: int = 0            # segments replayed from the plan cache

    @property
    def arena_bytes(self) -> int:
        return self.arena.arena_bytes


@dataclasses.dataclass
class SegmentPlan:
    """Cached DP result for one partition cell (anonymized subgraph)."""

    graph: Graph                       # the anonymized segment subgraph
    preplaced: tuple[int, ...]         # boundary ids within that subgraph
    result: ScheduleResult


@dataclasses.dataclass
class OrderResult:
    """A memory-optimal order for a whole graph, segment by segment."""

    order: list[int]
    exact: bool
    n_states_expanded: int
    n_signatures: int
    segments: list[Segment]
    seg_cache_hits: int
    budget_stats: list[BudgetSearchStats]


def schedule_order(
    g: Graph,
    *,
    divide_and_conquer: bool = True,
    adaptive_budget: bool = True,
    state_quota: int | None = 20_000,
    exact_threshold: int = 18,
    engine: str = "auto",
    cache: PlanCache | None = None,
    on_timeout: str = "adaptive",
) -> OrderResult:
    """Hierarchically decompose ``g`` and DP-schedule each cell once.

    The nested segment tree (:func:`repro.core.partition.partition_hierarchy`)
    reduces the graph to leaf cells; each leaf's *anonymized* subgraph is
    DP-scheduled with the branch-and-bound search and memoized in the plan
    cache, so structurally identical cells — stacked RandWire/DARTS stages
    repeat — schedule once and replay (``seg_cache_hits``).  A relabeled
    isomorphic cell additionally tries the cache's canonical (WL) tier and
    rewrites the stored order through the color bijection
    (:func:`repro.core.plancache.translate_order`).

    Large cells run the branch-and-bound DP under ``state_quota``;
    ``on_timeout`` picks the quota-exhaustion policy: ``'adaptive'``
    (default) falls back to the Algorithm 2 budget meta-search — and, if
    even that capitulates to a heuristic order, to a bounded per-cell beam,
    keeping the better of the two inexact orders — while ``'raise'``
    propagates :class:`~repro.core.scheduler.SearchTimeout` to the caller.
    ``exact`` reports whether every cell was solved exactly (no beam, no
    heuristic capitulation).  When ``cache`` is None an ephemeral per-call
    cache still provides in-run cell reuse.
    """
    if divide_and_conquer:
        leaves = partition_hierarchy(g).leaves()
        segments = [Segment(node_ids=list(lf.node_ids),
                            boundary_in=list(lf.boundary_in))
                    for lf in leaves]
    else:
        segments = [Segment(node_ids=g.topo_order(), boundary_in=[])]

    seg_cache = cache if cache is not None else PlanCache(capacity=64)
    order: list[int] = []
    budget_stats: list[BudgetSearchStats] = []
    exact = True
    expanded = 0
    n_signatures = 0
    hits = 0
    for seg in segments:
        sub_ids = sorted(set(seg.node_ids) | set(seg.boundary_in))
        sub, idmap = g.induced_subgraph(sub_ids, anonymize=True)
        inv = {v: k for k, v in idmap.items()}
        pre = tuple(sorted(idmap[b] for b in seg.boundary_in))
        opts = ("dp_segment", pre, engine, state_quota, exact_threshold,
                adaptive_budget)
        plan = seg_cache.get(sub, opts)
        if plan is None:
            iso = seg_cache.get_canonical(sub, opts)
            if isinstance(iso, SegmentPlan):
                k = len(iso.result.order)
                translated = translate_order(
                    iso.graph, sub,
                    list(iso.result.order) + list(iso.preplaced))
                if translated is not None and \
                        sorted(translated[k:]) == sorted(pre):
                    plan = SegmentPlan(
                        graph=sub, preplaced=pre,
                        result=dataclasses.replace(
                            iso.result, order=translated[:k]),
                    )
                    seg_cache.put(sub, opts, plan)
        if plan is not None:
            hits += 1
            res = plan.result
            searched = False
        else:
            searched = True
            n_free = len(sub) - len(pre)
            if n_free <= exact_threshold or not adaptive_budget:
                res = dp_schedule(sub, preplaced=pre, engine=engine)
            else:
                try:
                    res = dp_schedule(sub, preplaced=pre, engine=engine,
                                      state_quota=state_quota)
                except SearchTimeout:
                    if on_timeout == "raise":
                        raise
                    # Algorithm 2 fallback: budget meta-search with quota
                    # escalation (terminates; may capitulate to a heuristic
                    # order, which clears the `exact` flag)
                    res, stats = adaptive_budget_schedule(
                        sub, state_quota=state_quota, preplaced=pre,
                        engine=engine,
                    )
                    budget_stats.append(stats)
                    if not res.exact:
                        # meta-search capitulated to a heuristic order: a
                        # bounded beam usually does better — keep the lower
                        # peak (both are inexact)
                        beam = dp_schedule(sub, preplaced=pre, engine=engine,
                                           state_quota=state_quota,
                                           on_quota="beam")
                        if beam.peak_bytes < res.peak_bytes:
                            res = beam
            seg_cache.put(sub, opts, SegmentPlan(sub, pre, res))
        order.extend(inv[u] for u in res.order)
        exact = exact and res.exact
        if searched:          # replayed cells did no search work
            expanded += res.n_states_expanded
            n_signatures += res.n_signatures
    return OrderResult(
        order=order,
        exact=exact,
        n_states_expanded=expanded,
        n_signatures=n_signatures,
        segments=segments,
        seg_cache_hits=hits,
        budget_stats=budget_stats,
    )


def schedule(
    g: Graph,
    *,
    rewrite: bool = True,
    inplace: bool = True,
    divide_and_conquer: bool = True,
    adaptive_budget: bool = True,
    state_quota: int = 20_000,
    exact_threshold: int = 18,
    compute_baselines: bool = True,
    engine: str = "auto",
    cache: "PlanCache | bool | None" = True,
) -> SerenityResult:
    """Run the full SERENITY pipeline on graph ``g``.

    Args:
      g: the dataflow graph to schedule (node sizes in *bytes*).
      rewrite: apply the paper's identity graph rewrites first (partial
        convs, concat views, fused-proj distribution); the returned
        ``SerenityResult.graph`` is the rewritten graph actually scheduled.
      inplace: with ``rewrite=True``, additionally mark in-place-eligible
        elementwise ops (:func:`~repro.core.rewriter.annotate_inplace`) so
        unary chains share one buffer end-to-end.
      divide_and_conquer: reduce the graph to the leaves of the nested
        segment tree (:func:`repro.core.partition.partition_hierarchy`) and
        schedule each cell independently (paper Section 3.2, hierarchical);
        structurally identical cells are DP-scheduled once and replayed via
        the plan cache (``SerenityResult.seg_cache_hits``).
      adaptive_budget: large segments run the branch-and-bound DP under
        ``state_quota`` and fall back to the Algorithm 2 soft-budget
        meta-search on timeout.
      state_quota: deterministic stand-in for Algorithm 2's per-step
        timeout — maximum DP signatures per level before a step aborts.
      exact_threshold: segments with at most this many nodes skip the budget
        meta-search and run the exact DP directly (cheaper than a
        meta-search).
      compute_baselines: also evaluate the heuristic baselines (Kahn/greedy/
        DFS peaks, in bytes) on the final graph.
      engine: DP implementation (see :func:`repro.core.scheduler.dp_schedule`).
      cache: content-addressed plan memoization.  ``True`` (default) uses
        the process-wide :class:`~repro.core.plancache.PlanCache`; pass a
        :class:`PlanCache` to control capacity/disk placement, or ``False``
        to always recompute.  A hit returns the cold run's
        ``SerenityResult`` zero-copy (same order, same peaks, same arena
        plan — including the chosen allocator policy and offsets) in
        O(graph hash) time — treat cached results as immutable.

    Returns:
      A :class:`SerenityResult`: the (possibly rewritten) graph, the chosen
      ``order``, ``peak_bytes`` (liveness-model peak, bytes), the packed
      ``arena`` plan (``arena_bytes`` = bytes a device must reserve), the
      divide-and-conquer segments, rewrite/budget/baseline reports and the
      scheduling wall time in seconds.
    """
    pc = _resolve_cache(cache)
    cache_opts = (
        "serenity.schedule", rewrite, inplace, divide_and_conquer,
        adaptive_budget, state_quota, exact_threshold, compute_baselines,
        engine,
    )
    if pc is not None:
        hit = pc.get(g, cache_opts)
        if hit is not None:
            return hit

    t0 = time.perf_counter()
    g_in = g                      # cache key addresses the pre-rewrite graph
    report: RewriteReport | None = None
    if rewrite:
        g, report = rewrite_graph(g)
        if inplace:
            g, report.n_inplace = annotate_inplace(g)

    ores = schedule_order(
        g,
        divide_and_conquer=divide_and_conquer,
        adaptive_budget=adaptive_budget,
        state_quota=state_quota,
        exact_threshold=exact_threshold,
        engine=engine,
        cache=pc,
    )

    sim = simulate_schedule(g, ores.order)
    arena = plan_arena_best(g, ores.order)
    baselines: dict[str, int] = {}
    if compute_baselines:
        for name, fn in BASELINES.items():
            baselines[name] = fn(g).peak_bytes
    result = SerenityResult(
        graph=g,
        order=ores.order,
        peak_bytes=sim.peak_bytes,
        arena=arena,
        segments=ores.segments,
        rewrite_report=report,
        budget_stats=ores.budget_stats,
        wall_time_s=time.perf_counter() - t0,
        baseline_peaks=baselines,
        exact=ores.exact,
        n_states_expanded=ores.n_states_expanded,
        seg_cache_hits=ores.seg_cache_hits,
    )
    if pc is not None:
        pc.put(g_in, cache_opts, result)
    return result


def plan_coresidency(
    graphs: Sequence[Graph],
    budget: int | None = None,
    *,
    serialize: bool = True,
    **schedule_kw,
) -> tuple[SharedArenaPlan, list[SerenityResult]]:
    """Schedule each graph, then co-plan all their arenas into one buffer.

    The multi-tenant composition of the pipeline (DESIGN.md §9): each graph
    gets its own optimal schedule and standalone arena plan via
    :func:`schedule`, and :func:`~repro.core.allocator.plan_shared_arena`
    overlaps the members' non-concurrent slack inside one joint buffer.
    Each returned ``members[i]`` plan can execute against the shared buffer
    directly (``execute_plan(res.graph, res.order, shared.members[i],
    arena=buf)``).

    Returns ``(shared_plan, per-graph SerenityResults)``; callers check
    ``shared_plan.fits(budget)`` for admission decisions.
    """
    results = [schedule(g, **schedule_kw) for g in graphs]
    shared = plan_shared_arena([r.arena for r in results], budget,
                               serialize=serialize)
    return shared, results


def execute(
    g: Graph,
    inputs=None,
    plan: ArenaPlan | None = None,
    *,
    order: Sequence[int] | None = None,
    impl: str = "auto",
    interpret: bool = False,
    arena=None,
    jit: bool = False,
    strict: bool = True,
    **schedule_kw,
) -> ExecutionResult:
    """Schedule (if needed) and run ``g`` on the planned arena.

    The plan→execution closing move (DESIGN.md §6): every intermediate
    tensor lives as a slice of one donated arena buffer at its
    :class:`~repro.core.allocator.ArenaPlan` byte offset, and execution
    *measures* the realized footprint against the planned one.

    Args:
      g: graph to run.  When ``plan`` is ``None`` the full pipeline
        (:func:`schedule`, including rewriting) runs first and the rewritten
        graph is executed; when a ``plan`` is supplied, ``g`` must be the
        exact graph the plan was built from and ``order`` its schedule.
      inputs: values for the graph's input nodes — ``{name: array}``,
        ``{node_id: array}`` or a sequence in input-node order; flattened to
        float32.  Missing inputs get deterministic defaults.
      plan: an :class:`ArenaPlan` to realize (skips scheduling).
      order: the schedule ``plan`` was built from (required with ``plan``).
      impl / interpret / arena / jit / strict: forwarded to
        :func:`repro.core.executor.execute_plan` — slice-op dispatch
        (Pallas on TPU / XLA elsewhere), Pallas interpret mode, an optional
        donated float32 buffer, whole-program jit, and the
        realized-vs-planned assertion.
      **schedule_kw: forwarded to :func:`schedule` when planning here.

    Returns:
      :class:`~repro.core.executor.ExecutionResult` with the output values
      (flat float32, keyed by output-node name) and the measured
      ``realized_peak_bytes`` / ``realized_arena_bytes`` (both in bytes,
      asserted equal to the plan's ``peak_bytes`` / ``arena_bytes`` under
      ``strict``).
    """
    if plan is None:
        res = schedule(g, **schedule_kw)
        g, order, plan = res.graph, res.order, res.arena
    elif order is None:
        raise ExecutorError("execute: `order` is required when `plan` is "
                            "supplied (the schedule the plan was built from)")
    return execute_plan(g, order, plan, inputs, impl=impl,
                        interpret=interpret, arena=arena, jit=jit,
                        strict=strict)
