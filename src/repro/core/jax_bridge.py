"""SERENITY <-> JAX integration: schedule jaxprs for minimal live memory.

The paper schedules operator graphs of edge networks; a jaxpr is the same
thing one level down — a DAG of equations whose issue order determines how
long each output buffer stays live.  XLA's buffer assigner honours (unfused)
program order, so reordering equations with the paper's DP lowers the
activation high-watermark exactly the way the paper lowers TFLite's arena
peak.

Public API
----------
jaxpr_to_graph(closed_jaxpr)          -> (Graph, eqn_nodes)
schedule_jaxpr(closed_jaxpr, ...)     -> (reordered ClosedJaxpr, report)
serenity_transform(fn)(*args)         -> fn with memory-optimal eqn order
analyze_fn(fn, *args)                 -> footprint report (no transform)
memory_aware_remat(fn, budget, *args) -> fn or jax.checkpoint(fn) chosen by
                                         the scheduler's footprint analysis
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core
from jax._src.core import eval_jaxpr as _eval_jaxpr

from repro.core.allocator import plan_arena_best
from repro.core.graph import Graph, simulate_schedule
from repro.core.heuristics import kahn_schedule
from repro.core.plancache import PlanCache, resolve as _resolve_cache
from repro.core.scheduler import dp_schedule
from repro.core.budget import adaptive_budget_schedule
from repro.core.scheduler import SearchTimeout


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def jaxpr_to_graph(closed) -> tuple[Graph, list[int]]:
    """Lift a ClosedJaxpr into the SERENITY IR.

    Node 0..n_in-1: the jaxpr invars (op='input').  One node per equation
    afterwards; the node's cost is the sum of its output aval bytes.
    Returns (graph, eqn_node_ids) where eqn_node_ids[i] is the node id of
    equation i.
    """
    jaxpr = closed.jaxpr
    specs: list[dict] = []
    producer: dict[Any, int] = {}

    for v in jaxpr.invars:
        nid = len(specs)
        specs.append(dict(name=f"in{nid}", op="input",
                          size_bytes=_aval_bytes(v.aval), preds=[]))
        producer[v] = nid
    eqn_nodes: list[int] = []
    for i, eqn in enumerate(jaxpr.eqns):
        preds = []
        for v in eqn.invars:
            if isinstance(v, core.Literal):
                continue
            if v in producer:
                preds.append(producer[v])
        size = sum(_aval_bytes(o.aval) for o in eqn.outvars)
        nid = len(specs)
        specs.append(dict(
            name=f"{eqn.primitive.name}.{i}",
            op=eqn.primitive.name,
            size_bytes=size,
            preds=sorted(set(preds)),
        ))
        eqn_nodes.append(nid)
        for o in eqn.outvars:
            producer[o] = nid
    return Graph.build(specs, name="jaxpr"), eqn_nodes


@dataclasses.dataclass
class JaxprScheduleReport:
    n_eqns: int
    original_peak: int
    kahn_peak: int
    optimal_peak: int
    exact: bool                    # False if the beam fallback was used
    order: list[int]
    arena_bytes: int = 0           # offset-allocator watermark of the order
    arena_policy: str = ""         # winning placement policy

    @property
    def reduction_vs_original(self) -> float:
        return self.original_peak / max(self.optimal_peak, 1)

    @property
    def arena_over_peak(self) -> float:
        """Fragmentation ratio: 1.0 == the arena realizes the liveness peak."""
        return self.arena_bytes / max(self.optimal_peak, 1)


def schedule_jaxpr(closed, *, state_quota: int = 4000,
                   beam_fallback: bool = True,
                   cache: "PlanCache | bool | None" = True):
    """Reorder the equations of ``closed`` into a memory-optimal order.

    Equation orders are memoized in the content-addressed plan cache keyed
    on the lifted graph, so re-tracing the same function (every ``jit``
    refresh, every serving replica warm-up) schedules in O(graph hash).
    """
    g, eqn_nodes = jaxpr_to_graph(closed)
    node_to_eqn = {n: i for i, n in enumerate(eqn_nodes)}

    pc = _resolve_cache(cache)
    cache_opts = ("jax_bridge.schedule_jaxpr", state_quota, beam_fallback)
    cached = pc.get(g, cache_opts) if pc is not None else None
    if cached is not None:
        (best_peak, best_order, exact, orig_peak, kahn_peak, arena_bytes,
         arena_policy) = cached
    else:
        # footprint of the original (trace) order — itself a feasible
        # schedule, so it seeds the soft budget (tighter than Kahn on
        # traced programs)
        orig_order = list(range(len(g)))
        orig = simulate_schedule(g, orig_order)
        kahn = kahn_schedule(g)
        tau = min(orig.peak_bytes, kahn.peak_bytes)

        exact = True
        try:
            res = dp_schedule(g, budget=tau, state_quota=state_quota)
        except SearchTimeout:
            if not beam_fallback:
                raise
            # beam runs UNBUDGETED: beam width alone bounds the search — a
            # budget would dead-end it (low-peak states it keeps can all hit
            # the budget wall while the feasible path got evicted)
            exact = False
            res = dp_schedule(g, state_quota=state_quota, on_quota="beam")

        candidates = [
            (orig.peak_bytes, orig_order),
            (kahn.peak_bytes, kahn.order),
            (res.peak_bytes, res.order),
        ]
        best_peak, best_order = min(candidates, key=lambda c: c[0])
        orig_peak, kahn_peak = orig.peak_bytes, kahn.peak_bytes
        # realized memory plan for the chosen order: XLA's buffer assigner
        # honours program order, so this is the arena the runtime reserves
        arena = plan_arena_best(g, best_order)
        arena_bytes, arena_policy = arena.arena_bytes, arena.policy
        if pc is not None:
            pc.put(g, cache_opts,
                   (best_peak, list(best_order), exact, orig_peak, kahn_peak,
                    arena_bytes, arena_policy))
    new_eqns = [closed.jaxpr.eqns[node_to_eqn[n]] for n in best_order
                if n in node_to_eqn]
    assert len(new_eqns) == len(closed.jaxpr.eqns)
    new_jaxpr = closed.jaxpr.replace(eqns=new_eqns)
    new_closed = core.ClosedJaxpr(new_jaxpr, closed.consts)
    report = JaxprScheduleReport(
        n_eqns=len(new_eqns),
        original_peak=orig_peak,
        kahn_peak=kahn_peak,
        optimal_peak=best_peak,
        exact=exact,
        order=list(best_order),
        arena_bytes=arena_bytes,
        arena_policy=arena_policy,
    )
    return new_closed, report


def serenity_transform(fn: Callable, **kw) -> Callable:
    """Return ``fn`` with its jaxpr equations in memory-optimal order.
    The returned callable also exposes ``.report`` after first call."""
    def wrapped(*args, **kwargs):
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        new_closed, report = schedule_jaxpr(closed, **kw)
        wrapped.report = report
        flat, _ = jax.tree.flatten((args, kwargs))
        out = _eval_jaxpr(new_closed.jaxpr, new_closed.consts, *flat)
        out_tree = jax.tree.structure(jax.eval_shape(fn, *args, **kwargs))
        return jax.tree.unflatten(out_tree, out)

    wrapped.report = None
    return wrapped


def analyze_fn(fn: Callable, *args, **kw) -> JaxprScheduleReport:
    closed = jax.make_jaxpr(fn)(*args)
    _, report = schedule_jaxpr(closed, **kw)
    return report


def memory_aware_remat(fn: Callable, budget_bytes: int, *abstract_args,
                       **kw) -> tuple[Callable, dict]:
    """Budget-driven remat choice (the paper's cap, our policy knob):

    analyze ``fn``'s optimal schedule footprint; if even the optimal order
    exceeds the budget, return ``jax.checkpoint(fn)`` (trading recompute for
    liveness), else return ``fn`` scheduled but unrematerialized.
    """
    report = analyze_fn(fn, *abstract_args, **kw)
    decision = {
        "optimal_peak": report.optimal_peak,
        "budget": budget_bytes,
        "remat": report.optimal_peak > budget_bytes,
        "exact": report.exact,
    }
    if decision["remat"]:
        return jax.checkpoint(fn), decision
    return fn, decision
