"""SERENITY <-> JAX integration: schedule jaxprs for minimal live memory.

The paper schedules operator graphs of edge networks; a jaxpr is the same
thing one level down — a DAG of equations whose issue order determines how
long each output buffer stays live.  XLA's buffer assigner honours (unfused)
program order, so reordering equations with the paper's DP lowers the
activation high-watermark exactly the way the paper lowers TFLite's arena
peak.

Public API
----------
jaxpr_to_graph(closed_jaxpr)          -> (Graph, eqn_nodes)
schedule_jaxpr(closed_jaxpr, ...)     -> (reordered ClosedJaxpr, report)
serenity_transform(fn)(*args)         -> fn with memory-optimal eqn order
compile_scheduled(fn)(*args)          -> fn jitted through the planned arena
                                         (realized footprint measured)
analyze_fn(fn, *args)                 -> footprint report (no transform)
memory_aware_remat(fn, budget, *args) -> fn or jax.checkpoint(fn) chosen by
                                         the scheduler's footprint analysis
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core
from jax._src.core import eval_jaxpr as _eval_jaxpr

from repro.core.allocator import ArenaPlan, plan_arena_best
from repro.core.executor import RealizedTracker, _from_bytes, _to_bytes
from repro.core.graph import Graph, simulate_schedule
from repro.core.heuristics import kahn_schedule
from repro.core.plancache import PlanCache, resolve as _resolve_cache
from repro.core.serenity import (
    PlanConfig,
    _warn_deprecated,
    plan as serenity_plan,
)
from repro.kernels.arena import arena_write


def jaxpr_config(state_quota: int = 4000,
                 on_timeout: str = "adaptive") -> PlanConfig:
    """The default :class:`PlanConfig` for jaxpr scheduling.

    Jaxpr graphs are planned without the paper's graph rewrites — equation
    node ids must survive verbatim so the reordered jaxpr can be rebuilt —
    and without heuristic baselines (the bridge computes its own traced /
    Kahn candidates).
    """
    return PlanConfig(rewrite=False, inplace=False, compute_baselines=False,
                      state_quota=state_quota, on_timeout=on_timeout)


_UNSET = object()


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def jaxpr_to_graph(closed) -> tuple[Graph, list[int]]:
    """Lift a ClosedJaxpr into the SERENITY IR.

    Node 0..n_in-1: the jaxpr invars (op='input').  One node per equation
    afterwards; the node's cost is the sum of its output aval bytes.
    Returns (graph, eqn_node_ids) where eqn_node_ids[i] is the node id of
    equation i.
    """
    jaxpr = closed.jaxpr
    specs: list[dict] = []
    producer: dict[Any, int] = {}

    for v in jaxpr.invars:
        nid = len(specs)
        specs.append(dict(name=f"in{nid}", op="input",
                          size_bytes=_aval_bytes(v.aval), preds=[]))
        producer[v] = nid
    eqn_nodes: list[int] = []
    for i, eqn in enumerate(jaxpr.eqns):
        preds = []
        for v in eqn.invars:
            if isinstance(v, core.Literal):
                continue
            if v in producer:
                preds.append(producer[v])
        size = sum(_aval_bytes(o.aval) for o in eqn.outvars)
        nid = len(specs)
        specs.append(dict(
            name=f"{eqn.primitive.name}.{i}",
            op=eqn.primitive.name,
            size_bytes=size,
            preds=sorted(set(preds)),
        ))
        eqn_nodes.append(nid)
        for o in eqn.outvars:
            producer[o] = nid
    return Graph.build(specs, name="jaxpr"), eqn_nodes


@dataclasses.dataclass
class JaxprScheduleReport:
    """Footprint accounting for one scheduled jaxpr.  All ``*_peak``/
    ``*_bytes`` fields are bytes; ``order`` indexes the lifted graph's
    nodes (invars first, then equations)."""

    n_eqns: int
    original_peak: int             # live-bytes peak of the traced eqn order
    kahn_peak: int                 # peak of the Kahn/TFLite-style order
    optimal_peak: int              # peak of the chosen (best) order
    exact: bool                    # False if the beam fallback was used
    order: list[int]
    arena_bytes: int = 0           # offset-allocator watermark of the order
    arena_policy: str = ""         # winning placement policy
    realized_bytes: int = 0        # live-byte high-water of the planned
                                   # lifetimes replayed over the executed
                                   # order (0 = not run; set by
                                   # compile_scheduled, whose numeric
                                   # equivalence assert covers addressing)
    n_env_bypassed: int = 0        # tensors kept out of the arena (unsized
                                   # or non-byteable dtypes)
    arena_plan: "ArenaPlan | None" = None   # full offset plan of the order

    @property
    def reduction_vs_original(self) -> float:
        return self.original_peak / max(self.optimal_peak, 1)

    @property
    def arena_over_peak(self) -> float:
        """Fragmentation ratio: 1.0 == the arena realizes the liveness peak."""
        return self.arena_bytes / max(self.optimal_peak, 1)

    @property
    def realized_matches_plan(self) -> bool:
        """True when the measured high-water equals the planned peak."""
        return self.realized_bytes == self.optimal_peak


def schedule_jaxpr(closed, *, state_quota=_UNSET, beam_fallback=_UNSET,
                   cache: "PlanCache | bool | None" = True,
                   config: PlanConfig | None = None):
    """Reorder the equations of ``closed`` into a memory-optimal order.

    Equation orders are memoized in the content-addressed plan cache keyed
    on the lifted graph plus the serialized config, so re-tracing the same
    function (every ``jit`` refresh, every serving replica warm-up)
    schedules in O(graph hash).

    Args:
      closed: the ``ClosedJaxpr`` to reorder.
      cache: plan-cache handle/boolean as in :func:`repro.core.plan`.
      config: planning knobs (:func:`jaxpr_config` defaults when ``None``):
        the DP runs under ``config.state_quota`` and
        ``config.on_timeout='adaptive'`` falls back to the Algorithm 2
        budget meta-search and a bounded per-cell beam on quota exhaustion
        (the report's ``exact`` flag records whether any fallback produced
        the order) while ``'raise'`` propagates
        :class:`~repro.core.scheduler.SearchTimeout`.
      state_quota / beam_fallback: deprecated kwarg shims (warn once);
        mapped onto ``config`` — passing both styles is an error.

    Returns:
      ``(new_closed, report)``: the same jaxpr with equations permuted into
      the best order found (never worse than the traced order), and a
      :class:`JaxprScheduleReport` with the byte peaks of the traced /
      Kahn / chosen orders plus the offset-allocator watermark
      (``arena_bytes``, bytes) of the chosen order.
    """
    if state_quota is not _UNSET or beam_fallback is not _UNSET:
        if config is not None:
            raise TypeError("schedule_jaxpr: pass either config= or the "
                            "legacy state_quota=/beam_fallback= kwargs, "
                            "not both")
        _warn_deprecated(
            "schedule_jaxpr(state_quota=..., beam_fallback=...)",
            "schedule_jaxpr(closed, config=jaxpr_config(...))")
        config = jaxpr_config(
            state_quota=4000 if state_quota is _UNSET else state_quota,
            on_timeout="adaptive"
            if (beam_fallback is _UNSET or beam_fallback) else "raise")
    elif config is None:
        config = jaxpr_config()
    g, eqn_nodes = jaxpr_to_graph(closed)
    node_to_eqn = {n: i for i, n in enumerate(eqn_nodes)}

    pc = _resolve_cache(cache)
    cache_opts = ("jax_bridge.schedule_jaxpr", config.cache_key())
    cached = pc.get(g, cache_opts) if pc is not None else None
    if cached is not None:
        (best_peak, best_order, exact, orig_peak, kahn_peak, arena) = cached
    else:
        # footprints of the traced order and the Kahn order — both feasible
        # schedules, so the chosen order is never worse than either
        orig_order = list(range(len(g)))
        orig = simulate_schedule(g, orig_order)
        kahn = kahn_schedule(g)

        # hierarchical divide and conquer + branch-and-bound DP per cell
        # (the same search serenity.plan runs); isomorphic cells replay
        # through the plan cache
        res = serenity_plan(g, config, cache=pc if pc is not None else False)
        exact = res.exact

        candidates = [
            (orig.peak_bytes, orig_order),
            (kahn.peak_bytes, kahn.order),
            (res.peak_bytes, res.order),
        ]
        best_peak, best_order = min(candidates, key=lambda c: c[0])
        orig_peak, kahn_peak = orig.peak_bytes, kahn.peak_bytes
        # realized memory plan for the chosen order: XLA's buffer assigner
        # honours program order, so this is the arena the runtime reserves
        # (the full plan rides the cache so compile_scheduled never replans)
        arena = (res.arena if best_order is res.order
                 else plan_arena_best(g, best_order))
        if pc is not None:
            pc.put(g, cache_opts,
                   (best_peak, list(best_order), exact, orig_peak, kahn_peak,
                    arena))
    new_eqns = [closed.jaxpr.eqns[node_to_eqn[n]] for n in best_order
                if n in node_to_eqn]
    assert len(new_eqns) == len(closed.jaxpr.eqns)
    new_jaxpr = closed.jaxpr.replace(eqns=new_eqns)
    new_closed = core.ClosedJaxpr(new_jaxpr, closed.consts)
    report = JaxprScheduleReport(
        n_eqns=len(new_eqns),
        original_peak=orig_peak,
        kahn_peak=kahn_peak,
        optimal_peak=best_peak,
        exact=exact,
        order=list(best_order),
        arena_bytes=arena.arena_bytes,
        arena_policy=arena.policy,
        arena_plan=arena,
    )
    return new_closed, report


def serenity_transform(fn: Callable, **kw) -> Callable:
    """Return ``fn`` with its jaxpr equations in memory-optimal order.
    The returned callable also exposes ``.report`` after first call."""
    def wrapped(*args, **kwargs):
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        new_closed, report = schedule_jaxpr(closed, **kw)
        wrapped.report = report
        flat, _ = jax.tree.flatten((args, kwargs))
        out = _eval_jaxpr(new_closed.jaxpr, new_closed.consts, *flat)
        out_tree = jax.tree.structure(jax.eval_shape(fn, *args, **kwargs))
        return jax.tree.unflatten(out_tree, out)

    wrapped.report = None
    return wrapped


# ---------------------------------------------------------------------------
# Arena-threaded execution: realize the planned offsets (DESIGN.md §6)
# ---------------------------------------------------------------------------


def _threadable(aval) -> bool:
    try:
        return (_aval_bytes(aval) > 0
                and aval.dtype != jnp.bool_
                and aval.dtype.itemsize in (1, 2, 4, 8))
    except Exception:
        return False


def _build_arena_program(closed, g: Graph, order, plan: ArenaPlan):
    """Compile the scheduled jaxpr into ``run(*flat_args) -> flat_outputs``
    where every threadable intermediate lives as a byte slice of one uint8
    arena buffer at its planned offset.

    Returns ``(run, n_env_bypassed)``.  ``run`` is pure and jittable; the
    arena is created inside the trace so XLA owns (and can donate/alias)
    its storage.
    """
    jaxpr = closed.jaxpr
    n_in = len(jaxpr.invars)
    # byte address of every threaded var: node offset + intra-node cursor
    # (an equation's outvars are laid out back-to-back inside its node slice)
    addr: dict[Any, int] = {}
    bypassed = 0
    node_vars: list[tuple[int, list]] = []     # (node id, vars) in node order
    for i, v in enumerate(jaxpr.invars):
        node_vars.append((i, [v]))
    for i, eqn in enumerate(jaxpr.eqns):
        node_vars.append((n_in + i, list(eqn.outvars)))
    for nid, out_vs in node_vars:
        cursor = plan.offset_of(nid)
        for v in out_vs:
            if _threadable(v.aval):
                addr[v] = cursor
            else:
                bypassed += 1
            cursor += _aval_bytes(v.aval)
    eqn_of_node = {n_in + i: eqn for i, eqn in enumerate(jaxpr.eqns)}

    out_set = {v for v in jaxpr.outvars if not isinstance(v, core.Literal)}

    def run(*flat_args):
        env: dict[Any, Any] = dict(zip(jaxpr.constvars, closed.consts))
        arena = jnp.zeros(max(plan.arena_bytes, 1), jnp.uint8)
        # jaxpr outputs escape the arena at production time: the planner is
        # free to reuse their bytes afterwards (they have in-graph consumers
        # but must survive to the caller)
        captured: dict[Any, Any] = {}

        def read(v):
            if isinstance(v, core.Literal):
                return v.val
            if v in addr:
                nbytes = _aval_bytes(v.aval)
                b = jax.lax.dynamic_slice(arena, (addr[v],), (nbytes,))
                return _from_bytes(b, v.aval.shape, v.aval.dtype)
            return env[v]

        def write(v, val):
            nonlocal arena
            if v in out_set:
                captured[v] = val
            if v in addr:
                arena = arena_write(arena, _to_bytes(val), addr[v],
                                    impl="xla")
            else:
                env[v] = val

        for nid in order:
            if nid < n_in:
                write(jaxpr.invars[nid], flat_args[nid])
                continue
            eqn = eqn_of_node[nid]
            invals = [read(v) for v in eqn.invars]
            subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
            ans = eqn.primitive.bind(*subfuns, *invals, **bind_params)
            outs = ans if eqn.primitive.multiple_results else [ans]
            for v, val in zip(eqn.outvars, outs):
                write(v, val)
        return tuple(v.val if isinstance(v, core.Literal)
                     else captured.get(v, env.get(v))
                     for v in jaxpr.outvars)

    return run, bypassed


def compile_scheduled(fn: Callable, *, state_quota=_UNSET,
                      cache: "PlanCache | bool | None" = True,
                      assert_equiv: bool = True, atol: float = 1e-5,
                      config: PlanConfig | None = None,
                      ) -> Callable:
    """Jit ``fn`` with its equations reordered *and executed through the
    planned arena*: every threadable intermediate is read and written as a
    byte slice of one linear uint8 buffer at its
    :class:`~repro.core.allocator.ArenaPlan` offset.

    The wrapper (re)compiles per input-shape signature.  On each first call
    for a signature it:

      1. traces ``fn`` and schedules the jaxpr (:func:`schedule_jaxpr`);
      2. packs the lifted graph's tensor lifetimes with
         :func:`~repro.core.allocator.plan_arena_best`;
      3. jits the arena-threaded program and runs it;
      4. with ``assert_equiv`` (default), also runs the *unscheduled* ``fn``
         once and asserts all outputs match within ``atol`` — arena
         transparency is checked, not assumed (first call per signature
         only: warm calls run just the jitted arena program);
      5. replays the executed schedule's alloc/free events through
         :class:`~repro.core.executor.RealizedTracker` and records the
         live-byte high-water in ``wrapped.report.realized_bytes`` next to
         the planned ``arena_bytes`` — realized vs planned, both in bytes
         (byte-addressing correctness itself is what step 4 checks).

    Warm calls for a known signature skip tracing entirely: the key is the
    input leaves' (shape, dtype) tuple and the output treedef is cached with
    the jitted program.

    Returns the wrapped callable; ``wrapped.report`` holds the
    :class:`JaxprScheduleReport` of the most recent compilation.
    ``state_quota`` is a deprecated kwarg shim (warns once) mapped onto
    ``config``; :func:`jaxpr_config` builds the default.
    """
    if state_quota is not _UNSET:
        if config is not None:
            raise TypeError("compile_scheduled: pass either config= or the "
                            "legacy state_quota= kwarg, not both")
        _warn_deprecated("compile_scheduled(state_quota=...)",
                         "compile_scheduled(fn, config=jaxpr_config(...))")
        config = jaxpr_config(state_quota=state_quota)
    elif config is None:
        config = jaxpr_config()
    compiled: dict[Any, tuple] = {}

    def wrapped(*args, **kwargs):
        flat, in_tree = jax.tree.flatten((args, kwargs))
        key = (in_tree, tuple((jnp.shape(x), jnp.result_type(x))
                              for x in flat))
        first_call = key not in compiled
        if first_call:
            # one trace yields both the jaxpr and the output tree structure
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(
                *args, **kwargs)
            _, report = schedule_jaxpr(closed, config=config, cache=cache)
            g, _ = jaxpr_to_graph(closed)
            plan = report.arena_plan or plan_arena_best(g, report.order)
            run, bypassed = _build_arena_program(closed, g, report.order,
                                                 plan)
            tracker = RealizedTracker(g, report.order, plan)
            for u in report.order:
                tracker.step(u)
            report.realized_bytes = tracker.peak_bytes
            report.n_env_bypassed = bypassed
            out_tree = jax.tree.structure(out_shape)
            compiled[key] = (jax.jit(run), report, out_tree)
        run_jit, report, out_tree = compiled[key]
        wrapped.report = report
        result = jax.tree.unflatten(out_tree, list(run_jit(*flat)))
        if assert_equiv and first_call:
            ref = fn(*args, **kwargs)
            for a, b in zip(jax.tree.leaves(result), jax.tree.leaves(ref)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=atol, rtol=atol)
        return result

    wrapped.report = None
    return wrapped


def analyze_fn(fn: Callable, *args, **kw) -> JaxprScheduleReport:
    closed = jax.make_jaxpr(fn)(*args)
    _, report = schedule_jaxpr(closed, **kw)
    return report


def memory_aware_remat(fn: Callable, budget_bytes: int, *abstract_args,
                       **kw) -> tuple[Callable, dict]:
    """Budget-driven remat choice (the paper's cap, our policy knob):

    analyze ``fn``'s optimal schedule footprint; if even the optimal order
    exceeds the budget, return ``jax.checkpoint(fn)`` (trading recompute for
    liveness), else return ``fn`` scheduled but unrematerialized.
    """
    report = analyze_fn(fn, *abstract_args, **kw)
    decision = {
        "optimal_peak": report.optimal_peak,
        "budget": budget_bytes,
        "remat": report.optimal_peak > budget_bytes,
        "exact": report.exact,
    }
    if decision["remat"]:
        return jax.checkpoint(fn), decision
    return fn, decision
