"""Differential oracles for the width-W latency x memory Pareto frontier.

Two independent implementations of the time-slot scheduling model of
DESIGN.md §12, used by the differential test corpus to check that
``pareto_schedule``'s frontier is exactly the set of non-dominated
(makespan, peak-bytes) points — the same role ``brute_force_schedule``
plays for the serial peak.

* **ILP** (``solver='pulp'``) — the classic HLS time-indexed formulation:
  one binary ``x[u,t]`` per (op, slot), width and precedence as linear
  constraints, slot durations ``d[t] >= cost[u] * x[u,t]``, and the
  footprint at every slot bounded by the peak variable with LP-relaxed
  free indicators (pressure-maximized by the objective, so they are tight
  at the optimum).  The frontier is enumerated by the epsilon-constraint
  sweep: minimize peak under a shrinking latency cap, tightening the
  makespan at each step.  Import-guarded — ``pulp`` ships only in the
  ``ilp`` optional extra (CI runs it in one matrix job; tier-1 stays
  solver-free).

* **Pure-Python fallback** (``solver='fallback'``) — exact memoized
  *suffix* enumeration over scheduled-set masks, for graphs of at most
  ``max_nodes`` (default 10) nodes.  Deliberately independent of the
  forward planner's machinery: the footprint of a mask is re-derived from
  scratch as the sum of live tensor sizes (produced, and either a graph
  output or still awaiting a consumer) instead of incrementally, there are
  no bounds, no incumbents, and no eager-move dominance.

Both oracles return the identical frontier; ``oracle_frontier`` with
``solver='auto'`` prefers the ILP when available and asserts nothing —
tests diff its output against the planner's.
"""

from __future__ import annotations

import itertools
from typing import Sequence

from repro.core.graph import Graph

__all__ = [
    "OracleError",
    "has_ilp_solver",
    "oracle_frontier",
]

#: the fallback enumerates all 2^n scheduled-set masks; tiny by contract
_FALLBACK_MAX_NODES = 10


class OracleError(RuntimeError):
    """The requested oracle backend is unavailable or out of scope."""


def has_ilp_solver() -> bool:
    """True when the ``ilp`` optional extra (pulp + CBC) is importable."""
    try:
        import pulp  # noqa: F401
    except ImportError:
        return False
    return True


def _node_tables(
    g: Graph, costs: Sequence[int] | None
) -> tuple[list[int], list[int], list[int]]:
    """(costs, net_alloc, alloc_pos) re-derived from the graph."""
    if costs is None:
        from repro.core.scheduler import node_costs

        costs = node_costs(g)
    n = len(g)
    net = [0] * n
    pos = [0] * n
    for u in range(n):
        nd = g.nodes[u]
        net[u] = g.sizes[u] - sum(g.sizes[p] for p in nd.alias_preds)
        pos[u] = max(net[u], 0)
    return list(costs), net, pos


def _nondominated(
    points: set[tuple[int, int]] | list[tuple[int, int]]
) -> tuple[tuple[int, int], ...]:
    """Strictly non-dominated (makespan, peak) points, sorted by makespan."""
    out: list[tuple[int, int]] = []
    for ms, pk in sorted(set(points)):
        # kept peaks are strictly decreasing, so the last kept point has the
        # lowest peak seen: anything not strictly below it is dominated (or
        # an equal-makespan tie whose lower-peak twin is already kept)
        if not out or pk < out[-1][1]:
            out.append((ms, pk))
    return tuple(out)


# ---------------------------------------------------------------------------
# Pure-Python fallback: memoized suffix enumeration over masks
# ---------------------------------------------------------------------------


def _fallback_frontier(
    g: Graph,
    max_width: int,
    preplaced: Sequence[int],
    costs: Sequence[int],
    pos: Sequence[int],
    max_nodes: int,
) -> tuple[tuple[int, int], ...]:
    n = len(g)
    if n > max_nodes:
        raise OracleError(
            f"fallback oracle enumerates all masks: {n} nodes > "
            f"max_nodes {max_nodes}")
    pre = frozenset(preplaced)
    pre_mask = 0
    mu0 = 0
    for p in pre:
        pre_mask |= 1 << p
        mu0 += g.sizes[p]
    full_mask = pre_mask
    for u in range(n):
        full_mask |= 1 << u
    succ_mask = g.succ_mask
    pred_mask = g.pred_mask
    sizes = g.sizes

    def footprint(mask: int) -> int:
        # from-scratch live-set sum: a produced tensor is resident while it
        # is a graph output or still has an unscheduled consumer (an
        # alias-consumed pred's storage morphs into its consumer's, which
        # this counts exactly once via the consumer's own size)
        total = 0
        for v in range(n):
            if not mask >> v & 1:
                continue
            if succ_mask[v] == 0 or succ_mask[v] & ~mask:
                total += sizes[v]
        return total

    def ready(mask: int) -> list[int]:
        return [u for u in range(n)
                if not mask >> u & 1 and pred_mask[u] & mask == pred_mask[u]]

    memo: dict[int, tuple[tuple[int, int], ...]] = {}

    def suffix(mask: int) -> tuple[tuple[int, int], ...]:
        """Pareto set of (remaining makespan, absolute suffix peak)."""
        if mask == full_mask:
            return ((0, 0),)
        hit = memo.get(mask)
        if hit is not None:
            return hit
        mu = footprint(mask)
        rdy = ready(mask)
        acc: set[tuple[int, int]] = set()
        for size in range(1, min(max_width, len(rdy)) + 1):
            for S in itertools.combinations(rdy, size):
                dur = max(costs[u] for u in S)
                transient = mu + sum(pos[u] for u in S)
                nm = mask
                for u in S:
                    nm |= 1 << u
                for ms_rest, pk_rest in suffix(nm):
                    acc.add((dur + ms_rest, max(transient, pk_rest)))
        res = _nondominated(acc)
        memo[mask] = res
        return res

    return _nondominated(
        [(ms, max(pk, mu0)) for ms, pk in suffix(pre_mask)])


# ---------------------------------------------------------------------------
# ILP: time-indexed formulation + epsilon-constraint sweep (requires pulp)
# ---------------------------------------------------------------------------


def _pulp_frontier(
    g: Graph,
    max_width: int,
    costs: Sequence[int],
    net: Sequence[int],
    pos: Sequence[int],
    latency_budget: int | None,
) -> tuple[tuple[int, int], ...]:
    import pulp

    n = len(g)
    slots = range(n)
    freeable = [p for p in range(n)
                if g.succs[p]
                and not any(p in g.nodes[c].alias_preds for c in g.succs[p])]

    def solve(minimize: str, latency_cap: int | None, peak_cap: int | None):
        prob = pulp.LpProblem("pareto_oracle", pulp.LpMinimize)
        x = pulp.LpVariable.dicts(
            "x", (range(n), slots), cat=pulp.LpBinary)
        d = pulp.LpVariable.dicts("d", slots, lowBound=0)
        peak = pulp.LpVariable("peak", lowBound=0)
        f = pulp.LpVariable.dicts(
            "f", (freeable, range(1, n)), lowBound=0, upBound=1)
        for u in range(n):
            prob += pulp.lpSum(x[u][t] for t in slots) == 1
        for t in slots:
            prob += pulp.lpSum(x[u][t] for u in range(n)) <= max_width
            for u in range(n):
                prob += d[t] >= costs[u] * x[u][t]
        start = {u: pulp.lpSum(t * x[u][t] for t in slots) for u in range(n)}
        for u in range(n):
            for p in g.nodes[u].preds:
                prob += start[u] >= start[p] + 1
        makespan = pulp.lpSum(d[t] for t in slots)
        # z[u][t] = scheduled at or before slot t (prefix-sum expression)
        for t in slots:
            mem = pulp.lpSum(pos[u] * x[u][t] for u in range(n))
            mem += pulp.lpSum(
                net[u] * x[u][tp] for u in range(n) for tp in range(t))
            if t >= 1:
                for p in freeable:
                    # f is pressure-maximized: tight iff every consumer of p
                    # landed in a strictly earlier slot
                    for c in g.succs[p]:
                        prob += f[p][t] <= pulp.lpSum(
                            x[c][tp] for tp in range(t))
                mem -= pulp.lpSum(
                    g.sizes[p] * f[p][t] for p in freeable)
            prob += mem <= peak
        if latency_cap is not None:
            prob += makespan <= latency_cap
        if peak_cap is not None:
            prob += peak <= peak_cap
        prob += peak if minimize == "peak" else makespan
        status = prob.solve(pulp.PULP_CBC_CMD(msg=0))
        if pulp.LpStatus[status] != "Optimal":
            return None
        return (int(round(pulp.value(peak))),
                int(round(pulp.value(makespan))))

    points: list[tuple[int, int]] = []
    cap = latency_budget
    while True:
        got = solve("peak", cap, None)
        if got is None:
            break
        best_peak, _ = got
        got2 = solve("makespan", cap, best_peak)
        assert got2 is not None
        _, tight_ms = got2
        points.append((tight_ms, best_peak))
        cap = tight_ms - 1
        if cap < 0:
            break
    return _nondominated(points)


def oracle_frontier(
    g: Graph,
    *,
    max_width: int,
    preplaced: Sequence[int] = (),
    costs: Sequence[int] | None = None,
    latency_budget: int | None = None,
    solver: str = "auto",
    max_nodes: int = _FALLBACK_MAX_NODES,
) -> tuple[tuple[int, int], ...]:
    """Exact (makespan, peak_bytes) frontier from an independent solver.

    ``solver='auto'`` uses the ILP when ``pulp`` is importable and the
    pure-Python fallback otherwise; ``'pulp'`` and ``'fallback'`` force a
    backend (the former raising :class:`OracleError` without the ``ilp``
    extra).  The ILP leg does not model preplaced residents — pass
    ``preplaced=()`` or use the fallback.
    """
    costs, net, pos = _node_tables(g, costs)
    if solver == "auto":
        solver = "pulp" if has_ilp_solver() else "fallback"
    if solver == "fallback":
        pts = _fallback_frontier(g, max_width, preplaced, costs, pos,
                                 max_nodes)
        if latency_budget is not None:
            pts = tuple(p for p in pts if p[0] <= latency_budget)
        return pts
    if solver != "pulp":
        raise ValueError(f"unknown solver {solver!r}")
    if not has_ilp_solver():
        raise OracleError(
            "solver='pulp' requires the 'ilp' optional extra "
            "(pip install .[ilp])")
    if preplaced:
        raise OracleError("the ILP oracle does not model preplaced "
                          "residents; use solver='fallback'")
    if len(g) > max_nodes:
        raise OracleError(
            f"ILP oracle capped at max_nodes {max_nodes} ({len(g)} nodes)")
    return _pulp_frontier(g, max_width, costs, net, pos, latency_budget)
