"""Serving driver: batched prefill + greedy decode on a planned KV arena.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 4 --prompt-len 32 --gen 16

SERENITY integration: before allocating the decode state, the server builds
the serve-schedule dataflow graph (embed -> L x block -> logits per step,
cache buffers live across the whole schedule) and runs the paper's
linear-arena planner on it (DESIGN.md §1 "serving arena planner").  The
plan is then *realized*, not just printed: the initial decode state is
packed into one arena buffer at the planned byte offsets and handed to the
decode loop as slices of that arena (JAX values are immutable, so each
donated decode step carries the state forward from those slices), and the
realized footprint — measured by executing the decode-state graph through
``repro.core.executor`` — is reported against the planned bytes
(DESIGN.md §6).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import Graph, kahn_schedule, plan_arena_best
from repro.core.executor import execute_plan, pack_buffers, unpack_buffer
from repro.core.plancache import default_cache
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.params import ParamDef
from repro.models.zoo import build_model


def plan_decode_arena(model, bsz: int, smax: int) -> dict:
    """Arena-plan the decode state buffers with the SERENITY allocator.

    The plan is memoized in the content-addressed plan cache: every replica
    serving the same (arch, batch, seq) shape — and every later request for
    it in this process — reuses the first plan in O(graph hash).
    """
    defs = model.make_cache_defs(bsz, smax)
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    specs = []
    # one graph node per persistent buffer; all live across the whole step
    for i, d in enumerate(leaves):
        nbytes = int(np.prod(d.shape)) * np.dtype(d.dtype).itemsize
        specs.append(dict(name=f"buf{i}", op="cache", size_bytes=nbytes,
                          preds=[]))
    # transient per-step tensors (logits + hidden) chain off the caches
    D, V = model.cfg.d_model, model.cfg.vocab_size
    specs.append(dict(name="hidden", op="act", size_bytes=bsz * D * 2,
                      preds=list(range(len(leaves)))))
    specs.append(dict(name="logits", op="act", size_bytes=bsz * V * 4,
                      preds=[len(specs) - 1]))
    g = Graph.build(specs, name="decode_state")
    pc = default_cache()
    cache_opts = ("serve.plan_decode_arena",)
    out = pc.get(g, cache_opts)
    if out is None:
        order = kahn_schedule(g).order
        plan = plan_arena_best(g, order)
        naive = sum(s["size_bytes"] for s in specs)
        out = {"arena_bytes": plan.arena_bytes, "naive_bytes": naive,
               "peak_bytes": plan.peak_bytes, "policy": plan.policy,
               "frag_ratio": plan.frag_ratio,
               "n_buffers": len(specs), "plan": plan,
               "graph": g, "order": order}
        pc.put(g, cache_opts, out)
    return out


def realize_decode_state(plan: dict, cache):
    """Initialize the decode state through the planned arena.

    Packs the initial cache leaves into one uint8 arena buffer at their
    planned byte offsets (jitted, arena donated) and rebuilds the cache
    pytree from slices of it, so the state the decode loop starts from is
    materialized at the plan's offsets rather than ad-hoc per-buffer
    allocations.  Returns (arena, rebuilt_cache).
    """
    leaves, treedef = jax.tree.flatten(cache)
    apl = plan["plan"]
    arena = pack_buffers(apl, dict(enumerate(leaves)))
    rebuilt = [unpack_buffer(arena, apl, i, leaf.shape, leaf.dtype)
               for i, leaf in enumerate(leaves)]
    return arena, jax.tree.unflatten(treedef, rebuilt)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", choices=("none", "single", "multi"),
                    default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = build_model(cfg)
    smax = args.prompt_len + args.gen

    # ---- SERENITY arena plan for the decode state -------------------------
    plan = plan_decode_arena(model, args.batch, smax)
    pc_stats = default_cache().stats
    print(f"[serve] decode-state arena: {plan['arena_bytes']/1e6:.2f} MB "
          f"across {plan['n_buffers']} buffers "
          f"(policy={plan['policy']}, "
          f"arena/peak={plan['frag_ratio']:.3f}, "
          f"naive sum {plan['naive_bytes']/1e6:.2f} MB; plan cache "
          f"hits={pc_stats.hits} misses={pc_stats.misses})")
    # execute the decode-state graph against the plan: the realized
    # footprint is measured from alloc/free events, not estimated
    # (execute_plan is strict — it raises if realized diverges from planned)
    ex = execute_plan(plan["graph"], plan["order"], plan["plan"], inputs=None)
    print(f"[serve] realized arena: live-byte peak "
          f"{ex.realized_peak_bytes/1e6:.2f} MB == planned peak, extent "
          f"{ex.realized_arena_bytes/1e6:.2f} MB == planned arena")

    mesh = rules = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        rules = rules_for_mesh(mesh)

    params = model.init(jax.random.PRNGKey(args.seed))
    # decode state starts as slices of the planned arena buffer
    state_arena, cache = realize_decode_state(
        plan, model.init_cache(args.batch, smax))
    print(f"[serve] decode state initialized from a "
          f"{state_arena.nbytes/1e6:.2f} MB planned arena buffer")
    prefill = jax.jit(make_prefill_step(model, rules))
    decode = jax.jit(make_decode_step(model, rules), donate_argnums=(1,))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {
        "tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32
        )

    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, batch)
    tok = jnp.argmax(logits, -1)[:, None]
    t_prefill = time.perf_counter() - t0

    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        t = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, tok, t)
        tok = jnp.argmax(logits, -1)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.1f} ms; {args.gen} decode steps in "
          f"{t_decode*1e3:.1f} ms "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print(f"[serve] sample generation (first row): {np.asarray(gen)[0][:16]}")


if __name__ == "__main__":
    main()
