"""Multi-tenant serving: request queue + budgeted arena pool + batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --requests 8 --prompt-len 32 --gen 16 --budget-mb 4

SERENITY integration (DESIGN.md §1/§9): every request's decode state is
arena-planned by the paper's machinery — KV caches pinned resident at the
bottom of the plan (:func:`repro.core.allocator.plan_arena_regions`), the
per-step transients (embed/attn/MLP activations, logits) stacked above —
and the request then *leases* that plan from a budgeted
:class:`repro.runtime.pool.ArenaPool`.  Admission charges the joint
co-residency extent (:func:`repro.core.allocator.plan_shared_arena`):
requests are admitted, queued FIFO, or rejected against one global device
byte budget, and the admitted set's transient slack is shared, so the pool
sustains far more concurrency than one-arena-per-request under the same
budget (``benchmarks/bench_serving.py`` measures both).

The decode loop is continuously batched: each server step advances every
admitted request by one token, the batch composition re-forms as requests
finish and queued requests take their bytes, and each request's KV state
lives *packed in its leased arena buffer at the planned byte offsets*
between steps (``pack_buffers``/``unpack_buffer``).  Two step modes:

  ``serial``  (default) one jitted bsz=1 decode reused for every active
              request, executed back-to-back — transients of distinct
              requests are never live together, matching the pool's
              ``overlap='serial'`` admission accounting.
  ``vmap``    all active requests advance in ONE jitted arena->arena
              program: the active arenas are stacked into a
              ``(bucket, extent)`` uint8 matrix (donated), each row
              unpacked at the planned byte offsets, decoded and packed
              back entirely inside the vmapped XLA program — no Python
              loop over leases.  Programs are cached per power-of-two
              batch bucket; padding rows beyond the live batch are charged
              to the pool budget (``ArenaPool.reserve_scratch``) for the
              step, falling back to an exact-size bucket when they do not
              fit.  All members' transients materialize at once, so
              admission must use ``overlap='none'`` accounting.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import Graph, PlanConfig, pin_transients, plan
from repro.core.allocator import resident_bytes
from repro.core.executor import pack_buffers, unpack_buffer
from repro.core.plancache import default_cache
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.params import ParamDef
from repro.models.zoo import build_model
from repro.runtime.chaos import ChaosController, TransientExecutorError
from repro.runtime.fleet import Fleet, PlannerService, bucket_key_for
from repro.runtime.loadgen import OpenLoopLoadGen, workload_summary
from repro.runtime.pool import ArenaPool, PoolError

#: Pareto request classes decode admission serves (DESIGN.md §12): a
#: ``memory`` request leases the tight regions plan (transients time-share
#: their bytes — maximum co-residency under the budget), a ``latency``
#: request the same layout with every transient pinned always-live
#: (:func:`~repro.core.allocator.pin_transients`) — it pays more bytes so
#: its step never waits on buffer reuse inside a shared arena.
REQUEST_CLASSES = ("memory", "latency")


def _align4(n: int) -> int:
    return -(-int(n) // 4) * 4


def decode_state_graph(model, bsz: int, smax: int) -> tuple[Graph, int]:
    """The serve-schedule dataflow graph for one request's decode step.

    Nodes 0..C-1 are the persistent KV-cache buffers (graph outputs: state
    that survives between steps); above them the per-step transient chain —
    embedding activation, per-layer attention + MLP activations, logits,
    sampled token — each consumed by the next, so the arena planner can
    time-share their bytes.  Returns ``(graph, n_cache_leaves)``; cache
    node ids equal the ``jax.tree`` leaf order of ``make_cache_defs``,
    which is what ``pack_decode_state`` relies on.
    """
    defs = model.make_cache_defs(bsz, smax)
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    specs = []
    for i, d in enumerate(leaves):
        nbytes = _align4(int(np.prod(d.shape)) * np.dtype(d.dtype).itemsize)
        specs.append(dict(name=f"cache{i}", op="cache", size_bytes=nbytes,
                          preds=[]))
    cfg = model.cfg
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    prev = None

    def chain(name, op, nbytes):
        nonlocal prev
        specs.append(dict(name=name, op=op, size_bytes=_align4(nbytes),
                          preds=[] if prev is None else [prev]))
        prev = len(specs) - 1

    chain("embed_out", "act", bsz * D * 4)
    for li in range(cfg.n_layers):
        chain(f"l{li}.attn", "act", bsz * D * 4)
        chain(f"l{li}.mlp", "act", bsz * F * 4)
        chain(f"l{li}.out", "act", bsz * D * 4)
    chain("logits", "act", bsz * V * 4)
    chain("token", "act", bsz * 4)
    return Graph.build(specs, name="decode_state"), len(leaves)


def plan_decode_arena(model, bsz: int, smax: int) -> dict:
    """Arena-plan one request's decode state with the SERENITY allocator.

    The KV caches are pinned resident at the bottom of the arena (they
    persist between steps, so their bytes can never be time-shared) and the
    per-step transients are planned above them
    (:func:`~repro.core.allocator.plan_arena_regions`).  The plan is
    memoized in the content-addressed plan cache: every replica serving the
    same (arch, batch, seq) shape — and every later request for it in this
    process — reuses the first plan in O(graph hash).
    """
    g, n_cache = decode_state_graph(model, bsz, smax)
    pc = default_cache()
    cache_opts = ("serve.plan_decode_arena", 3)   # 3: PlanConfig-planned
    out = pc.get(g, cache_opts)
    if out is None:
        # resident: the KV caches and the sampled token — everything the
        # request carries between steps (the token node also keeps the
        # logits buffer transient: it is the logits' consumer).  The Kahn
        # scheduler is deliberate: decode state is dozens of *isolated*
        # persistent buffers, which the exact DP models as an exponential
        # bitmask space with nothing to gain over the greedy order.
        cfg = PlanConfig(
            rewrite=False, inplace=False, scheduler="kahn",
            resident=(*range(n_cache), len(g) - 1),
            compute_baselines=False)
        res = plan(g, cfg, cache=pc)
        apl = res.arena
        naive = sum(g.sizes)
        pers, extent = resident_bytes(apl)
        out = {"arena_bytes": apl.arena_bytes, "naive_bytes": naive,
               "peak_bytes": apl.peak_bytes, "policy": apl.policy,
               "frag_ratio": apl.frag_ratio,
               "persistent_bytes": pers, "resident_extent": extent,
               "transient_bytes": apl.arena_bytes - extent,
               "n_buffers": len(g), "n_cache": n_cache, "plan": apl,
               "graph": g, "order": res.order}
        pc.put(g, cache_opts, out)
    return out


def pack_decode_state(plan: dict, cache, arena=None):
    """Pack a decode-state pytree into (the resident region of) an arena.

    The cache leaves land at their planned byte offsets; the returned uint8
    buffer covers the plan's resident extent (the persistent region — the
    transient region above it exists only during a step and is never
    materialized per request).  Pass ``arena`` to reuse a leased buffer
    (donated to the jitted pack).
    """
    leaves, _ = jax.tree.flatten(cache)
    if arena is None:
        arena = jnp.zeros(plan["resident_extent"], jnp.uint8)
    return pack_buffers(plan["plan"], dict(enumerate(leaves)), arena=arena)


def unpack_decode_state(plan: dict, arena, defs_like):
    """Rebuild the decode-state pytree from its planned arena offsets."""
    leaves, treedef = jax.tree.flatten(defs_like)
    apl = plan["plan"]
    rebuilt = [unpack_buffer(arena, apl, i, leaf.shape, leaf.dtype)
               for i, leaf in enumerate(leaves)]
    return jax.tree.unflatten(treedef, rebuilt)


def realize_decode_state(plan: dict, cache):
    """Initialize the decode state through the planned arena.

    Packs the initial cache leaves into one uint8 arena buffer at their
    planned byte offsets (jitted, arena donated) and rebuilds the cache
    pytree from slices of it, so the state the decode loop starts from is
    materialized at the plan's offsets rather than ad-hoc per-buffer
    allocations.  Returns (arena, rebuilt_cache).
    """
    arena = pack_decode_state(plan, cache)
    return arena, unpack_decode_state(plan, arena, cache)


# ---------------------------------------------------------------------------
# Request-queue server with continuous batching
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One generation request moving through submit -> admit -> decode."""

    rid: int
    prompt: np.ndarray               # (P,) int32 token ids
    max_new: int
    klass: str | None = None         # Pareto request class (REQUEST_CLASSES;
                                     # None = classless base-plan admission)
    priority: int = 0                # higher = preempted later
    tenant: str | None = None        # quota bucket (ArenaPool.tenant_quotas)
    submit_s: float = 0.0
    admit_s: float = 0.0
    done_s: float = 0.0
    tokens: list = dataclasses.field(default_factory=list)
    rejected: bool = False
    reject_code: str = ""            # machine-readable cause (Ticket.reason_code)
    reject_reason: str = ""
    preemptions: int = 0             # times this request was spilled
    # runtime state while admitted
    lease: object = None
    arena: object = None             # leased uint8 buffer holding the KV state
    spill: object = None             # SpilledLease while preempted
    t: int = 0                       # decode position (cache_len)
    last_tok: int = 0

    @property
    def latency_s(self) -> float:
        return self.done_s - self.submit_s


@dataclasses.dataclass
class TickWatchdog:
    """Per-tick deadline + stall escalation for the serving loop.

    Two concerns (DESIGN.md §13): a *deadline* — ticks slower than
    ``step_deadline_s`` are counted (``deadline_misses``) — and a *stall* —
    ``stall_ticks`` consecutive ticks with no observable progress (no
    token, no admission, no release, no queue movement) escalate instead
    of silently spinning: :meth:`observe` returns ``True`` and the server
    raises :class:`ServingStallError` carrying the structured queue
    diagnostics.
    """

    step_deadline_s: float | None = None
    stall_ticks: int = 64            # > the max readmit backoff (2^5 ticks)
    ticks: int = 0
    deadline_misses: int = 0
    slowest_tick_s: float = 0.0
    stagnant_ticks: int = 0          # consecutive no-progress ticks
    escalations: int = 0

    def observe(self, dt: float, progressed: bool) -> bool:
        """Record one tick; True when stall escalation is due."""
        self.ticks += 1
        self.slowest_tick_s = max(self.slowest_tick_s, dt)
        if self.step_deadline_s is not None and dt > self.step_deadline_s:
            self.deadline_misses += 1
        self.stagnant_ticks = 0 if progressed else self.stagnant_ticks + 1
        if self.stagnant_ticks >= self.stall_ticks:
            self.escalations += 1
            self.stagnant_ticks = 0
            return True
        return False

    def as_dict(self) -> dict:
        return {"ticks": self.ticks,
                "deadline_misses": self.deadline_misses,
                "slowest_tick_s": self.slowest_tick_s,
                "escalations": self.escalations}


class ServingStallError(RuntimeError):
    """The decode loop provably cannot make progress.

    ``report`` is the structured diagnostics dict: every queued request's
    rid/class/priority/tenant and its per-request ``_fits`` failure
    reason, plus the pool's reserved/budget bytes at escalation time.
    """

    def __init__(self, message: str, report: dict):
        super().__init__(message)
        self.report = report


class DecodeServer:
    """Continuous-batching decode server over a budgeted arena pool.

    Each :meth:`step` (one scheduler tick):

      1. admits queued requests the pool now has bytes for (prefill fills
         their KV cache, which is packed into the leased arena),
      2. advances every admitted request by one decode token — the *batch*
         is the admitted set, re-formed every tick as requests finish,
      3. releases finished requests' leases (their warm buffers go to the
         pool LRU; the freed bytes admit the queue head).

    Between ticks every request's KV state lives packed in its leased
    arena buffer at the planned byte offsets.

    Robustness layer (DESIGN.md §13): a mid-run :meth:`set_budget` shrink
    (or an injected admission fault) triggers the graceful-degradation
    ladder — (1) re-plan a ``latency``-class request at its
    memory-optimal Pareto point, (2) shrink vmap buckets to the exact
    batch / drop padding scratch, (3) preempt the lowest-priority lease
    (spill its packed KV state to host, re-admit later with bounded
    retry + exponential backoff).  A :class:`TickWatchdog` escalates
    stalls with structured queue diagnostics, and a ``chaos=``
    :class:`~repro.runtime.chaos.ChaosController` drives deterministic
    fault injection through the hooks.
    """

    def __init__(self, model, params, pool: ArenaPool, *, smax: int,
                 rules=None, step_mode: str = "serial",
                 chaos: ChaosController | None = None,
                 step_deadline_s: float | None = None,
                 stall_ticks: int = 64,
                 max_readmit_attempts: int = 5,
                 max_transient_retries: int = 3):
        if step_mode not in ("serial", "vmap"):
            raise ValueError(f"unknown step_mode {step_mode!r}")
        if step_mode == "vmap" and pool.overlap == "serial":
            raise ValueError(
                "step_mode='vmap' materializes every active request's "
                "transients at once; the pool must use overlap='none' "
                "admission accounting")
        self.model = model
        self.params = params
        self.pool = pool
        self.smax = smax
        self.step_mode = step_mode
        self.rules = rules
        self._prefill = jax.jit(make_prefill_step(model, rules))
        self._decode = jax.jit(make_decode_step(model, rules))
        self._batched: dict[int, object] = {}   # bucket -> jitted step
        self._plan = plan_decode_arena(model, 1, smax)
        # register our regions plan with the pool once; submits reuse the
        # key (no per-request graph re-fingerprinting)
        self._key, _ = pool.plan(self._plan["graph"], self._plan["order"],
                                 plan=self._plan["plan"])
        # the decode state's Pareto request classes (DESIGN.md §12): both
        # keep the regions layout (identical offsets, so pack/unpack and
        # the jitted steps are class-agnostic) but charge admission
        # differently — 'latency' pins its transients always-live
        pool.register_pareto(self._key, {
            "memory": self._plan["plan"],
            "latency": pin_transients(self._plan["plan"]),
        })
        self._tickets: dict[int, Request] = {}
        self.active: list[Request] = []
        self.done: list[Request] = []
        # robustness state (DESIGN.md §13)
        self.chaos = chaos
        if chaos is not None:
            if pool.admission_hook is not None:
                raise ValueError(
                    "chaos= takes ownership of pool.admission_hook, but "
                    "the pool already has one installed; construct the "
                    "pool without admission_hook= or inject admission "
                    "faults through the chaos FaultPlan instead")
            pool.admission_hook = chaos.admission_should_fail
        self.max_readmit_attempts = max_readmit_attempts
        self.max_transient_retries = max_transient_retries
        self.watchdog = TickWatchdog(step_deadline_s=step_deadline_s,
                                     stall_ticks=stall_ticks)
        self._tick = 0
        self._spilled: list[Request] = []       # preempted, awaiting readmit
        self._exact_buckets = False             # ladder rung 2 latch
        self._scratch_token = None              # vmap padding reservation
        self.ladder = {"replan": 0, "shrink_buckets": 0, "preempt": 0}
        self.transient_errors = 0
        self._transient_streak = 0
        self._last_tick_s = 0.0
        self.min_budget_bytes = pool.budget_bytes
        self.max_over_budget_bytes = 0
        self.last_stall: dict | None = None

    # -- admission ---------------------------------------------------------

    def warm(self, n_buffers: int = 1) -> None:
        """Startup warming: pre-plan + pre-allocate arenas for this shape."""
        for _ in range(n_buffers):
            self.pool.warm(self._plan["graph"], key=self._key)

    def submit(self, req: Request) -> None:
        req.submit_s = time.perf_counter()
        # the pool holds *our* regions plan under self._key, so lease
        # buffers, admission accounting and the state pack/unpack all
        # address one set of offsets; a classed request leases its
        # registered Pareto-point plan instead (same offsets, different
        # admission charge)
        ticket = self.pool.submit(self._plan["graph"], key=self._key,
                                  klass=req.klass, priority=req.priority,
                                  tenant=req.tenant)
        if ticket.rejected:
            self._finish_rejected(req, ticket)
            return
        self._tickets[ticket.rid] = req

    def _finish_rejected(self, req: Request, ticket) -> None:
        req.rejected = True
        req.reject_code = ticket.reason_code
        req.reject_reason = ticket.reason
        req.done_s = time.perf_counter()
        req.spill = None
        self.done.append(req)

    def _collect_rejected(self) -> None:
        """Retire queued requests a budget-shrink sweep rejected."""
        for ticket in self.pool.poll_rejected():
            req = self._tickets.pop(ticket.rid, None)
            if req is not None:
                self._finish_rejected(req, ticket)

    def _start(self, ticket) -> None:
        req = self._tickets.pop(ticket.rid)
        req.admit_s = time.perf_counter()
        req.lease = ticket.lease
        if req.spill is not None:
            # re-admission of a preempted request: its packed KV state is
            # self-contained (plan offsets are buffer-relative), so the
            # restore is one host->device byte copy — no re-prefill, and
            # req.t / tokens continue exactly where the spill left off
            sp, req.spill = req.spill, None
            ticket.lease.buffer = None
            req.arena = jnp.asarray(np.asarray(sp.host_state))
            req.klass = sp.klass or req.klass   # a downgrade sticks
            self.active.append(req)
            return
        P = len(req.prompt)
        cache = self.model.init_cache(1, self.smax)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if self.model.cfg.is_encoder_decoder:
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(req.rid), (1, P, self.model.cfg.d_model),
                jnp.float32)
        logits, cache = self._prefill(self.params, cache, batch)
        req.last_tok = int(jnp.argmax(logits, -1)[0])
        req.tokens.append(req.last_tok)
        req.t = P
        req.arena = pack_decode_state(self._plan, cache,
                                      arena=ticket.lease.buffer)
        ticket.lease.buffer = None    # ownership moved to the request
        self.active.append(req)

    # -- degradation ladder (DESIGN.md §13) ---------------------------------

    def set_budget(self, nbytes: int) -> None:
        """Shrink/grow the pool budget mid-run and enforce it.

        A shrink that leaves the admitted set over budget walks the
        degradation ladder (:meth:`_degrade_once`) until the members fit
        again — the pool itself never evicts, so this is where preemption
        happens.
        """
        over = self.pool.set_budget(nbytes)
        self.min_budget_bytes = min(self.min_budget_bytes,
                                    self.pool.budget_bytes)
        while over > 0:
            if not self._degrade_once():
                break                 # nothing left to shed (no members)
            over = self.pool.reserved_bytes - self.pool.budget_bytes

    def _preempt_request(self, req: Request,
                         downgrade_to: str | None = None) -> None:
        """Spill an active request's lease; it rejoins via readmit."""
        sp = self.pool.preempt(req.lease, state=req.arena)
        req.lease = None
        req.arena = None
        req.preemptions += 1
        if downgrade_to is not None and sp.klass != downgrade_to:
            self.pool.downgrade(sp, downgrade_to)
            req.klass = downgrade_to
        sp.next_tick = self._tick + 1   # first readmit try next tick
        req.spill = sp
        self.active.remove(req)
        self._spilled.append(req)

    def _degrade_once(self) -> bool:
        """One ladder rung; True when it shed bytes (or scratch).

        Rung 1: re-plan a ``latency``-class request at its memory-optimal
        Pareto point (preempt + downgrade + readmit — the PR 8 classes
        share offsets, so only the admission charge changes).  Rung 2:
        pin vmap decode to exact-size batch buckets and drop any padding
        scratch.  Rung 3: preempt the lowest-priority lease outright.
        """
        # admitted-but-unpolled tickets (an external set_budget between
        # poll and _start) hold leases none of the rungs below can see:
        # absorb them into the active set first so their bytes are
        # sheddable rather than silently left over budget
        for ticket in self.pool.poll():
            self._start(ticket)
        lat = [r for r in self.active if r.klass == "latency"
               and r.lease is not None]
        if lat and "memory" in self.pool.pareto_classes(self._key):
            victim = min(lat, key=lambda r: (r.priority, -r.rid))
            self._preempt_request(victim, downgrade_to="memory")
            self.ladder["replan"] += 1
            return True
        if not self._exact_buckets:
            self._exact_buckets = True
            self.ladder["shrink_buckets"] += 1
            # drop the server's own padding-scratch reservation (token-
            # scoped: other reservers' scratch is theirs to release)
            token, self._scratch_token = self._scratch_token, None
            if token is not None:
                token.release()
            return True
        owned = [r for r in self.active if r.lease is not None]
        if not owned:
            return False
        # same ordering as ArenaPool.preempt_candidate: lowest priority
        # first, youngest lease among ties
        victim = min(owned, key=lambda r: (r.priority, -r.lease.rid))
        self._preempt_request(victim)
        self.ladder["preempt"] += 1
        return True

    def _retry_spilled(self) -> None:
        """Drive due re-admissions: bounded retry, exponential backoff."""
        still = []
        for req in self._spilled:
            sp = req.spill
            if not sp.due(self._tick):
                still.append(req)
                continue
            ticket = self.pool.readmit(sp)
            if ticket.rejected:
                self._finish_rejected(req, ticket)
            elif ticket.admitted:
                self._tickets[ticket.rid] = req   # restored by _start
            else:
                sp.backoff(self._tick)
                if sp.attempts >= self.max_readmit_attempts:
                    ticket.reason_code = "readmit_exhausted"
                    ticket.reason = (
                        f"re-admission failed after {sp.attempts} attempts "
                        f"(pool reserved {self.pool.reserved_bytes} of "
                        f"{self.pool.budget_bytes} budget bytes)")
                    ticket.rejected = True
                    self._finish_rejected(req, ticket)
                else:
                    still.append(req)
        self._spilled = still

    # -- decode ------------------------------------------------------------

    def _cache_defs(self):
        return self.model.make_cache_defs(1, self.smax)

    def _step_serial(self) -> None:
        for req in self.active:
            cache = unpack_decode_state(self._plan, req.arena,
                                        self._cache_defs())
            tok = jnp.full((1, 1), req.last_tok, jnp.int32)
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(req.t))
            req.last_tok = int(jnp.argmax(logits, -1)[0])
            req.tokens.append(req.last_tok)
            req.t += 1
            req.arena = pack_decode_state(self._plan, cache, arena=req.arena)

    def _build_batched(self, bucket: int):
        """One jitted arena->arena decode program for this batch bucket.

        The program's input is the stacked ``(bucket, resident_extent)``
        uint8 arena matrix (donated): each row is unpacked at the *planned
        byte offsets* — a layout fixed at trace time, not a Python loop
        over leases — decoded one token, and the new KV state packed back
        into the row, all inside one ``jax.vmap``-ed XLA program.
        """
        decode = make_decode_step(self.model, self.rules)
        defs = self._cache_defs()
        dplan = self._plan

        def one(arena, tok, t, params):
            cache = unpack_decode_state(dplan, arena, defs)
            logits, new = decode(params, cache, tok, t)
            leaves = jax.tree.leaves(new)
            arena = pack_buffers(dplan["plan"], dict(enumerate(leaves)),
                                 arena=arena, jit=False)
            return jnp.argmax(logits, -1).reshape(()), arena

        def step(params, arenas, toks, ts):
            return jax.vmap(one, in_axes=(0, 0, 0, None))(
                arenas, toks, ts, params)

        return jax.jit(step, donate_argnums=(1,))

    @staticmethod
    def _bucket(n: int) -> int:
        """Next power-of-two batch bucket (bounds trace count to log2)."""
        return 1 << max(0, n - 1).bit_length()

    def _step_vmap(self) -> None:
        B = len(self.active)
        # ladder rung 2: exact-size buckets trade extra traces for zero
        # padding rows (no scratch charged against the shrunk budget)
        bucket = B if self._exact_buckets else self._bucket(B)
        pad = bucket - B
        if pad:
            # padding rows materialize real state + transients beyond the
            # admitted set: charge them to the pool budget for the duration
            # of the step (a handle-based reservation released in the
            # finally below), or shrink the bucket to the exact batch
            try:
                self._scratch_token = self.pool.reserve_scratch(
                    pad * self._plan["arena_bytes"])
            except PoolError:
                bucket, pad = B, 0
        try:
            fn = self._batched.get(bucket)
            if fn is None:
                fn = self._batched[bucket] = self._build_batched(bucket)
            r0 = self.active[0]
            arenas = jnp.stack([r.arena for r in self.active]
                               + [r0.arena] * pad)
            toks = jnp.asarray([[[r.last_tok]] for r in self.active]
                               + [[[r0.last_tok]]] * pad, jnp.int32)
            ts = jnp.asarray([r.t for r in self.active] + [r0.t] * pad,
                             jnp.int32)
            next_toks, arenas = fn(self.params, arenas, toks, ts)
            next_toks = np.asarray(next_toks).reshape(-1)[:B]
            for i, req in enumerate(self.active):
                req.last_tok = int(next_toks[i])
                req.tokens.append(req.last_tok)
                req.t += 1
                req.arena = arenas[i]
        finally:
            token, self._scratch_token = self._scratch_token, None
            if token is not None:
                token.release()

    def step(self) -> int:
        """One scheduler tick; returns the number of active requests.

        Tick order: arm this tick's chaos faults, admit (poll + start),
        apply injected budget shrinks (which may walk the ladder), retry
        spilled re-admissions, then decode — guarded by the transient-
        error bounded retry — and finally retire finished requests and
        record the budget-invariant trace.
        """
        self._tick += 1
        t_tick = time.perf_counter()
        shrinks = ()
        if self.chaos is not None:
            shrinks = self.chaos.begin_tick(self._tick)
        self.pool.kick()              # retry after transient faults
        self._collect_rejected()
        for ticket in self.pool.poll():
            self._start(ticket)
        for spec in shrinks:
            if spec.kind == "budget_shrink":
                self.set_budget(max(1, int(self.pool.budget_bytes
                                           * spec.factor)))
        self._collect_rejected()
        self._retry_spilled()
        for ticket in self.pool.poll():
            self._start(ticket)
        if self.active:
            try:
                if self.chaos is not None:
                    self.chaos.maybe_executor_error()
                if self.step_mode == "serial":
                    self._step_serial()
                else:
                    self._step_vmap()
                self._transient_streak = 0
            except TransientExecutorError:
                # request state untouched: skip the decode phase this tick
                # and retry next tick, up to the bounded retry limit
                self.transient_errors += 1
                self._transient_streak += 1
                if self._transient_streak > self.max_transient_retries:
                    raise
        still = []
        for req in self.active:
            if len(req.tokens) >= req.max_new:
                req.done_s = time.perf_counter()
                req.lease.buffer = req.arena   # warm buffer back to the pool
                req.arena = None
                self.pool.release(req.lease)
                self.done.append(req)
            else:
                still.append(req)
        self.active = still
        # budget-invariant trace: realized arena bytes vs the instantaneous
        # (possibly shrunk) budget — the chaos suite asserts this never
        # goes positive once the ladder has run
        self.max_over_budget_bytes = max(
            self.max_over_budget_bytes,
            self.pool.reserved_bytes - self.pool.budget_bytes)
        self._last_tick_s = time.perf_counter() - t_tick
        return len(self.active)

    # -- stall diagnostics (DESIGN.md §13) ----------------------------------

    def _progress_sig(self) -> tuple:
        """Observable state; two equal signatures = a tick did nothing.

        Spill backoff state is part of the signature: a failed readmit
        attempt re-arms the backoff (``attempts``/``next_tick`` move), and
        that is observable work even when nothing else changed.
        """
        return (len(self.done),
                sum(len(r.tokens) for r in self.active),
                len(self.active), len(self._spilled), len(self._tickets),
                self.pool.queue_len, self.pool.stats.admitted,
                self.pool.budget_bytes,
                tuple(sorted((r.rid, r.spill.attempts, r.spill.next_tick)
                             for r in self._spilled)))

    def _backoff_pending(self) -> bool:
        """True while a spilled re-admission is waiting out its exponential
        backoff window — that wait is scheduled future work, not
        stagnation, so it must not count toward watchdog escalation."""
        return any(r.spill is not None and r.spill.next_tick > self._tick
                   for r in self._spilled)

    def _stall_report(self) -> dict:
        """Structured queue diagnostics: every waiting request's identity
        and its current ``_fits`` failure reason."""
        return {
            "tick": self._tick,
            "queued": self.pool.queue_report(),
            "waiting_rids": sorted(self._tickets),
            "spilled": [{"rid": r.rid, "attempts": r.spill.attempts,
                         "next_tick": r.spill.next_tick,
                         "klass": r.spill.klass}
                        for r in self._spilled],
            "reserved_bytes": self.pool.reserved_bytes,
            "budget_bytes": self.pool.budget_bytes,
            "scratch_bytes": self.pool.scratch_bytes,
            "watchdog": self.watchdog.as_dict(),
        }

    def _raise_stall(self) -> None:
        report = self._stall_report()
        self.last_stall = report
        queued = ", ".join(
            f"rid={q['rid']} klass={q['klass']} prio={q['priority']} "
            f"({q['why']})" for q in report["queued"]) or "none"
        raise ServingStallError(
            f"serving stalled at tick {report['tick']}: "
            f"{len(report['waiting_rids'])} request(s) waiting, "
            f"{len(report['spilled'])} spilled, none active; pool reserved "
            f"{report['reserved_bytes']} of {report['budget_bytes']} budget "
            f"bytes; queued: [{queued}]", report)

    def run(self, requests: Sequence[Request], *,
            max_steps: int = 100_000) -> dict:
        """Drive all ``requests`` to completion; returns serving metrics."""
        t0 = time.perf_counter()
        for r in requests:
            self.submit(r)
        steps = 0
        while (self.active or self._tickets or self._spilled) \
                and steps < max_steps:
            sig = self._progress_sig()
            self.step()
            steps += 1
            progressed = self._progress_sig() != sig \
                or self._backoff_pending()
            if self.watchdog.observe(self._last_tick_s, progressed):
                self._raise_stall()
            if not progressed and not self.active and self._tickets \
                    and not self._spilled and not self.pool.leases \
                    and not self.pool.pending_admissions \
                    and self.chaos is None:
                # nothing active, nothing held, pending or spilled, no
                # fault injection that could explain it, and the queue did
                # not move: it can never drain (an admission bug) — fail
                # loudly now instead of waiting out the watchdog
                self._raise_stall()
        jax.block_until_ready(self.params)
        wall = time.perf_counter() - t0
        served = [r for r in self.done if not r.rejected]
        lat = sorted(r.latency_s for r in served)
        if lat:
            p50_ms = 1e3 * float(np.percentile(lat, 50))
            p99_ms = 1e3 * float(np.percentile(lat, 99))
        else:
            # an all-rejected run has no latencies: report NaN, never a
            # vacuous 0.0 that would pass any latency SLO silently
            p50_ms = p99_ms = float("nan")
        n_tok = sum(len(r.tokens) for r in served)
        st = self.pool.stats
        ps = self.pool.preemption_stats
        reject_codes: dict[str, int] = {}
        for r in self.done:
            if r.rejected:
                code = r.reject_code or "submit"
                reject_codes[code] = reject_codes.get(code, 0) + 1
        return {
            "n_requests": len(requests),
            "n_served": len(served),
            "n_rejected": sum(r.rejected for r in self.done),
            "n_tokens": n_tok,
            "wall_s": wall,
            "tok_per_s": n_tok / max(wall, 1e-9),
            "p50_ms": p50_ms,
            "p99_ms": p99_ms,
            "steps": steps,
            "max_concurrent": st.max_concurrent,
            "peak_reserved_bytes": st.peak_reserved_bytes,
            "budget_bytes": self.pool.budget_bytes,
            "warm_hits": st.warm_hits,
            "plan_hits": st.plan_hits,
            "arena_bytes": self._plan["arena_bytes"],
            "persistent_bytes": self._plan["persistent_bytes"],
            "transient_bytes": self._plan["transient_bytes"],
            "admitted_by_class": dict(st.admitted_by_class),
            # robustness block (DESIGN.md §13)
            "reject_codes": reject_codes,
            "n_preempted": ps.preemptions,
            "spill_bytes": ps.spilled_bytes,
            "n_readmitted": ps.readmitted,
            "readmit_attempts": ps.readmit_attempts,
            "admission_faults": ps.admission_faults,
            "budget_shrinks": ps.budget_shrinks,
            "min_budget_bytes": self.min_budget_bytes,
            "max_over_budget_bytes": self.max_over_budget_bytes,
            "transient_errors": self.transient_errors,
            "ladder": dict(self.ladder),
            "watchdog": self.watchdog.as_dict(),
            "stall": self.last_stall,
        }


def make_pool(budget_bytes: int, *, step_mode: str = "serial",
              pooled: bool = True, max_warm: int = 4,
              tenant_quotas: dict[str, int] | None = None) -> ArenaPool:
    """Pool whose admission accounting matches the server's step mode."""
    overlap = "serial" if (pooled and step_mode == "serial") else "none"
    return ArenaPool(
        budget_bytes,
        overlap=overlap,
        max_warm=max_warm,
        alloc_fn=lambda n: jnp.zeros(n, jnp.uint8),
        tenant_quotas=tenant_quotas,
    )


def run_server(model, params, requests, *, smax: int, budget_bytes: int,
               step_mode: str = "serial", pooled: bool = True,
               rules=None, warm: int = 0,
               chaos: ChaosController | None = None,
               tenant_quotas: dict[str, int] | None = None,
               **server_kwargs) -> dict:
    """Build a pool + server, serve ``requests``, return metrics."""
    pool = make_pool(budget_bytes, step_mode=step_mode, pooled=pooled,
                     tenant_quotas=tenant_quotas)
    server = DecodeServer(model, params, pool, smax=smax, rules=rules,
                          step_mode=step_mode, chaos=chaos, **server_kwargs)
    if warm:
        server.warm(warm)
    return server.run(requests)


def synth_requests(n: int, prompt_len: int, gen: int, vocab: int,
                   seed: int = 0,
                   latency_frac: float = 0.0,
                   priorities: Sequence[int] | None = None,
                   tenants: Sequence[str] | None = None) -> list[Request]:
    """Synthesize ``n`` requests; ``latency_frac`` > 0 tags that fraction
    as the ``latency`` Pareto class and the rest ``memory`` (0.0 keeps
    every request classless — base-plan admission, the pre-§12 behavior).
    ``priorities`` / ``tenants`` are cycled over the requests when given.
    """
    if not 0.0 <= latency_frac <= 1.0:
        raise ValueError(f"latency_frac must be in [0, 1], got {latency_frac}")
    rng = np.random.default_rng(seed)
    n_lat = round(n * latency_frac)
    reqs = []
    for i in range(n):
        klass = None if latency_frac == 0.0 else \
            ("latency" if i < n_lat else "memory")
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, prompt_len).astype(np.int32),
            max_new=gen, klass=klass,
            priority=priorities[i % len(priorities)] if priorities else 0,
            tenant=tenants[i % len(tenants)] if tenants else None))
    return reqs


# ---------------------------------------------------------------------------
# Sharded fleet top layer (DESIGN.md §14)
# ---------------------------------------------------------------------------


def fleet_planner_for_model(model, buckets: Sequence[int]) \
        -> tuple[PlannerService, dict]:
    """A :class:`PlannerService` loaded with this model's real decode
    plans, one per sequence bucket.

    Each bucket's regions-layout decode plan (KV caches pinned resident,
    transients above — :func:`plan_decode_arena`) is registered together
    with its two Pareto class plans, all backed by the shared
    content-addressed plan cache — so fleet workers lease exactly the
    plans the single-device server serves, fetched by fingerprint, never
    planned locally.  Returns ``(planner, {bucket: PlanRecord})``.
    """
    planner = PlannerService(cache=default_cache())
    records = {}
    for b in sorted(set(int(b) for b in buckets)):
        d = plan_decode_arena(model, 1, b)
        records[b] = planner.register(
            d["graph"], plan=d["plan"],
            classes={"memory": d["plan"],
                     "latency": pin_transients(d["plan"])})
    return planner, records


def run_fleet(model, arrivals, *, buckets: Sequence[int],
              n_decode: int = 4, n_prefill: int = 1,
              shard_budget_bytes: int | None = None,
              prefill_budget_bytes: int | None = None,
              max_batch: int = 8, prefill_chunk: int = 32,
              tenant_quotas: dict[str, int] | None = None,
              fault_plans: dict | None = None,
              max_ticks: int | None = None) -> dict:
    """Serve an open-loop workload on a sharded fleet of this model's
    decode plans (simulated device workers — scheduling fidelity, not
    kernels; see ``runtime/fleet.py``).

    ``shard_budget_bytes`` defaults to ``max_batch`` times the largest
    non-oversize bucket's arena — each decode shard can hold a full
    batch of the biggest routable request.
    """
    planner, records = fleet_planner_for_model(model, buckets)
    if shard_budget_bytes is None:
        fitted = sorted(records)[:-1] or sorted(records)
        shard_budget_bytes = max_batch * records[fitted[-1]].alone_bytes
    fleet = Fleet(planner, key_for=bucket_key_for(records),
                  n_decode=n_decode, n_prefill=n_prefill,
                  shard_budget_bytes=shard_budget_bytes,
                  prefill_budget_bytes=prefill_budget_bytes,
                  max_batch=max_batch, prefill_chunk=prefill_chunk,
                  tenant_quotas=tenant_quotas, fault_plans=fault_plans)
    metrics = fleet.run_arrivals(arrivals, max_ticks=max_ticks)
    metrics["shard_budget_bytes"] = shard_budget_bytes
    metrics["buckets"] = sorted(records)
    return metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--budget-mb", type=float, default=0.0,
                    help="global arena budget; 0 = 4x one request's arena")
    ap.add_argument("--step-mode", choices=("serial", "vmap"),
                    default="serial")
    ap.add_argument("--no-pool", action="store_true",
                    help="naive one-arena-per-request admission baseline")
    ap.add_argument("--warm", type=int, default=2,
                    help="arenas to pre-plan/pre-allocate at startup")
    ap.add_argument("--latency-frac", type=float, default=0.0,
                    help="fraction of requests admitted as the "
                         "latency-sensitive Pareto class (pinned "
                         "transients); the rest memory-starved")
    ap.add_argument("--mesh", choices=("none", "single", "multi"),
                    default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve on a sharded fleet of N decode shards "
                         "(simulated workers over the real decode plans) "
                         "instead of the single in-process server")
    ap.add_argument("--prefill-shards", type=int, default=1,
                    help="dedicated prefill-lane shards (fleet mode; 0 "
                         "prefills inline on decode shards)")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="open-loop Poisson arrival rate, requests/tick "
                         "(fleet mode)")
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = build_model(cfg)
    smax = args.prompt_len + args.gen

    plan = plan_decode_arena(model, 1, smax)
    pc_stats = default_cache().stats
    print(f"[serve] decode-state arena/request: "
          f"{plan['arena_bytes']/1e6:.2f} MB "
          f"({plan['persistent_bytes']/1e6:.2f} MB KV state + "
          f"{plan['transient_bytes']/1e6:.2f} MB step transients, "
          f"policy={plan['policy']}, naive sum "
          f"{plan['naive_bytes']/1e6:.2f} MB; plan cache "
          f"hits={pc_stats.hits} misses={pc_stats.misses})")

    budget = int(args.budget_mb * 1e6) if args.budget_mb else \
        4 * plan["arena_bytes"]

    if args.fleet > 0:
        # sharded fleet: open-loop load over per-bucket decode plans;
        # simulated workers exercise routing/admission, not kernels
        gen = OpenLoopLoadGen(
            seed=args.seed, rate=args.rate,
            prompt_mean=args.prompt_len, prompt_max=4 * smax,
            gen_mean=args.gen, gen_max=2 * args.gen, latency_frac=0.25)
        arrivals = gen.arrivals(args.requests)
        print(f"[fleet] workload: {workload_summary(arrivals)}")
        m = run_fleet(model, arrivals,
                      buckets=(smax, 2 * smax, 8 * smax),
                      n_decode=args.fleet, n_prefill=args.prefill_shards)
        print(f"[fleet] {m['n_served']}/{m['n_requests']} served "
              f"({m['n_rejected']} rejected, rate {m['rejection_rate']}), "
              f"{m['tokens']} tokens over {m['ticks']} ticks on "
              f"{args.fleet}+{args.prefill_shards} shards "
              f"({m['tok_per_tick']} tok/tick)")
        print(f"[fleet] latency p50 {m['p50_ticks']} / p99 {m['p99_ticks']} "
              f"ticks; {m['handoffs']} prefill handoffs, "
              f"{m['migrations']} migrations, {m['preemptions']} "
              f"preemptions; shard budget "
              f"{m['shard_budget_bytes']/1e6:.2f} MB")
        return

    mesh = rules = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        rules = rules_for_mesh(mesh)

    params = model.init(jax.random.PRNGKey(args.seed))
    reqs = synth_requests(args.requests, args.prompt_len, args.gen,
                          cfg.vocab_size, args.seed + 1,
                          latency_frac=args.latency_frac)
    metrics = run_server(model, params, reqs, smax=smax,
                         budget_bytes=budget, step_mode=args.step_mode,
                         pooled=not args.no_pool, rules=rules,
                         warm=args.warm)
    print(f"[serve] {metrics['n_served']}/{metrics['n_requests']} requests "
          f"({metrics['n_rejected']} rejected), {metrics['n_tokens']} tokens "
          f"in {metrics['wall_s']:.2f} s "
          f"({metrics['tok_per_s']:.1f} tok/s)")
    print(f"[serve] latency p50 {metrics['p50_ms']:.0f} ms / "
          f"p99 {metrics['p99_ms']:.0f} ms; concurrency "
          f"{metrics['max_concurrent']} under "
          f"{metrics['budget_bytes']/1e6:.2f} MB budget "
          f"(peak reserved {metrics['peak_reserved_bytes']/1e6:.2f} MB; "
          f"warm hits {metrics['warm_hits']})")
    if metrics["admitted_by_class"]:
        by = metrics["admitted_by_class"]
        print("[serve] admitted by Pareto class: "
              + ", ".join(f"{k}={by[k]}" for k in sorted(by)))


if __name__ == "__main__":
    main()
