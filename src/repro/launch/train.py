"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Wires together: config -> model -> optimizer -> (optional mesh+sharding) ->
data pipeline -> fault-tolerant loop with async checkpointing.  On this
container it runs reduced configs on CPU; on a TPU slice the same driver
shards over the production mesh (--mesh single|multi).
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.configs.base import ShapeConfig
from repro.data import DataPipeline
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.zoo import build_model
from repro.runtime import FaultTolerantLoop

log = logging.getLogger("repro.train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=("none", "single", "multi"),
                    default="none")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = build_model(cfg)
    opt = make_optimizer(cfg, lr=args.lr)

    mesh = rules = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        rules = rules_for_mesh(mesh)

    step_fn = make_train_step(model, opt, rules, peak_lr=args.lr,
                              warmup=max(args.steps // 20, 10),
                              total_steps=args.steps)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    pipe = DataPipeline(cfg=cfg, seq_len=args.seq, global_batch=args.batch,
                        seed=args.seed)

    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    log.info("arch=%s params=%.2fM devices=%d", cfg.name, n_params / 1e6,
             jax.device_count())
    state = {"params": params, "opt": opt.init(params)}

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    start = latest_step(args.ckpt_dir) or 0
    if start:
        log.info("resuming from checkpoint step %d", start)
        state = restore(args.ckpt_dir, start, state)

    losses = []
    t_last = time.perf_counter()

    def on_metrics(step, metrics):
        nonlocal t_last
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            dt = time.perf_counter() - t_last
            t_last = time.perf_counter()
            tok_s = args.batch * args.seq * args.log_every / dt
            log.info("step %5d loss=%.4f  %.1f tok/s", step,
                     float(metrics["loss"]), tok_s)

    def run_step(state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return jit_step(state, batch)

    loop = FaultTolerantLoop(
        step_fn=run_step,
        ckpt_manager=ckpt,
        batch_iter_factory=pipe.iter_from,
        ckpt_every=args.ckpt_every,
    )
    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        state, end_step = loop.run(state, start, args.steps,
                                   on_metrics=on_metrics)
    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    log.info("done at step %d: loss %.4f -> %.4f (stragglers=%d)",
             end_step, first, last, loop.timer.stragglers)


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
