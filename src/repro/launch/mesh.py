"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax

from repro.configs.base import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh over host devices for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def rules_for_mesh(mesh, **overrides) -> ShardingRules:
    """Default DP(+pod) x FSDP x TP rules adapted to the mesh's axis names."""
    axes = set(mesh.axis_names)
    kw = dict(
        batch=tuple(a for a in ("pod", "data") if a in axes),
        fsdp="data" if "data" in axes else None,
        tensor="model" if "model" in axes else None,
        expert="model" if "model" in axes else None,
        # caches: sequence dim takes whatever the KV-head dim leaves free
        # (two-pass resolution in param_pspecs)
        sequence="model" if "model" in axes else None,
        act_embed=None,
    )
    kw.update(overrides)
    return ShardingRules(mesh=mesh, **kw)
