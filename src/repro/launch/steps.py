"""Step builders + abstract input specs for train / prefill / decode.

This is the glue the dry-run, the trainer and the server share:

  * ``make_train_step(model, opt, rules)``   (state, batch) -> (state, metrics)
  * ``make_prefill_step / make_decode_step``  serving steps
  * ``train_input_specs / serve_input_specs``  ShapeDtypeStruct stand-ins with
    NamedShardings attached — weak-type-correct, shardable, zero allocation —
    for ``jax.jit(...).lower(...)`` against the production mesh.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, ShardingRules
from repro.models.params import ParamDef, abstract_params, param_pspecs
from repro.models.zoo import Model
from repro.optim import OPTIMIZERS
from repro.optim.schedule import cosine_warmup
from repro.parallel.sharding import act_spec


# --------------------------------------------------------------------- steps

def make_train_step(model: Model, opt, rules: ShardingRules | None,
                    *, impl: str = "xla", peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    grad_clip: float = 1.0):
    def train_step(state, batch):
        def loss_fn(p):
            return model.loss_fn(p, batch, impl=impl, rules=rules)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        # global-norm clip
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        lr = cosine_warmup(state["opt"]["step"], peak_lr=peak_lr,
                           warmup=warmup, total=total_steps)
        new_params, new_opt = opt.update(
            grads, state["opt"], state["params"], lr_scale=lr / opt.lr
        )
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(model: Model, rules, *, impl: str = "xla"):
    def prefill_step(params, cache, batch):
        return model.prefill_fn(params, cache, batch, impl=impl, rules=rules)

    return prefill_step


def make_decode_step(model: Model, rules, *, impl: str = "xla"):
    def decode_step(params, cache, tokens, t):
        return model.decode_fn(params, cache, tokens, t, impl=impl,
                               rules=rules)

    return decode_step


def make_optimizer(cfg: ArchConfig, **kw):
    return OPTIMIZERS[cfg.optimizer](**kw)


# --------------------------------------------------------------------- specs

def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                rules: ShardingRules, *, seq_len: int | None = None):
    """Abstract train/prefill batch: tokens (+ frames for enc-dec)."""
    S = seq_len if seq_len is not None else shape.seq_len
    Bz = shape.global_batch
    bspec = act_spec(rules, "bn")
    out = {}
    if cfg.is_encoder_decoder:
        Se = Sd = S // 2
        out["tokens"] = _sds((Bz, Sd), jnp.int32, mesh, bspec)
        out["frames"] = _sds((Bz, Se, cfg.d_model), jnp.float32, mesh,
                             act_spec(rules, "bnn"))
    else:
        out["tokens"] = _sds((Bz, S), jnp.int32, mesh, bspec)
    return out


def state_specs(model: Model, opt, mesh: Mesh, rules: ShardingRules):
    """Abstract {params, opt} train state (ShapeDtypeStruct + sharding)."""
    return {
        "params": abstract_params(model.defs, rules, mesh),
        "opt": abstract_params(opt.state_defs(model.defs), rules, mesh),
    }


def cache_specs(model: Model, mesh: Mesh, rules: ShardingRules,
                bsz: int, smax: int):
    return abstract_params(model.make_cache_defs(bsz, smax), rules, mesh)


def train_input_specs(model: Model, opt, shape: ShapeConfig, mesh: Mesh,
                      rules: ShardingRules):
    return (
        state_specs(model, opt, mesh, rules),
        batch_specs(model.cfg, shape, mesh, rules),
    )


def serve_input_specs(model: Model, shape: ShapeConfig, mesh: Mesh,
                      rules: ShardingRules, *, kind: str):
    """kind: 'prefill' (full-seq forward filling the cache) or 'decode'
    (one token against a seq_len-deep cache)."""
    cfg = model.cfg
    Bz, S = shape.global_batch, shape.seq_len
    params = abstract_params(model.defs, rules, mesh)
    cache = cache_specs(model, mesh, rules, Bz, S)
    bspec = act_spec(rules, "bn")
    if kind == "prefill":
        batch = batch_specs(cfg, shape, mesh, rules)
        return params, cache, batch
    tokens = _sds((Bz, 1), jnp.int32, mesh, bspec)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    return params, cache, tokens, t


def out_shardings_for(tree_specs):
    """Extract the NamedShardings from a ShapeDtypeStruct tree (or None)."""
    return jax.tree.map(lambda s: getattr(s, "sharding", None), tree_specs)
