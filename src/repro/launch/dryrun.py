import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the abstract inputs (ShapeDtypeStruct + NamedSharding — zero
     allocation) for the right step kind (train / prefill / decode),
  2. ``jax.jit(step, in_shardings=...).lower(...).compile()`` under the
     production mesh (16x16 single-pod, 2x16x16 multi-pod),
  3. records ``memory_analysis`` (fits-per-device proof), ``cost_analysis``
     (FLOPs/bytes) and the collective-op bytes parsed from the partitioned
     HLO, and derives the three roofline terms (DESIGN.md §7),
  4. writes one JSON per cell into --out (EXPERIMENTS.md §Dry-run reads it).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh, rules_for_mesh
from repro.launch.steps import (
    batch_specs,
    make_decode_step,
    make_optimizer,
    make_prefill_step,
    make_train_step,
    serve_input_specs,
    train_input_specs,
)
from repro.models.zoo import build_model

# ----------------------------------------------------------------- constants
PEAK_FLOPS = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Per-device bytes moved by collectives, summed from the partitioned
    HLO: for each collective op, the bytes of its *result* shapes (the
    payload resident on one device).  ``-start`` async forms counted once;
    ``-done`` skipped."""
    per_op: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    count: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo.splitlines():
        for c in _COLLECTIVES:
            tag = f" {c}(" if f" {c}(" in line else (
                f" {c}-start(" if f" {c}-start(" in line else None)
            if tag is None:
                continue
            lhs = line.split(tag)[0]
            if "=" not in lhs:
                continue
            result = lhs.split("=", 1)[1]
            b = _shape_bytes(result)
            per_op[c] += b
            count[c] += 1
            break
    return {
        "bytes_by_type": per_op,
        "count_by_type": count,
        "total_bytes": sum(per_op.values()),
    }


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = [
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes", "host_argument_size_in_bytes",
        "host_output_size_in_bytes", "host_temp_size_in_bytes",
        "peak_memory_in_bytes",
    ]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def min_bytes_estimate(cfg, shape, n_chips: int) -> float:
    """Analytic lower bound on per-chip HBM traffic for one step (documented
    approximation; the denominator for the memory-roofline fraction):

      train:   params read (fwd+bwd) + grad write + param write
               + AdamW m/v read+write (f32) + layer-boundary activations x3
      prefill: params read + KV-cache write + boundary activations
      decode:  active params read + cache read/write slice
    """
    P = cfg.param_count() * 2.0                      # bf16 bytes
    Pa = cfg.active_param_count() * 2.0
    opt = cfg.param_count() * (16.0 if cfg.optimizer == "adamw" else 2.0)
    L, D = cfg.n_layers + cfg.encoder_layers, cfg.d_model
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        acts = 3.0 * L * toks * D * 2.0
        total = 4.0 * P + 2.0 * opt + acts
    elif shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        kv = 2.0 * L * toks * cfg.n_kv_heads * cfg.head_dim * 2.0
        total = P + kv + L * toks * D * 2.0
    else:
        kv_per_tok = 2.0 * L * cfg.n_kv_heads * cfg.head_dim * 2.0
        if cfg.mla is not None:
            kv_per_tok = L * (cfg.mla.kv_lora_rank
                              + cfg.mla.qk_rope_head_dim) * 2.0
        cache = shape.global_batch * shape.seq_len * kv_per_tok
        if cfg.attn_free:
            cache = (shape.global_batch * cfg.n_layers * (D / cfg.head_dim)
                     * cfg.head_dim ** 2 * 4.0)
        total = Pa + cache
    return total / n_chips


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·tokens (train) / 2·N_active·tokens (fwd);
    attention score FLOPs excluded by convention (standard MFU accounting)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch          # decode: 1 token / seq


def cell_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: O(S^2) at 500k infeasible"
    return True, ""


# --------------------------------------------------------------- cost probes
#
# XLA's HloCostAnalysis counts a while-loop body ONCE (trip counts are
# dynamic), so the scanned-layer dry-run under-reports flops / bytes /
# collective bytes by ~L x.  The probe pass recovers artifact-derived totals:
# lower the SAME step at 1 and 2 layers (per layer type) with every scan
# fully unrolled, take per-type deltas, extrapolate linearly:
#     cost(full) = base + sum_type N_type * delta_type
# RWKV-6's WKV time-scan cannot be unrolled (T up to 524288); its per-step
# cost is supplemented analytically (flagged in the record).

import dataclasses as _dc

from repro.kernels.flash_attention import ops as _fa_ops


def _probe_variants(cfg) -> tuple[list, list[dict], dict]:
    """Returns (type_names, probe replacement dicts, full counts)."""
    if cfg.is_encoder_decoder:
        return (
            ["enc", "dec"],
            [dict(encoder_layers=1, n_layers=1),
             dict(encoder_layers=2, n_layers=1),
             dict(encoder_layers=2, n_layers=2)],
            {"enc": cfg.encoder_layers, "dec": cfg.n_layers},
        )
    if cfg.n_experts and cfg.n_dense_layers:
        return (
            ["dense", "moe"],
            [dict(n_dense_layers=1, n_layers=2),
             dict(n_dense_layers=2, n_layers=3),
             dict(n_dense_layers=2, n_layers=4)],
            {"dense": cfg.n_dense_layers,
             "moe": cfg.n_layers - cfg.n_dense_layers},
        )
    if cfg.family == "hybrid":
        period = len(cfg.block_pattern)
        return (
            ["group"],
            [dict(n_layers=period), dict(n_layers=2 * period)],
            {"group": cfg.n_layers / period},
        )
    return (
        ["layer"],
        [dict(n_layers=1), dict(n_layers=2)],
        {"layer": cfg.n_layers},
    )


def _lower_cell(cfg, shape, mesh, rules, kind):
    model = build_model(cfg)
    with mesh:
        if kind == "train":
            opt = make_optimizer(cfg)
            step = make_train_step(model, opt, rules)
            specs = train_input_specs(model, opt, shape, mesh, rules)
            return jax.jit(step, donate_argnums=(0,)).lower(*specs)
        if kind == "prefill":
            step = make_prefill_step(model, rules)
            specs = serve_input_specs(model, shape, mesh, rules,
                                      kind="prefill")
            return jax.jit(step, donate_argnums=(1,)).lower(*specs)
        step = make_decode_step(model, rules)
        specs = serve_input_specs(model, shape, mesh, rules, kind="decode")
        return jax.jit(step, donate_argnums=(1,)).lower(*specs)


def _cost_triple(compiled) -> dict:
    cost = _cost_dict(compiled)
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll_bytes": float(coll["total_bytes"]),
    }


def _wkv_supplement(cfg, shape, kind, n_chips) -> dict:
    """Analytic per-token WKV cost (the un-unrollable T-scan), per chip."""
    if not cfg.attn_free:
        return {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
    H, N = cfg.d_model // cfg.head_dim, cfg.head_dim
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    steps_missing = max(tokens - shape.global_batch, 0)   # probe counted 1
    mult = 4.0 if kind == "train" else 1.0                # fwd+recompute+bwd
    flops = steps_missing * H * (6 * N * N) * mult * cfg.n_layers
    bytes_ = steps_missing * H * (2 * N * N * 4) * mult * cfg.n_layers
    return {"flops": flops / n_chips, "bytes": bytes_ / n_chips,
            "coll_bytes": 0.0}


def probe_corrected_costs(cfg, shape, mesh, rules, kind, n_chips) -> dict:
    """Artifact-derived (flops, bytes, collective bytes), scan-corrected."""
    types, variants, full_counts = _probe_variants(cfg)
    _fa_ops.set_scan_unroll(True)
    try:
        costs = []
        for repl in variants:
            pcfg = _dc.replace(cfg, scan_unroll=True, **repl)
            compiled = _lower_cell(pcfg, shape, mesh, rules, kind).compile()
            costs.append(_cost_triple(compiled))
    finally:
        _fa_ops.set_scan_unroll(False)

    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        vals = [c[key] for c in costs]
        if len(types) == 1:
            delta = {types[0]: vals[1] - vals[0]}
            base = vals[0] - delta[types[0]]
        else:
            delta = {types[0]: vals[1] - vals[0],
                     types[1]: vals[2] - vals[1]}
            base = vals[0] - delta[types[0]] - delta[types[1]]
        out[key] = base + sum(full_counts[t] * delta[t] for t in types)
    supp = _wkv_supplement(cfg, shape, kind, n_chips)
    for k in out:
        out[k] += supp[k]
    out["probe_raw"] = costs
    out["wkv_supplement"] = supp
    return out


# --------------------------------------------------------------- variants
# §Perf hillclimb knobs: each variant = (rules overrides, cfg overrides).
VARIANTS: dict[str, dict] = {
    "baseline": dict(),
    # serving: replicate params over 'data' (no FSDP at inference), cache
    # sharded batch x heads — kills the per-step KV/param all-gathers
    "serve_repl": dict(rules=dict(fsdp=None, sequence=None)),
    # MoE: pin dispatch buffers to (expert x EP, capacity x DP)
    "moe_dispatch": dict(cfg=dict(moe_dispatch_sharding=True)),
    # MoE: explicit expert-parallel shard_map (local dispatch, ZeRO gather,
    # psum combine) — see models/moe_ep.py
    "moe_ep": dict(cfg=dict(moe_impl="ep_shardmap")),
    "moe_ep_dots": dict(cfg=dict(moe_impl="ep_shardmap", remat="dots")),
    # selective rematerialization: save matmul outputs, recompute elementwise
    "remat_dots": dict(cfg=dict(remat="dots")),
    # megatron-style activation sharding over the model axis
    "act_shard": dict(rules=dict(act_embed="model")),
    # larger attention KV chunks: fewer online-softmax accumulator rewrites
    "attn_chunk4k": dict(cfg=dict(attn_kv_chunk=4096)),
    # combined training recipe (per-cell winners composed)
    "train_opt": dict(cfg=dict(attn_kv_chunk=4096, remat="dots")),
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             rules_overrides: dict | None = None,
             label: str = "baseline", probes: bool = True,
             variant: str = "baseline") -> dict:
    cfg = configs.get(arch)
    var = VARIANTS[variant]
    if var.get("cfg"):
        cfg = _dc.replace(cfg, **var["cfg"])
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "label": label,
        "kind": shape.kind, "applicable": ok,
    }
    if not ok:
        rec["skip_reason"] = why
        _write(out_dir, rec)
        return rec

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    overrides = dict(rules_overrides or {})
    overrides.update(var.get("rules", {}))
    if shape.global_batch < mesh.shape.get("data", 1) * mesh.shape.get(
            "pod", 1):
        # batch unshardable (long_500k B=1): replicate batch, shard the
        # sequence axis of caches over both axes instead (SP).
        overrides.setdefault("batch", ())
        overrides.setdefault(
            "sequence",
            ("data", "model") if "model" in mesh.axis_names else ("data",),
        )
    rules = rules_for_mesh(mesh, **overrides)
    model = build_model(cfg)

    with mesh:
        if shape.kind == "train":
            opt = make_optimizer(cfg)
            step = make_train_step(model, opt, rules)
            specs = train_input_specs(model, opt, shape, mesh, rules)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(*specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, rules)
            specs = serve_input_specs(model, shape, mesh, rules,
                                      kind="prefill")
            lowered = jax.jit(step, donate_argnums=(1,)).lower(*specs)
        else:
            step = make_decode_step(model, rules)
            specs = serve_input_specs(model, shape, mesh, rules,
                                      kind="decode")
            lowered = jax.jit(step, donate_argnums=(1,)).lower(*specs)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = _cost_dict(compiled)
    mem = _memory_dict(compiled)
    print(mem)    # memory_analysis: proves the per-device footprint fits
    print({k: v for k, v in cost.items()
           if k in ("flops", "bytes accessed")})   # cost_analysis headline
    coll = collective_bytes_from_hlo(compiled.as_text())

    if probes:
        corrected = probe_corrected_costs(cfg, shape, mesh, rules,
                                          shape.kind, n_chips)
        rec["probe_corrected"] = {
            k: corrected[k] for k in ("flops", "bytes", "coll_bytes")
        }
        rec["probe_detail"] = {
            "raw": corrected["probe_raw"],
            "wkv_supplement": corrected["wkv_supplement"],
        }
        flops = corrected["flops"]
        bytes_acc = corrected["bytes"]
        coll_bytes = corrected["coll_bytes"]
    else:
        flops = cost.get("flops", 0.0)
        bytes_acc = cost.get("bytes accessed", 0.0)
        coll_bytes = float(coll["total_bytes"])
    # cost_analysis is per-device post-SPMD; roofline terms per DESIGN.md §7
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_bytes / LINK_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(cfg, shape)
    min_b = min_bytes_estimate(cfg, shape, n_chips)
    t_max = max(t_compute, t_memory, t_coll)
    t_useful_compute = mf / n_chips / PEAK_FLOPS
    t_min_memory = min_b / HBM_BW
    # roofline fraction: useful work at the hardware ceiling of the step's
    # *useful* bound, over the modelled step time (max of the three terms)
    frac = (max(t_useful_compute, t_min_memory) / t_max) if t_max > 0 else None
    rec.update(
        n_chips=int(n_chips),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        cost_analysis=cost,
        memory_analysis=mem,
        collectives=coll,
        roofline={
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops_global": mf,
            "model_flops_per_chip": mf / n_chips,
            "useful_flops_ratio": (mf / n_chips) / flops if flops else None,
            "min_bytes_per_chip": min_b,
            "useful_bytes_ratio": min_b / bytes_acc if bytes_acc else None,
            "t_useful_compute_s": t_useful_compute,
            "t_min_memory_s": t_min_memory,
            "roofline_fraction": frac,
        },
    )
    _write(out_dir, rec)
    return rec


def _write(out_dir: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['label']}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    r = rec.get("roofline", {})
    if rec.get("applicable", True):
        print(
            f"[dryrun] {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:12s}"
            f" compile={rec.get('compile_s', 0):7.1f}s"
            f" dominant={r.get('dominant', '-'):10s}"
            f" frac={r.get('roofline_fraction') or 0:.3f}",
            flush=True,
        )
    else:
        print(f"[dryrun] {rec['arch']:24s} {rec['shape']:12s} "
              f"{rec['mesh']:12s} SKIP: {rec['skip_reason']}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--label", default=None)
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the scan-unrolled cost probes")
    args = ap.parse_args()
    if args.label is None:
        args.label = args.variant

    archs = args.arch or (list(configs.ARCH_NAMES) if args.all else [])
    shapes = args.shape or list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]
    if not archs:
        ap.error("pass --arch or --all")
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, args.out, label=args.label,
                             probes=not args.no_probes,
                             variant=args.variant)
                except Exception:
                    failures.append((arch, shape, mp))
                    print(f"[dryrun] FAILED {arch} {shape} multi={mp}",
                          flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
