"""Property tests for divide-and-conquer separators (paper Section 3.2).

``find_separators`` is checked against a brute-force oracle that evaluates
the two separator conditions literally on transitive-closure sets:

  (a) every other node is a strict ancestor or strict descendant of v,
  (b) no edge jumps from a strict ancestor directly to a strict descendant.

And the optimality argument behind ``partition`` is exercised end-to-end:
concatenating per-segment exact DP schedules must reproduce the whole-graph
DP peak (Wilken et al., 2000 — the argument the paper invokes).

A seeded random sweep always runs; the hypothesis variants add shrinking
and wider exploration when hypothesis is installed (it is pinned in the
``test`` extra, so CI runs both).
"""

import random

import pytest

from repro.core import (
    Graph,
    dp_schedule,
    find_separators,
    partition,
    simulate_schedule,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------- graph builders

def random_dag(rng: random.Random, max_nodes: int = 10) -> Graph:
    n = rng.randint(2, max_nodes)
    specs = []
    for i in range(n):
        preds = []
        if i > 0:
            k = rng.randint(0, min(i, 3))
            preds = sorted(rng.sample(range(i), k))
        specs.append(dict(name=f"n{i}", op="op",
                          size_bytes=rng.randint(1, 64), preds=preds))
    return Graph.build(specs)


def hourglass_dag(rng: random.Random, max_cells: int = 4,
                  max_cell_nodes: int = 4) -> Graph:
    """Cells joined by single nodes: separator-rich by construction."""
    specs = [dict(name="in", op="op", size_bytes=rng.randint(1, 32),
                  preds=[])]
    joint = 0
    for _ in range(rng.randint(1, max_cells)):
        branch_ids = []
        for _ in range(rng.randint(1, max_cell_nodes)):
            specs.append(dict(name=f"n{len(specs)}", op="op",
                              size_bytes=rng.randint(1, 32), preds=[joint]))
            branch_ids.append(len(specs) - 1)
        specs.append(dict(name=f"n{len(specs)}", op="op",
                          size_bytes=rng.randint(1, 32), preds=branch_ids))
        joint = len(specs) - 1
    return Graph.build(specs)


# ------------------------------------------------------------ the oracles

def brute_force_separators(g: Graph) -> list[int]:
    """Conditions (a) and (b) evaluated literally on closure sets."""
    n = len(g)
    ancestors = [set() for _ in range(n)]
    for u in g.topo_order():
        for p in g.nodes[u].preds:
            ancestors[u] |= ancestors[p] | {p}
    descendants = [set() for _ in range(n)]
    for u in range(n):
        for a in ancestors[u]:
            descendants[a].add(u)
    seps = []
    for v in range(n):
        if ancestors[v] | descendants[v] | {v} != set(range(n)):
            continue                                   # (a) fails
        crossing = any(
            p in ancestors[v]
            for d in descendants[v]
            for p in g.nodes[d].preds
        )
        if not crossing:                               # (b) holds
            seps.append(v)
    return sorted(seps)


def _segment_concat_peak(g: Graph) -> tuple[list[int], int]:
    """Concatenate per-segment exact DP schedules; return (order, peak)."""
    order: list[int] = []
    for seg in partition(g):
        sub_ids = sorted(set(seg.node_ids) | set(seg.boundary_in))
        sub, idmap = g.induced_subgraph(sub_ids)
        inv = {v: k for k, v in idmap.items()}
        pre = tuple(idmap[b] for b in seg.boundary_in)
        res = dp_schedule(sub, preplaced=pre)
        order.extend(inv[u] for u in res.order)
    return order, simulate_schedule(g, order).peak_bytes


# ------------------------------------------------- seeded deterministic sweep

def test_separators_match_brute_force_seeded_sweep():
    rng = random.Random(2003_02369)
    for i in range(120):
        g = random_dag(rng) if i % 2 else hourglass_dag(rng)
        assert sorted(find_separators(g)) == brute_force_separators(g), \
            f"graph #{i}: {[ (nd.id, nd.preds) for nd in g.nodes ]}"


def test_hourglass_graphs_always_have_separators():
    rng = random.Random(7)
    for _ in range(40):
        g = hourglass_dag(rng)
        assert len(find_separators(g)) >= 1


def test_segment_concatenated_dp_matches_whole_graph_seeded_sweep():
    rng = random.Random(42)
    for i in range(60):
        g = random_dag(rng, max_nodes=11) if i % 2 else hourglass_dag(rng)
        order, peak = _segment_concat_peak(g)
        assert g.is_topological(order)
        assert peak == dp_schedule(g).peak_bytes


# ------------------------------------------------------ hypothesis variants

if HAVE_HYPOTHESIS:

    @st.composite
    def random_dags(draw, max_nodes=10):
        n = draw(st.integers(min_value=2, max_value=max_nodes))
        specs = []
        for i in range(n):
            preds = []
            if i > 0:
                k = draw(st.integers(min_value=0, max_value=min(i, 3)))
                preds = sorted(draw(st.sets(
                    st.integers(min_value=0, max_value=i - 1),
                    min_size=min(k, i), max_size=min(k, i),
                )))
            size = draw(st.integers(min_value=1, max_value=64))
            specs.append(dict(name=f"n{i}", op="op", size_bytes=size,
                              preds=preds))
        return Graph.build(specs)

    @given(random_dags())
    @settings(max_examples=80, deadline=None)
    def test_find_separators_matches_brute_force(g):
        assert sorted(find_separators(g)) == brute_force_separators(g)

    @given(random_dags(max_nodes=11))
    @settings(max_examples=50, deadline=None)
    def test_segment_concatenated_dp_matches_whole_graph_dp(g):
        order, peak = _segment_concat_peak(g)
        assert g.is_topological(order)
        assert peak == dp_schedule(g).peak_bytes

else:

    def test_hypothesis_variants_skipped():
        pytest.skip("hypothesis not installed: seeded sweeps above cover "
                    "the same properties")
