"""Property tests for divide-and-conquer separators (paper Section 3.2).

``find_separators`` is checked against a brute-force oracle that evaluates
the two separator conditions literally on transitive-closure sets:

  (a) every other node is a strict ancestor or strict descendant of v,
  (b) no edge jumps from a strict ancestor directly to a strict descendant.

And the optimality argument behind ``partition`` is exercised end-to-end:
concatenating per-segment exact DP schedules must reproduce the whole-graph
DP peak (Wilken et al., 2000 — the argument the paper invokes).

A seeded random sweep always runs; the hypothesis variants add shrinking
and wider exploration when hypothesis is installed (it is pinned in the
``test`` extra, so CI runs both).
"""

import random

import pytest

from repro.core import (
    Graph,
    PlanCache,
    dp_schedule,
    find_separators,
    partition,
    partition_hierarchy,
    schedule_order,
    simulate_schedule,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------- graph builders

def random_dag(rng: random.Random, max_nodes: int = 10) -> Graph:
    n = rng.randint(2, max_nodes)
    specs = []
    for i in range(n):
        preds = []
        if i > 0:
            k = rng.randint(0, min(i, 3))
            preds = sorted(rng.sample(range(i), k))
        specs.append(dict(name=f"n{i}", op="op",
                          size_bytes=rng.randint(1, 64), preds=preds))
    return Graph.build(specs)


def hourglass_dag(rng: random.Random, max_cells: int = 4,
                  max_cell_nodes: int = 4) -> Graph:
    """Cells joined by single nodes: separator-rich by construction."""
    specs = [dict(name="in", op="op", size_bytes=rng.randint(1, 32),
                  preds=[])]
    joint = 0
    for _ in range(rng.randint(1, max_cells)):
        branch_ids = []
        for _ in range(rng.randint(1, max_cell_nodes)):
            specs.append(dict(name=f"n{len(specs)}", op="op",
                              size_bytes=rng.randint(1, 32), preds=[joint]))
            branch_ids.append(len(specs) - 1)
        specs.append(dict(name=f"n{len(specs)}", op="op",
                          size_bytes=rng.randint(1, 32), preds=branch_ids))
        joint = len(specs) - 1
    return Graph.build(specs)


# ------------------------------------------------------------ the oracles

def brute_force_separators(g: Graph) -> list[int]:
    """Conditions (a) and (b) evaluated literally on closure sets."""
    n = len(g)
    ancestors = [set() for _ in range(n)]
    for u in g.topo_order():
        for p in g.nodes[u].preds:
            ancestors[u] |= ancestors[p] | {p}
    descendants = [set() for _ in range(n)]
    for u in range(n):
        for a in ancestors[u]:
            descendants[a].add(u)
    seps = []
    for v in range(n):
        if ancestors[v] | descendants[v] | {v} != set(range(n)):
            continue                                   # (a) fails
        crossing = any(
            p in ancestors[v]
            for d in descendants[v]
            for p in g.nodes[d].preds
        )
        if not crossing:                               # (b) holds
            seps.append(v)
    return sorted(seps)


def _segment_concat_peak(g: Graph) -> tuple[list[int], int]:
    """Concatenate per-segment exact DP schedules; return (order, peak)."""
    order: list[int] = []
    for seg in partition(g):
        sub_ids = sorted(set(seg.node_ids) | set(seg.boundary_in))
        sub, idmap = g.induced_subgraph(sub_ids)
        inv = {v: k for k, v in idmap.items()}
        pre = tuple(idmap[b] for b in seg.boundary_in)
        res = dp_schedule(sub, preplaced=pre)
        order.extend(inv[u] for u in res.order)
    return order, simulate_schedule(g, order).peak_bytes


# ------------------------------------------------- seeded deterministic sweep

def test_separators_match_brute_force_seeded_sweep():
    rng = random.Random(2003_02369)
    for i in range(120):
        g = random_dag(rng) if i % 2 else hourglass_dag(rng)
        assert sorted(find_separators(g)) == brute_force_separators(g), \
            f"graph #{i}: {[ (nd.id, nd.preds) for nd in g.nodes ]}"


def test_hourglass_graphs_always_have_separators():
    rng = random.Random(7)
    for _ in range(40):
        g = hourglass_dag(rng)
        assert len(find_separators(g)) >= 1


def test_segment_concatenated_dp_matches_whole_graph_seeded_sweep():
    rng = random.Random(42)
    for i in range(60):
        g = random_dag(rng, max_nodes=11) if i % 2 else hourglass_dag(rng)
        order, peak = _segment_concat_peak(g)
        assert g.is_topological(order)
        assert peak == dp_schedule(g).peak_bytes


# ------------------------------------------------- nested segment tree

def test_hierarchy_leaves_cover_every_node():
    rng = random.Random(77)
    for i in range(60):
        g = random_dag(rng) if i % 2 else hourglass_dag(rng)
        root = partition_hierarchy(g)
        leaves = root.leaves()
        covered = sorted(u for lf in leaves for u in lf.node_ids)
        assert covered == list(range(len(g)))
        # leaf boundaries reference only earlier-scheduled nodes
        seen: set[int] = set()
        for lf in leaves:
            assert set(lf.boundary_in) <= seen
            seen |= set(lf.node_ids)


def test_hierarchy_matches_flat_partition_on_separator_chains():
    """Flat separator cuts are maximal, so the tree's leaves partition the
    free nodes exactly like the flat pass (DESIGN.md §8)."""
    rng = random.Random(13)
    for _ in range(30):
        g = hourglass_dag(rng)
        flat = [sorted(s.node_ids) for s in partition(g)]
        leaves = [sorted(lf.node_ids) for lf in partition_hierarchy(g).leaves()]
        assert leaves == flat


def test_schedule_order_concatenates_to_flat_dp_optimum():
    """The hierarchical scheduler (tree walk + per-cell DP + plan-cache
    reuse) must reproduce the flat whole-graph DP peak."""
    rng = random.Random(2003)
    for i in range(40):
        g = random_dag(rng, max_nodes=11) if i % 2 else hourglass_dag(rng)
        res = schedule_order(g)
        assert g.is_topological(res.order)
        assert res.exact
        assert simulate_schedule(g, res.order).peak_bytes == \
            dp_schedule(g).peak_bytes


def test_isomorphic_cell_reuse_on_stacked_network():
    """A stacked repeated-cell network: every cell after the first replays
    from the plan cache and the result still matches the flat DP."""
    from repro.graphs import randwire_network

    g = randwire_network(n_cells=4, n=8, seed=10)
    pc = PlanCache()
    res = schedule_order(g, cache=pc)
    assert res.exact
    assert res.seg_cache_hits >= 3          # cells 2..4 replayed
    assert g.is_topological(res.order)
    # small enough for the flat exact DP: peaks must agree
    flat = dp_schedule(g, state_quota=400_000)
    assert simulate_schedule(g, res.order).peak_bytes == flat.peak_bytes
    # a second run hits every cell
    res2 = schedule_order(g, cache=pc)
    assert res2.seg_cache_hits == len(res2.segments)
    assert res2.order == res.order


def test_schedule_order_timeout_policies():
    """on_timeout='raise' must propagate the cell timeout; the default
    'adaptive' policy must still return a valid (possibly inexact) order."""
    import pytest as _pytest

    from repro.core import SearchTimeout

    # wide fanout: every order has the same peak, levels blow past quota 3
    specs = [dict(name="in", op="input", size_bytes=1)]
    for i in range(12):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=1, preds=[0]))
    g = Graph.build(specs)
    with _pytest.raises(SearchTimeout):
        schedule_order(g, state_quota=3, exact_threshold=0,
                       on_timeout="raise")
    res = schedule_order(g, state_quota=3, exact_threshold=0)
    assert g.is_topological(res.order)


def test_full_network_schedules_exactly_within_budget():
    """The acceptance gate: a stacked >=200-node RandWire network schedules
    *exactly* (no beam fallback) in well under a minute end to end."""
    import time

    from repro.core import schedule
    from repro.graphs import randwire_network

    g = randwire_network(n_cells=8, n=32)
    assert len(g) >= 200
    t0 = time.perf_counter()
    res = schedule(g, cache=PlanCache(), compute_baselines=False)
    wall = time.perf_counter() - t0
    assert res.exact, "full network fell back from the exact DP"
    assert wall < 60.0, f"{wall:.1f}s breaks the one-minute budget"
    assert res.graph.is_topological(res.order)
    assert res.seg_cache_hits > 0           # repeated cells replayed
    assert simulate_schedule(res.graph, res.order).peak_bytes == \
        res.peak_bytes


# ------------------------------------------------------ hypothesis variants

if HAVE_HYPOTHESIS:

    @st.composite
    def random_dags(draw, max_nodes=10):
        n = draw(st.integers(min_value=2, max_value=max_nodes))
        specs = []
        for i in range(n):
            preds = []
            if i > 0:
                k = draw(st.integers(min_value=0, max_value=min(i, 3)))
                preds = sorted(draw(st.sets(
                    st.integers(min_value=0, max_value=i - 1),
                    min_size=min(k, i), max_size=min(k, i),
                )))
            size = draw(st.integers(min_value=1, max_value=64))
            specs.append(dict(name=f"n{i}", op="op", size_bytes=size,
                              preds=preds))
        return Graph.build(specs)

    @given(random_dags())
    @settings(max_examples=80, deadline=None)
    def test_find_separators_matches_brute_force(g):
        assert sorted(find_separators(g)) == brute_force_separators(g)

    @given(random_dags(max_nodes=11))
    @settings(max_examples=50, deadline=None)
    def test_segment_concatenated_dp_matches_whole_graph_dp(g):
        order, peak = _segment_concat_peak(g)
        assert g.is_topological(order)
        assert peak == dp_schedule(g).peak_bytes

    @given(random_dags(max_nodes=11))
    @settings(max_examples=50, deadline=None)
    def test_hierarchical_schedule_order_matches_whole_graph_dp(g):
        res = schedule_order(g)
        assert g.is_topological(res.order)
        assert res.exact
        assert simulate_schedule(g, res.order).peak_bytes == \
            dp_schedule(g).peak_bytes

else:

    def test_hypothesis_variants_skipped():
        pytest.skip("hypothesis not installed: seeded sweeps above cover "
                    "the same properties")
