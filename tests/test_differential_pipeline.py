"""Cross-module differential fuzzing of the full pipeline (DESIGN.md §6/§8).

Every prior layer is spot-checked in isolation; this suite drives random
DAGs through scheduler × rewriter × allocator × executor *as one pipeline*
and cross-checks every redundant path against every other:

  * ``dp_schedule``: ``bnb=True`` vs ``bnb=False`` vs brute force (on
    graphs small enough), across the engine set (``--engines``), must all
    report the same optimal peak — and every returned order must replay to
    that peak through ``simulate_schedule``;
  * with and without ``rewrite_graph`` / ``annotate_inplace``: the
    rewritten variants go through the same agreement checks;
  * through ``plan_arena_best`` and the arena executor: the realized
    live-byte peak/extent must equal the plan's (``strict=True`` asserts
    it; we re-assert explicitly), and the arena-backed outputs must be
    bit-for-bit the plain dict-interpreter's (``run_reference``);
  * ``plan_shared_arena`` co-residency: members of a joint plan must be
    address-disjoint wherever their joint lifetimes overlap, and each
    member must execute strictly against one shared buffer;
  * recompute-expanded graphs (PR 6): ``rematerialize``'s search output
    and force-expanded clone graphs go through the same agreement checks
    (DP == brute-force oracle on small graphs, arena executor bit-equal
    to the reference), and an expanded graph's outputs must be bit-equal
    to the *unexpanded* graph's;
  * the latency x memory Pareto frontier (PR 8, DESIGN.md §12): on every
    corpus variant small enough for the oracle (<= 10 nodes) the DP
    frontier must equal the independent ILP / suffix-enumeration oracle
    exactly — no dominated, missing or extra points — and on every seed a
    sampled non-serial frontier point is executed against a step-packed
    arena with realized == planned asserted and outputs bit-equal to the
    reference.  Tier-1 runs the oracle's solver-free fallback; the CI
    ``ilp`` matrix job re-runs the same frontiers through pulp/CBC.

A fixed 50-seed corpus runs in tier-1 under a wall-clock cap;
hypothesis-driven variants (random seeds, deeper graphs) ride behind
``--runslow``.
"""

from __future__ import annotations

import time

import numpy as np
import pytest

from repro.core import (
    Graph,
    annotate_inplace,
    brute_force_schedule,
    dp_schedule,
    execute_plan,
    oracle_frontier,
    pareto_schedule,
    plan_arena_best,
    plan_shared_arena,
    rematerialize,
    rewrite_graph,
    run_reference,
    simulate_schedule,
    simulate_steps,
)
from repro.core.rewriter import RECOMPUTE_EXCLUDED_OPS, _clone_out

N_SEEDS = 50
BRUTE_MAX = 12          # brute-force oracle bound (node count)
CORPUS_TIME_CAP_S = 240.0
_sample_times: list[float] = []


@pytest.fixture(scope="module")
def engines(request) -> list[str]:
    return [e.strip()
            for e in request.config.getoption("--engines").split(",")
            if e.strip()]


# ---------------------------------------------------------------------------
# Seeded random-DAG generator: sizes, fan-in patterns, concat/conv motifs
# ---------------------------------------------------------------------------


def random_pipeline_graph(seed: int, max_nodes: int = 14) -> Graph:
    """A random executable DAG exercising every pipeline feature.

    Sizes are float32-aligned (executor requirement).  Motifs are inserted
    with calibrated probabilities so the corpus reliably contains the
    rewriter's patterns (``concat -> conv``, ``concat -> depthconv`` with
    aligned branch shares) and in-place-eligible elementwise chains, plus
    plain concats, accumulating adds and multi-fan-in convs that must
    survive rewriting untouched.
    """
    rng = np.random.default_rng(seed)
    n_target = int(rng.integers(6, max_nodes + 1))
    specs: list[dict] = []

    def size() -> int:
        return 4 * int(rng.integers(1, 33))

    def pick(k: int) -> list[int]:
        return sorted(int(x) for x in
                      rng.choice(len(specs), size=k, replace=False))

    for i in range(int(rng.integers(1, 3))):
        specs.append(dict(name=f"in{i}", op="input", size_bytes=size(),
                          preds=[]))
    while len(specs) < n_target:
        i = len(specs)
        r = rng.random()
        if r < 0.18 and i >= 2:
            # concat -> conv motif (rewriter: accumulating partial convs)
            preds = pick(int(rng.integers(2, min(3, i) + 1)))
            csize = sum(specs[p]["size_bytes"] for p in preds)
            specs.append(dict(name=f"cc{i}", op="concat", size_bytes=csize,
                              preds=preds))
            specs.append(dict(name=f"k{i}", op="conv", size_bytes=size(),
                              preds=[len(specs) - 1]))
        elif r < 0.30 and i >= 2:
            # concat -> depthconv motif with equal-size branches, so the
            # rewriter's kernel-wise shares stay float32-aligned
            k = int(rng.integers(2, min(3, i) + 1))
            b = size()
            srcs = pick(k)
            branch_ids = []
            for j, s in enumerate(srcs):
                specs.append(dict(name=f"b{i}.{j}", op="conv", size_bytes=b,
                                  preds=[s]))
                branch_ids.append(len(specs) - 1)
            specs.append(dict(name=f"cd{i}", op="concat", size_bytes=k * b,
                              preds=branch_ids))
            specs.append(dict(name=f"dw{i}", op="depthconv",
                              size_bytes=4 * k * int(rng.integers(1, 17)),
                              preds=[len(specs) - 1]))
        elif r < 0.52:
            # elementwise chain link; same size => in-place eligible when
            # the pred has no other consumer (bias toward the newest
            # non-input node so the corpus reliably marks in-place chains)
            non_input = [j for j in range(i)
                         if specs[j]["op"] != "input"]
            if non_input and rng.random() < 0.7:
                p = non_input[-1]
            else:
                p = int(rng.integers(0, i))
            op = str(rng.choice(["relu", "bn", "sigmoid", "tanh"]))
            specs.append(dict(name=f"e{i}", op=op,
                              size_bytes=specs[p]["size_bytes"], preds=[p]))
        elif r < 0.66 and i >= 2:
            # accumulating add (in-place-annotatable when one operand dies)
            preds = pick(int(rng.integers(2, min(3, i) + 1)))
            s = specs[preds[0]]["size_bytes"] if rng.random() < 0.7 else size()
            specs.append(dict(name=f"a{i}", op="add", size_bytes=s,
                              preds=preds))
        elif r < 0.76 and i >= 2:
            # plain concat the rewriter must leave alone (multi-consumer
            # or no conv behind it)
            preds = pick(int(rng.integers(2, min(3, i) + 1)))
            csize = sum(specs[p]["size_bytes"] for p in preds)
            specs.append(dict(name=f"pc{i}", op="concat", size_bytes=csize,
                              preds=preds))
        else:
            # generic fan-in op
            preds = pick(int(rng.integers(1, min(3, i) + 1)))
            specs.append(dict(name=f"c{i}", op="conv", size_bytes=size(),
                              preds=preds))
    return Graph.build(specs, name=f"fuzz{seed}")


def _variants(g: Graph):
    rw, report = rewrite_graph(g)
    ip, n_ip = annotate_inplace(rw)
    out = [("raw", g)]
    if report.total:
        out.append(("rewritten", rw))
    if n_ip:
        out.append(("inplace", ip))
    # recompute expansion at fuzz-scale search bounds: when the beam finds
    # a clone set that lowers the peak, the expanded graph must pass every
    # check the others do
    rm, rrep = rematerialize(rw, max_rounds=2, beam_width=2, eval_quota=200)
    if rrep.n_clones:
        out.append(("remat", rm))
    return out


# ---------------------------------------------------------------------------
# The per-sample differential check
# ---------------------------------------------------------------------------


def check_sample(g: Graph, engines: list[str]) -> None:
    results = {}
    for eng in engines:
        for bnb in (True, False):
            r = dp_schedule(g, engine=eng, bnb=bnb)
            assert r.exact, (g.name, eng, bnb)
            sim = simulate_schedule(g, r.order)
            assert sim.peak_bytes == r.peak_bytes, (
                f"{g.name}: engine={eng} bnb={bnb} order does not replay "
                f"to its reported peak")
            results[(eng, bnb)] = r
    peaks = {r.peak_bytes for r in results.values()}
    assert len(peaks) == 1, (
        f"{g.name}: engines/bnb disagree on the optimal peak: "
        f"{sorted((k, r.peak_bytes) for k, r in results.items())}")
    peak = peaks.pop()
    if len(g) <= BRUTE_MAX:
        assert brute_force_schedule(g).peak_bytes == peak, (
            f"{g.name}: DP peak {peak} != brute-force optimum")

    order = results[(engines[0], True)].order
    plan = plan_arena_best(g, order)
    assert plan.arena_bytes >= plan.peak_bytes
    ex = execute_plan(g, order, plan, inputs=None, strict=True)
    assert ex.realized_peak_bytes == plan.peak_bytes
    assert ex.realized_arena_bytes == plan.arena_bytes
    ref = run_reference(g)
    assert set(ex.outputs) == set(ref)
    for name, val in ref.items():
        np.testing.assert_array_equal(
            np.asarray(ex.outputs[name]), np.asarray(val),
            err_msg=f"{g.name}: arena output {name!r} diverges from the "
                    f"dict-storage reference")
    # fused alias-chain execution (DESIGN.md §11) must be observationally
    # identical: bit-equal outputs, same realized footprint
    exf = execute_plan(g, order, plan, inputs=None, strict=True, fuse=True)
    assert exf.realized_peak_bytes == plan.peak_bytes
    assert exf.realized_arena_bytes == plan.arena_bytes
    for name, val in ref.items():
        np.testing.assert_array_equal(
            np.asarray(exf.outputs[name]), np.asarray(val),
            err_msg=f"{g.name}: fused output {name!r} diverges from the "
                    f"dict-storage reference")


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_differential_corpus(seed, engines):
    t0 = time.perf_counter()
    g = random_pipeline_graph(seed)
    for _tag, variant in _variants(g):
        check_sample(variant, engines)
    _sample_times.append(time.perf_counter() - t0)


def test_corpus_exercises_every_motif():
    """The fixed corpus must actually hit the rewriter and in-place paths."""
    n_conv = n_dw = n_ip = 0
    for seed in range(N_SEEDS):
        g = random_pipeline_graph(seed)
        rw, report = rewrite_graph(g)
        _, marked = annotate_inplace(rw)
        n_conv += report.n_concat_conv > 0
        n_dw += report.n_concat_depthconv > 0
        n_ip += marked > 0
    assert n_conv >= 5, f"only {n_conv} corpus samples hit concat->conv"
    assert n_dw >= 5, f"only {n_dw} corpus samples hit concat->depthconv"
    assert n_ip >= 10, f"only {n_ip} corpus samples mark in-place ops"


def test_forced_clone_differential(engines):
    """Force one clone step onto eligible fuzz graphs (no search, so the
    corpus covers clones even where they don't lower the peak): the
    expanded graph must pass the full differential check — engines/bnb/
    brute-force agreement plus arena execution — and its outputs must be
    bit-equal to the *unexpanded* graph's."""
    n = 0
    for seed in range(N_SEEDS):
        g = random_pipeline_graph(seed, max_nodes=10)
        cands = [u for u in range(len(g))
                 if len(g.succs[u]) >= 2
                 and g.nodes[u].op not in RECOMPUTE_EXCLUDED_OPS
                 and not g.nodes[u].alias_preds]
        if not cands:
            continue
        u = max(cands, key=lambda v: len(g.succs[v]))
        gx = _clone_out(g, u, 1)
        assert len(gx) == len(g) + 1
        ref, refx = run_reference(g), run_reference(gx)
        assert set(ref) == set(refx)
        for name, val in ref.items():
            np.testing.assert_array_equal(
                np.asarray(refx[name]), np.asarray(val),
                err_msg=f"{g.name}: clone of node {u} changed output "
                        f"{name!r}")
        check_sample(gx, engines)
        n += 1
        if n >= 12:
            break
    assert n >= 8, f"only {n} fuzz graphs had a clonable node"


def test_corpus_under_time_cap():
    # runs after the corpus (pytest executes a module in definition order);
    # guards tier-1 runtime — the corpus must stay a smoke-scale suite
    assert len(_sample_times) in (0, N_SEEDS)
    assert sum(_sample_times) < CORPUS_TIME_CAP_S, (
        f"differential corpus took {sum(_sample_times):.1f}s "
        f"(cap {CORPUS_TIME_CAP_S}s)")


# ---------------------------------------------------------------------------
# Co-residency differential: joint plans are sound and executable
# ---------------------------------------------------------------------------


def _joint_windows(plans):
    """(member, alloc, joint t_alloc, joint t_free) on the serial timeline,
    replicating plan_shared_arena's classification."""
    out = []
    base = 0
    horizons = []
    for mi, p in enumerate(plans):
        mt = max(a.t_free for a in p.allocations)
        horizons.append(mt - 1)
    total = sum(h + 1 for h in horizons)
    for mi, p in enumerate(plans):
        mt = max(a.t_free for a in p.allocations)
        for a in p.allocations:
            if a.t_free == mt:
                out.append((mi, a, 0, total + 1))
            else:
                out.append((mi, a, base + max(a.t_alloc, 0), base + a.t_free))
        base += horizons[mi] + 1
    return out


@pytest.mark.parametrize("seed", [3, 11, 29])
def test_shared_arena_differential(seed, engines):
    import jax.numpy as jnp

    graphs = [random_pipeline_graph(seed + 100 * i) for i in range(3)]
    planned = []
    for g in graphs:
        order = dp_schedule(g, engine=engines[0]).order
        planned.append((g, order, plan_arena_best(g, order)))
    shared = plan_shared_arena([p for _, _, p in planned])
    assert shared.arena_bytes <= shared.sum_member_bytes
    assert len(shared.members) == len(graphs)

    # members' joint offsets: overlapping joint lifetimes => disjoint bytes
    wins = _joint_windows([m for m in shared.members])
    for i in range(len(wins)):
        mi, a, s0, e0 = wins[i]
        assert a.offset >= 0
        assert a.offset + a.size <= shared.arena_bytes
        for j in range(i + 1, len(wins)):
            mj, b, s1, e1 = wins[j]
            if s0 < e1 and s1 < e0:          # joint lifetimes overlap
                disjoint = (a.offset + a.size <= b.offset
                            or b.offset + b.size <= a.offset)
                assert disjoint, (
                    f"members {mi}/{mj}: allocations {a.node_ids} and "
                    f"{b.node_ids} overlap in time and bytes")

    # every member executes strictly against ONE shared buffer
    buf = jnp.zeros(-(-shared.arena_bytes // 4), jnp.float32)
    for (g, order, _), member in zip(planned, shared.members):
        ref = run_reference(g)
        ex = execute_plan(g, order, member, inputs=None, arena=buf,
                          strict=True)
        for name, val in ref.items():
            np.testing.assert_array_equal(np.asarray(ex.outputs[name]),
                                          np.asarray(val))


# ---------------------------------------------------------------------------
# Pareto frontier differential: DP vs independent oracle + step executor
# ---------------------------------------------------------------------------

ORACLE_MAX = 10          # oracle enumeration bound (node count)
PARETO_WIDTH = 2
_pareto_oracle_hits: list[bool] = []
_pareto_exec_hits: list[bool] = []


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_pareto_frontier_corpus(seed):
    """Frontier invariants + oracle agreement + step-executor realization.

    Every corpus variant: the latency-unconstrained endpoint must be the
    exact serial DP peak, and every frontier point must replay through the
    step-model simulator.  Variants small enough for the oracle
    (<= ORACLE_MAX nodes) must match the independent oracle frontier
    *exactly* — no dominated, missing or extra points (tier-1 gets the
    solver-free suffix-enumeration backend; the CI ``ilp`` job re-checks
    through pulp/CBC).  Wherever the frontier has a genuinely concurrent
    point, its min-makespan point is executed against a step-packed arena:
    realized == planned, outputs bit-equal to the reference.
    """
    g = random_pipeline_graph(seed)
    ran_oracle = ran_exec = False
    for tag, variant in _variants(g):
        front = pareto_schedule(variant, max_width=PARETO_WIDTH)
        serial = dp_schedule(variant)
        assert front.min_peak.peak_bytes == serial.peak_bytes, (
            f"{variant.name}/{tag}: frontier endpoint "
            f"{front.min_peak.peak_bytes} != serial DP peak "
            f"{serial.peak_bytes}")
        for pt in front.points:
            sim = simulate_steps(variant, pt.steps)
            assert sim.peak_bytes == pt.peak_bytes, (
                f"{variant.name}/{tag}: point ({pt.makespan}, "
                f"{pt.peak_bytes}) does not replay through simulate_steps")
        if len(variant) <= ORACLE_MAX:
            want = oracle_frontier(variant, max_width=PARETO_WIDTH)
            assert front.pairs() == want, (
                f"{variant.name}/{tag}: DP frontier {front.pairs()} != "
                f"oracle frontier {want}")
            ran_oracle = True
        pt = front.min_makespan
        if pt.width > 1:
            plan = plan_arena_best(variant, pt.order, steps=pt.steps)
            # an alias chain occupies one allocation at the chain's final
            # size for its whole lifetime, so the arena peak may exceed the
            # tensor-level step-model peak on rewritten variants; alias-free
            # graphs must match it exactly
            assert plan.peak_bytes >= pt.peak_bytes
            if not any(nd.alias_preds for nd in variant.nodes):
                assert plan.peak_bytes == pt.peak_bytes
            ex = execute_plan(variant, pt.order, plan, inputs=None,
                              steps=pt.steps, strict=True)
            assert ex.realized_peak_bytes == plan.peak_bytes
            assert ex.realized_arena_bytes == plan.arena_bytes
            ref = run_reference(variant)
            for name, val in ref.items():
                np.testing.assert_array_equal(
                    np.asarray(ex.outputs[name]), np.asarray(val),
                    err_msg=f"{variant.name}/{tag}: step-packed output "
                            f"{name!r} diverges from the reference")
            ran_exec = True
    _pareto_oracle_hits.append(ran_oracle)
    _pareto_exec_hits.append(ran_exec)


def test_pareto_corpus_coverage():
    """The fixed corpus must actually exercise both differential legs."""
    assert len(_pareto_oracle_hits) in (0, N_SEEDS)
    if _pareto_oracle_hits:
        n_oracle = sum(_pareto_oracle_hits)
        n_exec = sum(_pareto_exec_hits)
        assert n_oracle >= 10, (
            f"only {n_oracle} corpus seeds were oracle-sized")
        assert n_exec >= 35, (
            f"only {n_exec} corpus seeds executed a non-serial point")


def test_ilp_frontier_matches_fallback_and_planner():
    """pulp/CBC ILP == suffix-enumeration fallback == planner frontier.

    Runs only with the ``ilp`` optional extra installed (the CI matrix job);
    skips cleanly everywhere else so tier-1 stays solver-free.
    """
    pytest.importorskip("pulp")
    n = 0
    for seed in range(N_SEEDS):
        g = random_pipeline_graph(seed, max_nodes=8)
        if len(g) > 8:
            continue
        for w in (2, 3):
            ilp = oracle_frontier(g, max_width=w, solver="pulp")
            fb = oracle_frontier(g, max_width=w, solver="fallback")
            assert ilp == fb, (g.name, w, ilp, fb)
            assert pareto_schedule(g, max_width=w).pairs() == ilp, (
                g.name, w)
        n += 1
        if n >= 5:
            break
    assert n >= 3, f"only {n} corpus graphs were ILP-sized"


# ---------------------------------------------------------------------------
# Hypothesis variants (--runslow): random seeds, deeper graphs
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:            # hypothesis is a test extra; the fixed
    pass                       # corpus above still runs without it
else:
    @pytest.mark.slow
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 2**32 - 1))
    def test_differential_hypothesis(seed, engines):
        g = random_pipeline_graph(seed)
        for _tag, variant in _variants(g):
            check_sample(variant, engines)

    @pytest.mark.slow
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 2**32 - 1))
    def test_differential_hypothesis_deep(seed, engines):
        g = random_pipeline_graph(seed, max_nodes=22)
        check_sample(g, engines)
