"""Paper benchmark graphs + end-to-end SERENITY pipeline validation
against the paper's claims (ratios; see EXPERIMENTS.md §Paper-validation)."""

import pytest

from repro.core import (
    dp_schedule,
    kahn_schedule,
    rewrite_graph,
    schedule,
    simulate_traffic,
)
from repro.graphs import (
    BENCHMARK_GRAPHS,
    darts_normal_cell,
    randwire_graph,
    swiftnet_cell,
    swiftnet_network,
)


def test_node_counts_match_table2():
    assert len(swiftnet_cell("A")) == 21
    assert len(swiftnet_cell("B")) == 19
    assert len(swiftnet_cell("C")) == 22
    assert len(swiftnet_network()) == 62


def test_all_benchmark_graphs_schedule():
    for name, fn in BENCHMARK_GRAPHS.items():
        g = fn()
        res = schedule(g, state_quota=4000)
        assert g.is_topological([]) or res.order    # schedules exist
        kahn = res.baseline_peaks["kahn"]
        assert res.peak_bytes <= kahn, name


def test_scheduler_gain_band():
    """paper: DP scheduler alone averages 1.68x vs TFLite order; our
    reconstructed cells must land in a meaningful band (>1.2x average)."""
    ratios = []
    for which in ("A", "B", "C"):
        g = swiftnet_cell(which)
        res = schedule(g, rewrite=False, state_quota=4000,
                       compute_baselines=True)
        ratios.append(res.baseline_peaks["kahn"] / res.peak_bytes)
    avg = sum(ratios) / len(ratios)
    assert avg > 1.2, ratios


def test_rewriting_adds_gain():
    """paper: rewriting adds ~10.7% on top of scheduling."""
    for which in ("A", "B", "C"):
        g = swiftnet_cell(which)
        plain = schedule(g, rewrite=False, state_quota=4000,
                         compute_baselines=False).peak_bytes
        rew = schedule(g, rewrite=True, state_quota=4000,
                       compute_baselines=False).peak_bytes
        assert rew < plain, which


def test_offchip_traffic_reduction():
    """paper Fig. 11: better schedules reduce off-chip traffic under a
    fixed on-chip capacity."""
    g = swiftnet_cell("A")
    cap = dp_schedule(g).peak_bytes          # capacity between DP and Kahn
    kahn = kahn_schedule(g)
    t_kahn = simulate_traffic(g, kahn.order, cap,
                              include_weights=False).total_bytes
    dp = dp_schedule(g)
    t_dp = simulate_traffic(g, dp.order, cap,
                            include_weights=False).total_bytes
    assert t_dp <= t_kahn
    assert t_dp == 0                         # DP peak fits fully on-chip


def test_darts_cell_structure():
    g = darts_normal_cell()
    # 2 inputs + 5 sep_conv x 8 nodes + 1 dil_conv x 4 + 4 adds
    # + concat + next conv
    assert any(n.op == "concat" for n in g.nodes)
    assert len(g.entries()) == 2


def test_randwire_is_ws_dag():
    g = randwire_graph(seed=10)
    assert len(g) == 32 + 3                 # 32 nodes + in + mean + out conv
    g.topo_order()                          # acyclic


def test_divide_and_conquer_speedup_structure():
    """Table 2: partitioning splits the 62-node net into per-cell
    subproblems."""
    from repro.core import partition

    g = swiftnet_network()
    segs = partition(g)
    assert len(segs) >= 3                   # at least the 3 cells split
    largest = max(len(s.node_ids) for s in segs)
    assert largest < len(g)
