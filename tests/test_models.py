"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs; prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.zoo import build_model


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, key, B=2, S=32):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.float32)
    return b


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_smoke_forward_no_nans(name, key):
    cfg = C.smoke(name)
    model = build_model(cfg)
    params = model.init(key)
    loss, metrics = jax.jit(lambda p, b: model.loss_fn(p, b))(
        params, _batch(cfg, key)
    )
    assert jnp.isfinite(loss), metrics
    assert loss.shape == ()


@pytest.mark.slow
@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_smoke_train_step_improves_nothing_nan(name, key):
    cfg = C.smoke(name)
    model = build_model(cfg)
    opt = make_optimizer(cfg)
    step = jax.jit(make_train_step(model, opt, None),
                   donate_argnums=(0,))
    params = model.init(key)
    before = [np.asarray(x, np.float32) for x in jax.tree.leaves(params)]
    state = {"params": params, "opt": opt.init(params)}
    batch = _batch(cfg, key)
    for _ in range(2):
        state, metrics = step(state, batch)   # donates state buffers
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    moved = any(
        not np.allclose(a, np.asarray(b, np.float32))
        for a, b in zip(before, jax.tree.leaves(state["params"]))
    )
    assert moved


@pytest.mark.slow
@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_prefill_decode_consistency(name, key):
    """prefill(t0..tn) then decode(t_{n+1}) must equal prefill(t0..t_{n+1})."""
    cfg = C.smoke(name)
    model = build_model(cfg)
    params = model.init(key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S + 1)
    smax = 32

    full_batch = dict(batch)
    full = dict(full_batch, tokens=batch["tokens"])
    cache0 = model.init_cache(B, smax)
    logits_full, _ = jax.jit(
        lambda p, c, b: model.prefill_fn(p, c, b)
    )(params, cache0, full)

    part = dict(batch, tokens=batch["tokens"][:, :S])
    cache1 = model.init_cache(B, smax)
    _, cache1 = jax.jit(
        lambda p, c, b: model.prefill_fn(p, c, b)
    )(params, cache1, part)
    logits_step, _ = jax.jit(
        lambda p, c, tok, t: model.decode_fn(p, c, tok, t)
    )(params, cache1, batch["tokens"][:, S : S + 1], jnp.int32(S))

    # MLA decode uses the *absorbed* form (q projected into the latent
    # space) — mathematically identical to the expanded prefill but with a
    # different bf16 contraction order, so it needs a looser band.
    tol = 1e-1 if cfg.mla is not None else 3e-2
    np.testing.assert_allclose(
        np.asarray(logits_step, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=tol, atol=tol,
    )


def test_moe_router_balanced_dispatch():
    cfg = C.smoke("granite-moe-3b-a800m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, jax.random.PRNGKey(2), B=4, S=64)
    loss, metrics = model.loss_fn(params, batch)
    assert float(metrics["aux_loss"]) > 0.0     # router entropy engaged
    assert jnp.isfinite(loss)


def test_param_counts_match_published_sizes():
    expect = {
        "gemma-7b": 8.5e9, "llama3.2-1b": 1.24e9, "granite-20b": 20.3e9,
        "starcoder2-7b": 7.4e9, "chameleon-34b": 34.3e9,
        "deepseek-v3-671b": 671e9, "rwkv6-7b": 7.5e9,
        "recurrentgemma-2b": 2.6e9,
    }
    for name, target in expect.items():
        n = C.get(name).param_count()
        assert abs(n - target) / target < 0.08, (name, n, target)
