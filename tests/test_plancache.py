"""Content-addressed plan cache: canonical hashing + memoization contract."""

import pickle

import pytest

from repro.core import (
    Graph,
    Node,
    PlanCache,
    canonical_hash,
    labeled_fingerprint,
    schedule,
)
from repro.graphs import randwire_graph


def _relabel(g: Graph, perm: dict[int, int]) -> Graph:
    nodes = [
        Node(
            id=perm[nd.id],
            name=nd.name,
            op=nd.op,
            size_bytes=nd.size_bytes,
            preds=tuple(sorted(perm[p] for p in nd.preds)),
            alias_preds=frozenset(perm[p] for p in nd.alias_preds),
            weight_bytes=nd.weight_bytes,
            meta=nd.meta,
        )
        for nd in g.nodes
    ]
    return Graph(nodes, name=g.name)


def _chain3(last_pred: int = 1) -> Graph:
    return Graph.build([
        dict(name="a", op="input", size_bytes=8),
        dict(name="b", op="op", size_bytes=16, preds=[0]),
        dict(name="c", op="op", size_bytes=4, preds=[last_pred]),
    ])


# -- canonical hashing -------------------------------------------------------


def test_relabeled_isomorphic_graphs_hash_equal():
    g = randwire_graph(seed=10, n=16)
    n = len(g)
    # id reversal keeps edge directions, only relabels nodes
    rev = _relabel(g, {i: n - 1 - i for i in range(n)})
    assert canonical_hash(g) == canonical_hash(rev)
    # labeled fingerprints must still distinguish the two labelings
    assert labeled_fingerprint(g) != labeled_fingerprint(rev)


def test_hash_is_deterministic_across_rebuilds():
    a = randwire_graph(seed=10, n=16)
    b = randwire_graph(seed=10, n=16)
    assert a is not b
    assert canonical_hash(a) == canonical_hash(b)
    assert labeled_fingerprint(a) == labeled_fingerprint(b)


def test_shape_change_busts_hash():
    g = randwire_graph(seed=10, n=16)
    nodes = list(g.nodes)
    nodes[3] = nodes[3].replace(size_bytes=nodes[3].size_bytes + 4)
    g2 = Graph(nodes, name=g.name)
    assert canonical_hash(g) != canonical_hash(g2)
    assert labeled_fingerprint(g) != labeled_fingerprint(g2)


def test_edge_change_busts_hash():
    assert canonical_hash(_chain3(1)) != canonical_hash(_chain3(0))


def test_op_change_busts_hash():
    g = _chain3()
    nodes = list(g.nodes)
    nodes[1] = nodes[1].replace(op="conv")
    assert canonical_hash(g) != canonical_hash(Graph(nodes, name=g.name))


# -- cache behaviour ---------------------------------------------------------


def test_hit_returns_identical_schedule():
    g = randwire_graph(seed=10, n=16)
    pc = PlanCache()
    cold = schedule(g, cache=pc)
    warm = schedule(g, cache=pc)
    # cold run: one whole-graph miss plus one per partition cell (segment
    # plans are cached too — that's the isomorphic-cell reuse tier); warm
    # run: a single whole-graph hit short-circuits everything
    assert pc.stats.misses == 1 + len(cold.segments)
    assert pc.stats.hits == 1
    # the memory tier returns the cold run's plan itself: byte-identical
    assert warm is cold
    assert pickle.dumps(warm) == pickle.dumps(cold)


def test_hit_on_rebuilt_identical_graph():
    pc = PlanCache()
    cold = schedule(randwire_graph(seed=10, n=16), cache=pc)
    warm = schedule(randwire_graph(seed=10, n=16), cache=pc)
    assert pc.stats.hits == 1
    assert warm.order == cold.order
    assert warm.peak_bytes == cold.peak_bytes


def test_option_change_misses():
    g = randwire_graph(seed=10, n=16)
    pc = PlanCache()
    r1 = schedule(g, cache=pc)
    r2 = schedule(g, cache=pc, rewrite=False)
    # different options must not collide on the whole-graph entry...
    assert r2 is not r1
    # ...while a repeat of either call is a zero-copy hit
    assert schedule(g, cache=pc) is r1
    assert schedule(g, cache=pc, rewrite=False) is r2


def test_graph_change_misses():
    g = randwire_graph(seed=10, n=16)
    pc = PlanCache()
    r1 = schedule(g, cache=pc)
    nodes = list(g.nodes)
    nodes[0] = nodes[0].replace(size_bytes=nodes[0].size_bytes * 2)
    g2 = Graph(nodes, name=g.name)
    r2 = schedule(g2, cache=pc)
    # a size change busts the whole-graph entry (no stale plan returned)
    assert r2 is not r1
    assert r2.peak_bytes != r1.peak_bytes or r2.order != r1.order \
        or r2.graph.sizes != r1.graph.sizes


def test_disk_tier_round_trip(tmp_path):
    g = randwire_graph(seed=10, n=16)
    pc1 = PlanCache(disk_dir=str(tmp_path))
    cold = schedule(g, cache=pc1)
    # fresh process-level cache, same directory: must hit the disk tier
    pc2 = PlanCache(disk_dir=str(tmp_path))
    warm = schedule(randwire_graph(seed=10, n=16), cache=pc2)
    assert pc2.stats.disk_hits == 1
    assert warm.order == cold.order
    assert warm.peak_bytes == cold.peak_bytes
    assert [a for a in warm.arena.allocations] == \
        [a for a in cold.arena.allocations]


def test_lru_eviction():
    pc = PlanCache(capacity=2)
    graphs = [_chain3(), randwire_graph(seed=10, n=8),
              randwire_graph(seed=100, n=8)]
    results = [schedule(g, cache=pc) for g in graphs]
    assert len(pc) == 2
    # most recent whole-graph entry still resident -> zero-copy hit
    assert schedule(graphs[2], cache=pc) is results[2]
    # oldest whole-graph entry evicted -> re-scheduling it recomputes
    assert schedule(graphs[0], cache=pc) is not results[0]


def test_cache_false_disables():
    g = _chain3()
    r1 = schedule(g, cache=False)
    r2 = schedule(g, cache=False)
    assert r1 is not r2
    assert r1.order == r2.order


def test_cache_survives_pickle_of_graph():
    # Graph pickling drops the lazily-built numpy tables and keeps hashes valid
    g = randwire_graph(seed=10, n=16)
    g.masks()
    g2 = pickle.loads(pickle.dumps(g))
    assert canonical_hash(g2) == canonical_hash(g)
    assert labeled_fingerprint(g2) == labeled_fingerprint(g)


@pytest.mark.parametrize("seed", [10, 100])
def test_jax_bridge_uses_cache(seed):
    jax = pytest.importorskip("jax")
    jnp = jax.numpy
    from repro.core.plancache import configure_default, default_cache

    def fn(x):
        return (jnp.tanh(x) @ jnp.ones((x.shape[-1], 8))).sum() * seed

    configure_default(None)
    closed = jax.make_jaxpr(fn)(jnp.ones((4, 16)))
    from repro.core.jax_bridge import schedule_jaxpr
    _, rep1 = schedule_jaxpr(closed)
    misses = default_cache().stats.misses
    _, rep2 = schedule_jaxpr(closed)
    assert default_cache().stats.misses == misses      # second call: pure hit
    assert default_cache().stats.hits >= 1
    assert rep2.order == rep1.order
    configure_default(None)


# -- canonical tier + cross-labeling order translation (DESIGN.md §8) --------


def _asym_chain() -> Graph:
    """Distinct sizes everywhere: WL refinement individualizes every node."""
    return Graph.build([
        dict(name="a", op="input", size_bytes=100),
        dict(name="b", op="conv", size_bytes=50, preds=[0]),
        dict(name="c", op="conv", size_bytes=25, preds=[0]),
        dict(name="d", op="add", size_bytes=10, preds=[1, 2]),
    ])


def test_wl_colors_are_label_invariant():
    from repro.core import wl_colors

    g = _asym_chain()
    perm = {0: 3, 1: 0, 2: 2, 3: 1}
    g2 = _relabel(g, perm)
    c1, c2 = wl_colors(g), wl_colors(g2)
    assert sorted(c1) == sorted(c2)
    assert [c2[perm[u]] for u in range(len(g))] == c1


def test_translate_order_maps_relabeled_schedule():
    from repro.core import dp_schedule, simulate_schedule, translate_order

    g = _asym_chain()
    perm = {0: 2, 1: 3, 2: 0, 3: 1}
    g2 = _relabel(g, perm)
    res = dp_schedule(g)
    translated = translate_order(g, g2, res.order)
    assert translated == [perm[u] for u in res.order]
    assert g2.is_topological(translated)
    assert simulate_schedule(g2, translated).peak_bytes == res.peak_bytes


def test_translate_order_refuses_symmetric_cells():
    from repro.core import translate_order

    # two interchangeable branches: WL cannot individualize them
    g = Graph.build([
        dict(name="in", op="input", size_bytes=8),
        dict(name="l", op="conv", size_bytes=8, preds=[0]),
        dict(name="r", op="conv", size_bytes=8, preds=[0]),
        dict(name="out", op="add", size_bytes=8, preds=[1, 2]),
    ])
    assert translate_order(g, g, [0, 1, 2, 3]) is None


def test_get_canonical_returns_isomorph_payload():
    g = _asym_chain()
    g2 = _relabel(g, {0: 3, 1: 0, 2: 2, 3: 1})
    pc = PlanCache()
    pc.put(g, ("opts",), "payload")
    # exact tier misses for the relabeled graph, canonical tier serves it
    assert pc.get(g2, ("opts",)) is None
    assert pc.get_canonical(g2, ("opts",)) == "payload"
    # same labeling is NOT served by the canonical tier (exact tier owns it)
    assert pc.get_canonical(g, ("opts",)) is None
    # different options stay separate
    assert pc.get_canonical(g2, ("other",)) is None


# -- schema bump: pareto configs can never alias pre-bump entries (§12) ------


def _prebump_key(pc: PlanCache, g: Graph, config) -> tuple[str, str, str]:
    """The cache key a pre-PR-8 build would have used for this config.

    Pre-bump code ran SCHEMA_VERSION 5 and a ``cache_key()`` without the
    pareto fields (objective/max_width/latency_budget); reconstructing that
    key lets the tests prove the current keyspace is disjoint from it.
    """
    from repro.core import plancache as pcm

    legacy = tuple(kv for kv in config.cache_key()
                   if kv[0] not in ("objective", "max_width",
                                    "latency_budget"))
    old = pcm.SCHEMA_VERSION
    pcm.SCHEMA_VERSION = 5
    try:
        return pc.key_for(g, ("serenity.plan", legacy))
    finally:
        pcm.SCHEMA_VERSION = old


def test_schema_version_bumped_for_pareto():
    from repro.core.plancache import SCHEMA_VERSION
    from repro.core.serenity import PlanConfig

    # reverting the bump would let schema-5 pickles (no steps/makespan/
    # frontier fields) poison pareto lookups
    assert SCHEMA_VERSION >= 6
    names = {k for k, _ in PlanConfig().cache_key()}
    assert {"objective", "max_width", "latency_budget"} <= names


def test_options_key_depends_on_schema_version(monkeypatch):
    from repro.core import plancache as pcm

    k_now = pcm._options_key(("serenity.plan",))
    monkeypatch.setattr(pcm, "SCHEMA_VERSION", 5)
    assert pcm._options_key(("serenity.plan",)) != k_now


def test_pareto_config_never_aliases_prebump_entry():
    """A stale pre-bump entry must be unreachable from every new config.

    Covers both halves of the bump: the SCHEMA_VERSION fold (same options
    tuple, older code) and the cache_key shape change (new (name, value)
    pairs).  The poison payload is a sentinel that would crash plan() if a
    lookup ever returned it.
    """
    from repro.core import PlanConfig, plan

    g = randwire_graph(seed=3, n=12)
    pc = PlanCache()
    configs = [
        PlanConfig(),
        PlanConfig(objective="pareto", max_width=2),
        PlanConfig(objective="pareto", max_width=2,
                   latency_budget=10 ** 12),
    ]
    poison = object()
    for cfg in configs:
        stale = _prebump_key(pc, g, cfg)
        with pc._lock:
            pc._mem_put(stale, poison)
        assert pc.key_for(g, ("serenity.plan", cfg.cache_key())) != stale
    for cfg in configs:
        res = plan(g, cfg, cache=pc)
        assert res is not poison
        assert g.is_topological(res.order)


def test_pareto_and_peak_plans_do_not_alias():
    """Same graph, same cache: the two objectives key separately."""
    from repro.core import PlanConfig, plan

    g = randwire_graph(seed=3, n=12)
    pc = PlanCache()
    r_peak = plan(g, PlanConfig(), cache=pc)
    r_par = plan(g, PlanConfig(objective="pareto", max_width=2), cache=pc)
    assert r_par is not r_peak
    assert r_peak.schedule_frontier is None and r_peak.steps is None
    assert r_par.schedule_frontier is not None
    # repeats are zero-copy hits on their own entries
    assert plan(g, PlanConfig(), cache=pc) is r_peak
    assert plan(g, PlanConfig(objective="pareto", max_width=2),
                cache=pc) is r_par

# -- disk corruption (DESIGN.md §13) -----------------------------------------


def _put_one(tmp_path, payload="payload", options=("t",)):
    """Seed a disk-backed cache with one entry; return (graph, path)."""
    g = _chain3()
    pc = PlanCache(disk_dir=str(tmp_path))
    pc.put(g, options, payload)
    path = pc._disk_path(pc.key_for(g, options))
    assert path is not None and __import__("os").path.exists(path)
    return g, path


class TestBlobFrame:
    def test_round_trip(self):
        from repro.core.plancache import frame_blob, unframe_blob

        payload = pickle.dumps({"order": [0, 1, 2]})
        blob = frame_blob(payload)
        assert blob != payload                # frame actually prepends bytes
        assert unframe_blob(blob) == payload

    def test_rejects_truncation_garbage_and_stale_schema(self):
        import struct
        import zlib

        from repro.core.plancache import (
            SCHEMA_VERSION,
            frame_blob,
            unframe_blob,
        )

        payload = pickle.dumps(list(range(64)))
        blob = frame_blob(payload)
        # truncated write: anything shorter than the full blob fails CRC
        assert unframe_blob(blob[: len(blob) // 2]) is None
        assert unframe_blob(b"") is None
        assert unframe_blob(blob[:7]) is None          # shorter than header
        # single flipped payload bit
        bad = bytearray(blob)
        bad[-1] ^= 0x40
        assert unframe_blob(bytes(bad)) is None
        # wrong magic
        assert unframe_blob(b"XXXX" + blob[4:]) is None
        # intact blob from an older code version: schema field catches what
        # CRC cannot
        stale = struct.pack(
            "<4sII", b"RPLN", SCHEMA_VERSION - 1, zlib.crc32(payload)
        ) + payload
        assert unframe_blob(stale) is None


class TestDiskCorruptionEviction:
    def _fresh_get(self, tmp_path, g, options=("t",)):
        pc = PlanCache(disk_dir=str(tmp_path))
        return pc, pc.get(g, options)

    def test_truncated_write_is_counted_and_evicted(self, tmp_path):
        import os

        g, path = _put_one(tmp_path)
        with open(path, "rb") as f:
            blob = f.read()
        with open(path, "wb") as f:
            f.write(blob[: len(blob) // 2])
        pc, got = self._fresh_get(tmp_path, g)
        assert got is None                    # clean miss, not poison
        assert pc.stats.corrupt == 1
        assert pc.stats.misses == 1
        assert not os.path.exists(path)       # evicted on detection
        # next read is an ordinary miss, not another corruption event
        pc2, got2 = self._fresh_get(tmp_path, g)
        assert got2 is None and pc2.stats.corrupt == 0

    def test_garbage_bytes_are_counted_and_evicted(self, tmp_path):
        import os

        g, path = _put_one(tmp_path)
        blob = bytearray(open(path, "rb").read())
        blob[len(blob) // 2] ^= 0xFF          # bit rot mid-payload
        with open(path, "wb") as f:
            f.write(bytes(blob))
        pc, got = self._fresh_get(tmp_path, g)
        assert got is None
        assert pc.stats.corrupt == 1
        assert not os.path.exists(path)

    def test_stale_schema_blob_is_counted_and_evicted(self, tmp_path):
        import os
        import struct
        import zlib

        from repro.core.plancache import SCHEMA_VERSION, unframe_blob

        g, path = _put_one(tmp_path)
        payload = unframe_blob(open(path, "rb").read())
        assert payload is not None
        with open(path, "wb") as f:           # intact blob, older writer
            f.write(struct.pack(
                "<4sII", b"RPLN", SCHEMA_VERSION - 1, zlib.crc32(payload)
            ) + payload)
        pc, got = self._fresh_get(tmp_path, g)
        assert got is None
        assert pc.stats.corrupt == 1
        assert not os.path.exists(path)

    def test_framed_unpicklable_payload_is_corrupt(self, tmp_path):
        # CRC passes but pickle.loads raises: still a counted eviction
        from repro.core.plancache import frame_blob

        g, path = _put_one(tmp_path)
        with open(path, "wb") as f:
            f.write(frame_blob(b"\x80\x04 not a pickle"))
        pc, got = self._fresh_get(tmp_path, g)
        assert got is None
        assert pc.stats.corrupt == 1

    def test_blob_hook_injects_corruption(self, tmp_path):
        # the chaos seam: a hook-flipped bit is detected like real bit rot
        from repro.runtime import ChaosController, FaultPlan

        g, _ = _put_one(tmp_path)
        chaos = ChaosController(FaultPlan.generate(
            seed=3, n_ticks=4, kinds=("cache_corrupt",), rate=1.0))
        chaos.begin_tick(1)                   # arm a cache_corrupt fault
        pc = PlanCache(disk_dir=str(tmp_path), blob_hook=chaos.corrupt_blob)
        assert pc.get(g, ("t",)) is None
        assert pc.stats.corrupt == 1
        # an idle hook passes blobs through untouched
        _put_one(tmp_path)
        pc2 = PlanCache(disk_dir=str(tmp_path), blob_hook=chaos.corrupt_blob)
        assert pc2.get(g, ("t",)) == "payload"
        assert pc2.stats.disk_hits == 1 and pc2.stats.corrupt == 0

    def test_schedule_survives_corrupted_disk_tier(self, tmp_path):
        # end-to-end: every disk entry rotten -> recompute, re-persist
        import glob

        g = randwire_graph(seed=10, n=16)
        cold = schedule(g, cache=PlanCache(disk_dir=str(tmp_path)))
        for path in glob.glob(str(tmp_path / "*.plan.pkl")):
            with open(path, "r+b") as f:
                f.truncate(9)
        pc = PlanCache(disk_dir=str(tmp_path))
        again = schedule(randwire_graph(seed=10, n=16), cache=pc)
        assert pc.stats.corrupt >= 1
        assert pc.stats.disk_hits == 0
        assert again.order == cold.order
        assert again.peak_bytes == cold.peak_bytes
        # the recompute re-persisted valid frames: third process disk-hits
        pc3 = PlanCache(disk_dir=str(tmp_path))
        warm = schedule(randwire_graph(seed=10, n=16), cache=pc3)
        assert pc3.stats.disk_hits == 1 and pc3.stats.corrupt == 0
        assert warm.order == cold.order
