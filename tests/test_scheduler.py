"""DP scheduler: optimality vs brute force, budget & quota semantics."""

import pytest

from repro.core import (
    Graph,
    NoSolutionError,
    SearchTimeout,
    brute_force_schedule,
    dp_schedule,
    kahn_schedule,
    simulate_schedule,
)


def diamond():
    return Graph.build([
        dict(name="a", op="input", size_bytes=10),
        dict(name="b", op="op", size_bytes=100, preds=[0]),
        dict(name="c", op="op", size_bytes=1, preds=[0]),
        dict(name="d", op="op", size_bytes=5, preds=[1, 2]),
    ])


def test_dp_matches_bruteforce_diamond():
    g = diamond()
    dp = dp_schedule(g)
    bf = brute_force_schedule(g)
    assert dp.peak_bytes == bf.peak_bytes
    assert g.is_topological(dp.order)


def test_simulate_agrees_with_result():
    g = diamond()
    dp = dp_schedule(g)
    sim = simulate_schedule(g, dp.order)
    assert sim.peak_bytes == dp.peak_bytes


def test_wide_fanout_prefers_small_branches_interleaved():
    # one input feeding k independent expand->project chains; optimal keeps
    # only one expanded tensor live at a time
    specs = [dict(name="in", op="input", size_bytes=10)]
    for i in range(4):
        specs.append(dict(name=f"e{i}", op="op", size_bytes=1000,
                          preds=[0]))
        specs.append(dict(name=f"p{i}", op="op", size_bytes=10,
                          preds=[len(specs) - 1]))
    g = Graph.build(specs)
    dp = dp_schedule(g)
    bf = brute_force_schedule(g)
    assert dp.peak_bytes == bf.peak_bytes
    # peak ~ one expanded (1000) + input + done projections
    assert dp.peak_bytes <= 10 + 1000 + 4 * 10
    # BFS (kahn) keeps all four expanded tensors live
    assert kahn_schedule(g).peak_bytes >= 4 * 1000


def test_budget_below_optimal_raises():
    g = diamond()
    opt = dp_schedule(g).peak_bytes
    with pytest.raises(NoSolutionError):
        dp_schedule(g, budget=opt - 1)
    # at the optimum the schedule is found
    assert dp_schedule(g, budget=opt).peak_bytes == opt


def test_quota_raises_timeout():
    specs = [dict(name="in", op="input", size_bytes=1)]
    for i in range(12):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=1, preds=[0]))
    g = Graph.build(specs)
    with pytest.raises(SearchTimeout):
        dp_schedule(g, state_quota=3)


def test_beam_mode_completes_under_quota():
    specs = [dict(name="in", op="input", size_bytes=1)]
    for i in range(12):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=i + 1,
                          preds=[0]))
    g = Graph.build(specs)
    res = dp_schedule(g, state_quota=3, on_quota="beam")
    assert g.is_topological(res.order)


def test_preplaced_boundary():
    g = Graph.build([
        dict(name="x", op="input", size_bytes=7),
        dict(name="y", op="op", size_bytes=3, preds=[0]),
        dict(name="z", op="op", size_bytes=2, preds=[1]),
    ])
    res = dp_schedule(g, preplaced=(0,))
    assert res.order == [1, 2]
    # x(7) resident, +y(3)=10 peak, x freed after y -> z: 3+2
    assert res.peak_bytes == 10


def test_alias_nodes_do_not_double_count():
    g = Graph.build([
        dict(name="x", op="input", size_bytes=100),
        dict(name="acc", op="partial_conv", size_bytes=100, preds=[0],
             alias_preds=[0]),
    ])
    res = dp_schedule(g)
    assert res.peak_bytes == 100   # in-place: storage subsumed
