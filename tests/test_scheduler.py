"""DP scheduler: optimality vs brute force, budget & quota semantics."""

import pytest

from repro.core import (
    Graph,
    NoSolutionError,
    SearchTimeout,
    brute_force_schedule,
    dp_schedule,
    kahn_schedule,
    simulate_schedule,
)


def diamond():
    return Graph.build([
        dict(name="a", op="input", size_bytes=10),
        dict(name="b", op="op", size_bytes=100, preds=[0]),
        dict(name="c", op="op", size_bytes=1, preds=[0]),
        dict(name="d", op="op", size_bytes=5, preds=[1, 2]),
    ])


def test_dp_matches_bruteforce_diamond():
    g = diamond()
    dp = dp_schedule(g)
    bf = brute_force_schedule(g)
    assert dp.peak_bytes == bf.peak_bytes
    assert g.is_topological(dp.order)


def test_simulate_agrees_with_result():
    g = diamond()
    dp = dp_schedule(g)
    sim = simulate_schedule(g, dp.order)
    assert sim.peak_bytes == dp.peak_bytes


def test_wide_fanout_prefers_small_branches_interleaved():
    # one input feeding k independent expand->project chains; optimal keeps
    # only one expanded tensor live at a time
    specs = [dict(name="in", op="input", size_bytes=10)]
    for i in range(4):
        specs.append(dict(name=f"e{i}", op="op", size_bytes=1000,
                          preds=[0]))
        specs.append(dict(name=f"p{i}", op="op", size_bytes=10,
                          preds=[len(specs) - 1]))
    g = Graph.build(specs)
    dp = dp_schedule(g)
    bf = brute_force_schedule(g)
    assert dp.peak_bytes == bf.peak_bytes
    # peak ~ one expanded (1000) + input + done projections
    assert dp.peak_bytes <= 10 + 1000 + 4 * 10
    # BFS (kahn) keeps all four expanded tensors live
    assert kahn_schedule(g).peak_bytes >= 4 * 1000


def test_budget_below_optimal_raises():
    g = diamond()
    opt = dp_schedule(g).peak_bytes
    with pytest.raises(NoSolutionError):
        dp_schedule(g, budget=opt - 1)
    # at the optimum the schedule is found
    assert dp_schedule(g, budget=opt).peak_bytes == opt


def test_quota_raises_timeout():
    specs = [dict(name="in", op="input", size_bytes=1)]
    for i in range(12):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=1, preds=[0]))
    g = Graph.build(specs)
    with pytest.raises(SearchTimeout):
        dp_schedule(g, state_quota=3)


def test_beam_mode_completes_under_quota():
    specs = [dict(name="in", op="input", size_bytes=1)]
    for i in range(12):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=i + 1,
                          preds=[0]))
    g = Graph.build(specs)
    res = dp_schedule(g, state_quota=3, on_quota="beam")
    assert g.is_topological(res.order)


def test_preplaced_boundary():
    g = Graph.build([
        dict(name="x", op="input", size_bytes=7),
        dict(name="y", op="op", size_bytes=3, preds=[0]),
        dict(name="z", op="op", size_bytes=2, preds=[1]),
    ])
    res = dp_schedule(g, preplaced=(0,))
    assert res.order == [1, 2]
    # x(7) resident, +y(3)=10 peak, x freed after y -> z: 3+2
    assert res.peak_bytes == 10


def test_alias_nodes_do_not_double_count():
    g = Graph.build([
        dict(name="x", op="input", size_bytes=100),
        dict(name="acc", op="partial_conv", size_bytes=100, preds=[0],
             alias_preds=[0]),
    ])
    res = dp_schedule(g)
    assert res.peak_bytes == 100   # in-place: storage subsumed


# -- vectorized engine parity -------------------------------------------------


def _random_dag(rng, n):
    specs = []
    for i in range(n):
        k = rng.randint(0, min(i, 3))
        preds = sorted(rng.sample(range(i), k)) if k else []
        specs.append(dict(name=f"n{i}", op="op",
                          size_bytes=rng.randint(1, 64), preds=preds))
    return Graph.build(specs)


def test_numpy_engine_matches_python_on_random_dags():
    import random

    rng = random.Random(42)
    for _ in range(60):
        g = _random_dag(rng, rng.randint(2, 11))
        a = dp_schedule(g, engine="python")
        b = dp_schedule(g, engine="numpy")
        assert (a.peak_bytes, a.final_bytes) == (b.peak_bytes, b.final_bytes)
        assert g.is_topological(b.order)
        assert simulate_schedule(g, b.order).peak_bytes == b.peak_bytes


def test_numpy_engine_matches_python_on_benchmark_graphs():
    """Acceptance gate: identical peaks on every tier-1 benchmark graph."""
    from repro.graphs import BENCHMARK_GRAPHS

    for name, fn in BENCHMARK_GRAPHS.items():
        g = fn()
        a = dp_schedule(g, engine="python", state_quota=200_000)
        b = dp_schedule(g, engine="numpy", state_quota=200_000)
        assert (a.peak_bytes, a.final_bytes) == \
            (b.peak_bytes, b.final_bytes), name
        assert g.is_topological(b.order), name


@pytest.mark.parametrize("n_nodes,words", [(80, 2), (150, 3)])
def test_numpy_engine_multiword_masks(n_nodes, words):
    """Graphs past 64 nodes exercise the multi-word packed-mask path.

    150 nodes gives a 3-word mask — a *non*-power-of-two row width, which
    the flat bit-position decode must handle with true division.
    """
    import random

    rng = random.Random(7)
    # mostly-chain wiring keeps the exact-DP state space small at n=150
    specs = [dict(name="n0", op="op", size_bytes=8)]
    for i in range(1, n_nodes):
        preds = {i - 1} if rng.random() < 0.95 else \
            {rng.randint(max(0, i - 3), i - 1)}
        if rng.random() < 0.06:
            preds.add(rng.randint(max(0, i - 4), i - 1))
        specs.append(dict(name=f"n{i}", op="op",
                          size_bytes=rng.randint(1, 64),
                          preds=sorted(preds)))
    g = Graph.build(specs)
    assert g.masks().words == words
    a = dp_schedule(g, engine="python", state_quota=200_000)
    b = dp_schedule(g, engine="numpy", state_quota=200_000)
    assert (a.peak_bytes, a.final_bytes) == (b.peak_bytes, b.final_bytes)
    assert simulate_schedule(g, b.order).peak_bytes == b.peak_bytes


def test_numpy_engine_budget_and_quota_semantics():
    g = diamond()
    opt = dp_schedule(g, engine="numpy").peak_bytes
    with pytest.raises(NoSolutionError):
        dp_schedule(g, engine="numpy", budget=opt - 1)
    assert dp_schedule(g, engine="numpy", budget=opt).peak_bytes == opt
    specs = [dict(name="in", op="input", size_bytes=1)]
    for i in range(12):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=1, preds=[0]))
    wide = Graph.build(specs)
    with pytest.raises(SearchTimeout):
        dp_schedule(wide, engine="numpy", state_quota=3)
    beam = dp_schedule(wide, engine="numpy", state_quota=3, on_quota="beam")
    assert wide.is_topological(beam.order)


# -- fragmentation-aware tie-breaking -----------------------------------------


def test_water_estimate_bounds_and_engine_parity():
    """The arena-watermark estimate is a path property, >= the liveness peak,
    and both engines must agree on the per-signature winner's value."""
    import random

    rng = random.Random(7)
    for _ in range(40):
        g = _random_dag(rng, rng.randint(2, 11))
        a = dp_schedule(g, engine="python")
        b = dp_schedule(g, engine="numpy")
        assert a.arena_est_bytes >= a.peak_bytes
        assert (a.peak_bytes, a.final_bytes, a.arena_est_bytes) == \
            (b.peak_bytes, b.final_bytes, b.arena_est_bytes)


def test_water_estimate_exact_on_chains():
    """On a chain the estimate is exact: each step reuses the dead pred's
    hole, so water == peak == the realized first-fit arena."""
    from repro.core import plan_arena

    specs = [dict(name="n0", op="input", size_bytes=100)]
    for i in range(1, 8):
        specs.append(dict(name=f"n{i}", op="op", size_bytes=100,
                          preds=[i - 1]))
    g = Graph.build(specs)
    for engine in ("python", "numpy"):
        res = dp_schedule(g, engine=engine)
        assert res.arena_est_bytes == res.peak_bytes == 200
        plan = plan_arena(g, res.order)
        assert plan.arena_bytes == res.arena_est_bytes


def test_tie_break_prefers_hole_reusing_order():
    """Two equal-peak completions exist: free the big tensor before
    allocating its replacement (hole reuse) or after (arena grows).  The DP
    must report the hole-reusing watermark."""
    # in -> a(100) -> b(100) consumes a; c(100) also consumes in.
    # peak is 210 either way (a+b live, or a+c live), but scheduling c
    # before b keeps three 100-buffers in flight for first-fit while
    # b-before-c reuses a's hole.
    g = Graph.build([
        dict(name="in", op="input", size_bytes=10),
        dict(name="a", op="op", size_bytes=100, preds=[0]),
        dict(name="b", op="op", size_bytes=100, preds=[1]),
        dict(name="c", op="op", size_bytes=100, preds=[0]),
    ])
    from repro.core import brute_force_schedule, plan_arena

    for engine in ("python", "numpy"):
        res = dp_schedule(g, engine=engine)
        assert res.peak_bytes == brute_force_schedule(g).peak_bytes
        plan = plan_arena(g, res.order)
        # realized first-fit arena matches the DP's estimate: no surprise
        # fragmentation on the chosen order
        assert plan.arena_bytes == res.arena_est_bytes, engine


# -- branch-and-bound + dominance pruning (DESIGN.md §8) ----------------------


def test_bnb_matches_unbounded_on_random_dags():
    """The bound layer (incumbent, lower bound, eager-move dominance) must
    never change the optimal peak — checked against the unpruned DP and the
    brute-force oracle, on both engines."""
    import random

    rng = random.Random(20030)
    for _ in range(40):
        g = _random_dag(rng, rng.randint(2, 10))
        bf = brute_force_schedule(g)
        legacy = dp_schedule(g, engine="python", bnb=False)
        for engine in ("python", "numpy"):
            res = dp_schedule(g, engine=engine, bnb=True)
            assert res.peak_bytes == bf.peak_bytes == legacy.peak_bytes
            assert res.final_bytes == legacy.final_bytes
            assert res.n_states_expanded <= legacy.n_states_expanded
            assert g.is_topological(res.order)
            assert simulate_schedule(g, res.order).peak_bytes == res.peak_bytes


def test_bnb_reduces_states_on_benchmark_graphs():
    """Same peaks as the pre-bound DP, with strictly fewer expansions on
    every paper cell (the 5x gate itself lives in bench_scheduling_time)."""
    from repro.graphs import BENCHMARK_GRAPHS

    for name, fn in BENCHMARK_GRAPHS.items():
        g = fn()
        new = dp_schedule(g, state_quota=400_000, bnb=True)
        old = dp_schedule(g, state_quota=400_000, bnb=False)
        assert new.peak_bytes == old.peak_bytes, name
        assert new.final_bytes == old.final_bytes, name
        assert new.n_states_expanded < old.n_states_expanded, name


def test_eager_move_dominance_collapses_chains():
    """Two parallel head->unary-chain branches: once a head has established
    peak slack, every chain step is a zero-cost move and the dominance rule
    prunes the sibling transitions, collapsing the interleaving blowup."""
    specs = []
    chain_len = 7
    for b in range(2):
        head = len(specs)
        specs.append(dict(name=f"h{b}", op="input", size_bytes=1000))
        prev = head
        for i in range(chain_len):
            specs.append(dict(name=f"b{b}c{i}", op="op", size_bytes=100,
                              preds=[prev]))
            prev = len(specs) - 1
    g = Graph.build(specs)
    legacy = dp_schedule(g, engine="python", bnb=False)
    for engine in ("python", "numpy"):
        res = dp_schedule(g, engine=engine, bnb=True)
        assert res.peak_bytes == legacy.peak_bytes
        # without dominance the two chains interleave combinatorially;
        # with it each chain runs as a forced single path
        assert res.n_states_expanded * 3 <= legacy.n_states_expanded


def test_auto_engine_spills_and_matches():
    """engine='auto' starts scalar and restarts vectorized on a wide level;
    results must equal both fixed engines (randwire32 crosses the spill
    threshold, randwire16 stays scalar)."""
    from repro.graphs import randwire_graph

    for n in (16, 32):
        g = randwire_graph(seed=10, n=n)
        auto = dp_schedule(g, state_quota=400_000, engine="auto")
        ref = dp_schedule(g, state_quota=400_000, engine="python")
        vec = dp_schedule(g, state_quota=400_000, engine="numpy")
        assert (auto.peak_bytes, auto.final_bytes, auto.arena_est_bytes) == \
            (ref.peak_bytes, ref.final_bytes, ref.arena_est_bytes)
        assert auto.n_states_expanded == vec.n_states_expanded
        assert g.is_topological(auto.order)


def test_bnb_budget_below_optimal_still_raises():
    """An explicit infeasible budget must dominate the automatic bound."""
    g = diamond()
    opt = dp_schedule(g).peak_bytes
    for engine in ("python", "numpy"):
        with pytest.raises(NoSolutionError):
            dp_schedule(g, engine=engine, budget=opt - 1, bnb=True)
        assert dp_schedule(g, engine=engine, budget=opt,
                           bnb=True).peak_bytes == opt


def test_numpy_engine_preplaced_and_alias():
    g = Graph.build([
        dict(name="x", op="input", size_bytes=7),
        dict(name="y", op="op", size_bytes=3, preds=[0]),
        dict(name="z", op="op", size_bytes=2, preds=[1]),
    ])
    res = dp_schedule(g, engine="numpy", preplaced=(0,))
    assert res.order == [1, 2] and res.peak_bytes == 10
    g = Graph.build([
        dict(name="x", op="input", size_bytes=100),
        dict(name="acc", op="partial_conv", size_bytes=100, preds=[0],
             alias_preds=[0]),
    ])
    assert dp_schedule(g, engine="numpy").peak_bytes == 100
