import os
import sys

# tests see the single real CPU device (the dry-run sets its own device
# count in a separate process)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
