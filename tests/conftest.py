import os
import sys

import pytest

# tests see the single real CPU device (the dry-run sets its own device
# count in a separate process); the path insert keeps `repro` importable
# even without `pip install -e .`
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked slow (long model/kernel/distribution runs)",
    )
    parser.addoption(
        "--engines", default="python,numpy,auto",
        help="comma-separated DP engines the differential pipeline tests "
             "cross-check (CI runs one engine per matrix job)",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow/bench test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords or "bench" in item.keywords:
            item.add_marker(skip)
