"""The PlanConfig planning surface (DESIGN.md §10): config semantics,
deprecation shims, and clone-aware plan caching.

What PR 6 must keep true forever:

  * ``PlanConfig`` is frozen, validated, and name-keyed — its
    ``cache_key()`` can never positionally alias two different configs;
  * every legacy entry point (``schedule``, ``schedule_order``, legacy
    kwargs on ``execute`` / ``plan_coresidency`` / ``schedule_jaxpr``)
    warns ``DeprecationWarning`` exactly once per process, maps onto the
    same ``PlanConfig`` a direct caller would write, and lands on the
    *same* cache entry as the equivalent ``plan`` call;
  * recompute-expanded plans round-trip the two-tier plan cache — memory
    LRU and disk pickle — with their clones' provenance intact.
"""

from __future__ import annotations

import dataclasses
import warnings

import pytest

from repro.core import Graph, PlanCache, PlanConfig, execute, plan
from repro.core.rewriter import recompute_provenance
from repro.core.serenity import (
    _legacy_schedule_config,
    _reset_deprecation_warnings,
    plan_coresidency,
    schedule,
    schedule_order,
)
from repro.graphs import BENCHMARK_GRAPHS, randwire_graph


@pytest.fixture(autouse=True)
def _fresh_warnings():
    # each test sees the once-per-process warning machinery from scratch
    _reset_deprecation_warnings()
    yield
    _reset_deprecation_warnings()


def _diamond() -> Graph:
    return Graph.build([
        dict(name="x", op="input", size_bytes=64, preds=[]),
        dict(name="a", op="conv", size_bytes=128, preds=[0]),
        dict(name="b", op="conv", size_bytes=32, preds=[0]),
        dict(name="y", op="add", size_bytes=32, preds=[1, 2]),
    ], name="diamond")


# ---------------------------------------------------------------------------
# PlanConfig semantics
# ---------------------------------------------------------------------------


def test_planconfig_is_frozen():
    cfg = PlanConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.state_quota = 5
    assert cfg.replace(state_quota=5).state_quota == 5
    assert cfg.state_quota == 20_000          # original untouched


@pytest.mark.parametrize("bad", [
    dict(scheduler="topological"),
    dict(on_timeout="retry"),
    dict(flops_budget=0.5),
])
def test_planconfig_validates(bad):
    with pytest.raises(ValueError):
        PlanConfig(**bad)


def test_planconfig_cache_key_is_name_keyed():
    a, b = PlanConfig(), PlanConfig()
    assert a.cache_key() == b.cache_key()
    assert a.replace(state_quota=99).cache_key() != a.cache_key()
    # name-keyed: every field appears as a (name, value) pair, so two
    # different fields can never positionally alias each other
    names = [k for k, _ in PlanConfig().cache_key()]
    assert names == sorted(names)
    assert set(names) == {f.name for f in dataclasses.fields(PlanConfig)}


def test_planconfig_resident_coerced_hashable():
    cfg = PlanConfig(resident=[0, 1, 2])      # list in, tuple out
    assert cfg.resident == (0, 1, 2)
    hash(cfg.cache_key())                     # cache keys must be hashable


# ---------------------------------------------------------------------------
# Deprecation shims: warn once, same config, same plan, same cache entry
# ---------------------------------------------------------------------------


def test_schedule_shim_warns_exactly_once():
    g = _diamond()
    with pytest.warns(DeprecationWarning, match="serenity.plan"):
        schedule(g, cache=False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # a second warning would raise
        schedule(g, cache=False)


def test_schedule_order_shim_warns_and_orders():
    g = _diamond()
    with pytest.warns(DeprecationWarning):
        res = schedule_order(g, state_quota=4000)
    assert res.exact
    direct = plan(g, PlanConfig(rewrite=False, inplace=False,
                                state_quota=4000), cache=False)
    assert list(res.order) == list(direct.order)


def test_schedule_shim_hits_same_cache_entry_as_plan():
    g = randwire_graph(seed=3, n=12)
    pc = PlanCache()
    with pytest.warns(DeprecationWarning):
        legacy = schedule(g, state_quota=4000, cache=pc)
    direct = plan(g, _legacy_schedule_config(state_quota=4000), cache=pc)
    assert direct is legacy                   # zero-copy cache hit
    assert pc.stats.hits >= 1


def test_legacy_none_quota_passes_through():
    # schedule(state_quota=None) historically meant "unlimited", not the
    # default — the shim must not round it to 20_000
    cfg = _legacy_schedule_config(state_quota=None)
    assert cfg.state_quota is None


def test_execute_legacy_kwargs_warn_and_conflict():
    g = _diamond()
    with pytest.warns(DeprecationWarning, match="execute"):
        ex = execute(g, rewrite=False, cache=False)
    assert ex.realized_matches_plan
    with pytest.raises(TypeError):
        execute(g, config=PlanConfig(), rewrite=False, cache=False)


def test_plan_coresidency_legacy_kwargs_warn_and_conflict():
    gs = [_diamond(), _diamond()]
    with pytest.warns(DeprecationWarning, match="plan_coresidency"):
        shared, results = plan_coresidency(gs, rewrite=False, cache=False)
    assert len(results) == 2
    assert shared.arena_bytes <= shared.sum_member_bytes
    with pytest.raises(TypeError):
        plan_coresidency(gs, config=PlanConfig(), rewrite=False, cache=False)


def test_jaxpr_shim_warns_and_matches_config_call():
    jax = pytest.importorskip("jax")
    jnp = jax.numpy
    from repro.core.jax_bridge import jaxpr_config, schedule_jaxpr

    def f(x):
        return jnp.sum(jnp.tanh(x) * 2.0 + jnp.cos(x))

    closed = jax.make_jaxpr(f)(jnp.ones((8, 8), jnp.float32))
    with pytest.warns(DeprecationWarning, match="schedule_jaxpr"):
        _, legacy = schedule_jaxpr(closed, state_quota=2000, cache=False)
    _, direct = schedule_jaxpr(closed, config=jaxpr_config(state_quota=2000),
                               cache=False)
    assert legacy.order == direct.order
    assert legacy.optimal_peak == direct.optimal_peak


# ---------------------------------------------------------------------------
# Config-keyed caching: different configs miss, recompute plans round-trip
# ---------------------------------------------------------------------------


def test_different_configs_get_different_entries():
    g = randwire_graph(seed=3, n=12)
    pc = PlanCache()
    p1 = plan(g, PlanConfig(rewrite=False), cache=pc)
    p2 = plan(g, PlanConfig(rewrite=True), cache=pc)
    assert p1 is not p2                       # distinct entries, not aliased
    hits0 = pc.stats.hits
    assert plan(g, PlanConfig(rewrite=False), cache=pc) is p1
    assert plan(g, PlanConfig(rewrite=True), cache=pc) is p2
    assert pc.stats.hits == hits0 + 2


def test_recompute_plan_survives_cache_roundtrip(tmp_path):
    g = BENCHMARK_GRAPHS["randwire_cifar10"]()
    cfg = PlanConfig(rewrite=True, recompute=True, recompute_rounds=1,
                     state_quota=4000)
    pc = PlanCache(disk_dir=str(tmp_path))
    cold = plan(g, cfg, cache=pc)
    assert cold.recompute_report is not None
    clones = [(i, recompute_provenance(nd))
              for i, nd in enumerate(cold.graph.nodes)
              if recompute_provenance(nd) is not None]
    assert clones, "randwire_cifar10 round 1 must emit at least one clone"

    # memory tier: zero-copy identity
    assert plan(g, cfg, cache=pc) is cold

    # disk tier: a fresh process-equivalent cache unpickles the same plan,
    # clones and provenance intact
    pc2 = PlanCache(disk_dir=str(tmp_path))
    warm = plan(g, cfg, cache=pc2)
    assert pc2.stats.disk_hits == 1
    assert list(warm.order) == list(cold.order)
    assert warm.peak_bytes == cold.peak_bytes
    assert warm.pareto_frontier == cold.pareto_frontier
    for i, prov in clones:
        nd = warm.graph.nodes[i]
        assert recompute_provenance(nd) == prov
        assert nd.preds == cold.graph.nodes[i].preds

    # the recompute config is part of the key: the no-recompute plan is a
    # different entry with a different (clone-free) graph
    base = plan(g, cfg.replace(recompute=False), cache=pc2)
    assert len(base.graph) < len(cold.graph)


# ---------------------------------------------------------------------------
# The in-tree API lint actually catches what it claims to
# ---------------------------------------------------------------------------


def test_lint_regexes_flag_deprecated_calls_only():
    import importlib.util
    import pathlib

    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "lint_plan_api", root / "tools" / "lint_plan_api.py")
    lint = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(lint)

    flagged = ["res = schedule(g, rewrite=True)",
               "order = schedule_order(g).order",
               "schedule_jaxpr(closed, beam_fallback=False)"]
    clean = ["res = dp_schedule(g, state_quota=100)",
             "k = kahn_schedule(g)",
             "Kahn's schedule (always feasible)",
             "re-schedule (paper Fig. 9)",
             "p = plan(g, PlanConfig(rewrite=True))"]
    for line in flagged:
        assert lint._DEPRECATED_CALL.search(line) or \
            lint._DEPRECATED_KWARG.search(line), line
    for line in clean:
        assert not lint._DEPRECATED_CALL.search(line), line
        assert not lint._DEPRECATED_KWARG.search(line), line
    # and the tree is clean right now
    assert lint.main() == 0
