"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.kernel import rglru_pallas
from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rwkv6.kernel import wkv6_pallas
from repro.kernels.rwkv6.ref import wkv6_ref

TOL = dict(rtol=2e-2, atol=2e-2)      # bf16 sweeps
TOL32 = dict(rtol=2e-5, atol=2e-5)


def _qkv(key, B, Sq, Skv, H, KV, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 64),        # MHA
    (2, 256, 8, 2, 64),        # GQA 4:1
    (1, 128, 4, 1, 128),       # MQA
    (1, 256, 2, 2, 256),       # gemma-style head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_shapes_dtypes(B, S, H, KV, D, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, S, H, KV, D, dtype)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True,
                                 bq=64, bk=64)
    tol = TOL32 if dtype == jnp.float32 else TOL
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_pallas_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 256, 256, 4, 4, 64,
                   jnp.float32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 interpret=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)


def test_flash_pallas_decode_against_cache():
    # decode: 1 new token at position 200 over a 256-buffer w/ 201 valid
    q, k, v = _qkv(jax.random.PRNGKey(2), 2, 128, 256, 4, 4, 64,
                   jnp.float32)
    q1 = q[:, :128]
    ref = attention_ref(q1, k, v, causal=True, q_start=73, kv_len=201)
    out = flash_attention_pallas(q1, k, v, causal=True, q_start=73,
                                 kv_len=201, interpret=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)


def test_flash_xla_matches_ref_chunked():
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 192, 192, 6, 2, 64,
                   jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    for chunk in (48, 64, 192):
        out = flash_attention(q, k, v, causal=True, impl="xla",
                              kv_chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   **TOL32)


def test_flash_xla_mixed_value_dim():
    # MLA-style: qk dim 48, v dim 32
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 48))
    k = jax.random.normal(ks[1], (2, 64, 4, 48))
    v = jax.random.normal(ks[2], (2, 64, 4, 32))
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, impl="xla", kv_chunk=32)
    assert out.shape == (2, 64, 4, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)


# ------------------------------------------------------------------ rwkv6

@pytest.mark.parametrize("B,T,H,N,chunk", [
    (1, 32, 2, 8, 8), (2, 64, 3, 16, 16), (1, 48, 1, 32, 48),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_pallas_sweep(B, T, H, N, chunk, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, N), dtype)
    k = jax.random.normal(ks[1], (B, T, H, N), dtype)
    v = jax.random.normal(ks[2], (B, T, H, N), dtype)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, N)))).astype(
        dtype)
    u = (jax.random.normal(ks[4], (H, N)) * 0.5).astype(dtype)
    o_ref, s_ref = wkv6_ref(r, k, v, w, u)
    o, s = wkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    tol = TOL32 if dtype == jnp.float32 else dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-2, atol=1e-2)


def test_wkv6_initial_state_threading():
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    B, T, H, N = 1, 32, 2, 8
    mk = lambda i: jax.random.normal(ks[i], (B, T, H, N))
    r, k, v = mk(0), mk(1), mk(2)
    w = jnp.exp(-jnp.exp(mk(3)))
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    # full pass == two half passes with threaded state
    o_full, s_full = wkv6_ref(r, k, v, w, u)
    o1, s1 = wkv6_ref(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u)
    o2, s2 = wkv6_ref(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u,
                      initial_state=s1)
    np.testing.assert_allclose(np.asarray(o_full[:, 16:]), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ rglru

@pytest.mark.parametrize("B,T,D,chunk", [(1, 32, 16, 8), (2, 64, 32, 32)])
def test_rglru_pallas_sweep(B, T, D, chunk):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    la = -jnp.exp(jax.random.normal(ks[0], (B, T, D))) * 0.5
    gx = jax.random.normal(ks[1], (B, T, D))
    h0 = jax.random.normal(ks[2], (B, D))
    h_ref, hT_ref = rglru_ref(la, gx, h0)
    h, hT = rglru_pallas(la, gx, h0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), **TOL32)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref), **TOL32)


def test_rglru_associative_scan_equals_ref():
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    la = -jnp.exp(jax.random.normal(ks[0], (2, 128, 24))) * 0.3
    gx = jax.random.normal(ks[1], (2, 128, 24))
    h0 = jax.random.normal(ks[2], (2, 24))
    h_ref, hT_ref = rglru_ref(la, gx, h0)
    h, hT = rglru(la, gx, h0, impl="xla")
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               rtol=1e-4, atol=1e-4)
