"""Direct tests for runtime/fault.py: StepTimer straggler flagging and
FaultTolerantLoop bounded retry / checkpoint restore / SIGTERM shutdown.
"""

from __future__ import annotations

import os
import signal

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step
from repro.runtime.fault import FaultTolerantLoop, StepTimer

# ---------------------------------------------------------------------------
# StepTimer
# ---------------------------------------------------------------------------


class TestStepTimer:
    def test_no_flag_before_warmup(self):
        t = StepTimer()
        for _ in range(6):
            assert not t.observe(0.01)
        # 7 samples < the 8-sample warmup: even a huge step is not flagged
        assert not t.observe(100.0)
        assert t.stragglers == 0
        # the 8th sample crosses the warmup: now it is flagged
        assert t.observe(100.0)
        assert t.stragglers == 1

    def test_flags_outlier_against_moving_median(self):
        t = StepTimer(straggler_factor=2.5)
        for _ in range(10):
            t.observe(0.01)
        assert t.observe(0.1)            # 10x the median
        assert not t.observe(0.02)       # 2x: under the 2.5x factor
        assert t.stragglers == 1

    def test_window_is_bounded(self):
        t = StepTimer(window=8)
        for i in range(50):
            t.observe(0.01 + i * 1e-6)
        assert len(t.history) == 8


# ---------------------------------------------------------------------------
# FaultTolerantLoop
# ---------------------------------------------------------------------------


def _batches(start: int):
    """Deterministic restartable stream: batch i is the float i."""
    i = start
    while True:
        yield float(i)
        i += 1


def _loop(tmp_path, step_fn, **kw) -> FaultTolerantLoop:
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=10)
    return FaultTolerantLoop(step_fn, mgr, _batches, **kw)


def _state():
    return {"w": np.zeros((), np.float64)}


class TestFaultTolerantLoop:
    def test_happy_path_checkpoints_and_counts(self, tmp_path):
        def step(state, batch):
            return {"w": state["w"] + batch}, {"loss": batch}

        loop = _loop(tmp_path, step, ckpt_every=2)
        seen = []
        state, step_no = loop.run(_state(), 0, 5,
                                  on_metrics=lambda s, m: seen.append(s))
        assert step_no == 5
        assert float(state["w"]) == sum(range(5))    # b0..b4
        assert seen == [1, 2, 3, 4, 5]
        assert latest_step(loop.ckpt.dir) == 5       # final save
        assert len(loop.timer.history) == 5

    def test_transient_failure_restores_from_checkpoint(self, tmp_path):
        fails = {3: 1}                                # fail once at step 3

        def step(state, batch):
            step_no = int(round(float(batch)))
            if fails.get(step_no):
                fails[step_no] -= 1
                raise RuntimeError("injected transient step failure")
            return {"w": state["w"] + batch}, {}

        loop = _loop(tmp_path, step, ckpt_every=2, max_retries=3)
        state, step_no = loop.run(_state(), 0, 6)
        # restored from the step-2 checkpoint and replayed: the final state
        # must equal the clean run bit-for-bit (batches are step-indexed)
        assert step_no == 6
        assert float(state["w"]) == sum(range(6))

    def test_retry_budget_exhaustion_raises(self, tmp_path):
        def step(state, batch):
            raise RuntimeError("permanent failure")

        loop = _loop(tmp_path, step, ckpt_every=100, max_retries=2)
        with pytest.raises(RuntimeError, match="permanent failure"):
            loop.run(_state(), 0, 5)

    def test_retry_counter_resets_on_success(self, tmp_path):
        # two separate single-step failures: each is retried independently
        # and must not accumulate toward the retry budget
        fails = {1: 1, 3: 1}

        def step(state, batch):
            step_no = int(round(float(batch)))
            if fails.get(step_no):
                fails[step_no] -= 1
                raise RuntimeError("transient")
            return {"w": state["w"] + batch}, {}

        loop = _loop(tmp_path, step, ckpt_every=1, max_retries=1)
        state, step_no = loop.run(_state(), 0, 5)
        assert step_no == 5
        assert float(state["w"]) == sum(range(5))

    def test_sigterm_checkpoints_before_exit(self, tmp_path):
        prev = signal.getsignal(signal.SIGTERM)
        try:
            stop_at = 3

            def step(state, batch):
                step_no = int(round(float(batch)))
                if step_no == stop_at:
                    # preemption notice mid-training: the handler runs
                    # between steps and must checkpoint before exiting
                    os.kill(os.getpid(), signal.SIGTERM)
                return {"w": state["w"] + batch}, {}

            loop = _loop(tmp_path, step, ckpt_every=100)
            state, step_no = loop.run(_state(), 0, 100)
            assert loop._stop
            assert step_no == stop_at + 1            # stopped early
            # the exit checkpoint holds the full progress so far
            assert latest_step(loop.ckpt.dir) == step_no
            assert float(state["w"]) == sum(range(stop_at + 1))
        finally:
            signal.signal(signal.SIGTERM, prev)
