"""Expert-parallel shard_map MoE (§Perf B2): numerical parity with the
pjit-auto scatter path on a real host mesh."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp
import repro.configs as C
from repro.launch.mesh import rules_for_mesh
from repro.models.zoo import build_model

out = {}
for name in ("granite-moe-3b-a800m", "deepseek-v3-671b"):
    cfg = C.smoke(name)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = rules_for_mesh(mesh)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
    m1 = build_model(cfg)
    params = m1.init(jax.random.PRNGKey(0))
    with mesh:
        l1, _ = jax.jit(lambda p, b: m1.loss_fn(p, b, rules=rules))(
            params, batch)
    cfg2 = dataclasses.replace(cfg, moe_impl="ep_shardmap")
    m2 = build_model(cfg2)
    with mesh:
        l2, _ = jax.jit(lambda p, b: m2.loss_fn(p, b, rules=rules))(
            params, batch)
    out[name] = {"scatter": float(l1), "ep": float(l2)}
print(json.dumps(out))
"""


@pytest.mark.slow
def test_ep_shardmap_matches_scatter_moe():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for name, rec in out.items():
        np.testing.assert_allclose(rec["scatter"], rec["ep"], rtol=2e-2), name
