"""Latency x memory Pareto frontier invariants (DESIGN.md §12).

Seeded + property (hypothesis) coverage of ``pareto_schedule`` and its
``plan(g, PlanConfig(objective='pareto'))`` surface:

* every emitted frontier is strictly non-dominated and monotone
  (makespans strictly increase, peaks strictly decrease),
* the latency-unconstrained endpoint (``frontier.min_peak``) exactly
  equals the serial exact DP peak — width-W concurrency can trade
  latency for memory but can never beat the serial optimum,
* ``max_width=1`` reproduces today's serial schedule bit-for-bit,
* every frontier point replays to its claimed (makespan, peak) through
  the independent step-model simulator,
* budget/latency constraints and the step-model executor integration.

The differential cross-check against the ILP / suffix-enumeration oracle
lives in ``test_differential_pipeline.py``.
"""

from __future__ import annotations

import random

import pytest

from repro.core import (
    Graph,
    NoSolutionError,
    PlanConfig,
    dp_schedule,
    node_costs,
    pareto_schedule,
    plan,
    plan_arena_best,
    simulate_schedule,
    simulate_steps,
    steps_makespan,
)
from repro.core.executor import execute_plan
from repro.graphs import BENCHMARK_GRAPHS


def _random_dag(seed: int, n: int = 9, p: float = 0.35,
                max_size: int = 64) -> Graph:
    rng = random.Random(seed)
    specs = []
    for i in range(n):
        preds = [q for q in range(i) if rng.random() < p]
        # sizes are float32-aligned so the surrogate executor can run them
        specs.append(dict(name=f"n{i}", op="input" if not preds else "op",
                          size_bytes=4 * rng.randint(1, max_size // 4),
                          preds=preds))
    return Graph.build(specs, name=f"pareto_seed{seed}")


def _assert_frontier_invariants(g: Graph, frontier) -> None:
    pts = frontier.points
    assert pts, "frontier must never be empty"
    costs = node_costs(g)
    for a, b in zip(pts, pts[1:]):
        # strict monotonicity <=> strict non-domination for a sorted set
        assert a.makespan < b.makespan, (a.makespan, b.makespan)
        assert a.peak_bytes > b.peak_bytes, (a.peak_bytes, b.peak_bytes)
    for pt in pts:
        assert 1 <= pt.width <= frontier.max_width
        assert g.is_topological(pt.order)
        sim = simulate_steps(g, pt.steps)
        assert sim.peak_bytes == pt.peak_bytes
        assert sim.final_bytes == pt.final_bytes
        assert steps_makespan(g, pt.steps, costs) == pt.makespan


# ---------------------------------------------------------------------------
# seeded sweep
# ---------------------------------------------------------------------------

SEEDS = list(range(20))


@pytest.mark.parametrize("seed", SEEDS)
def test_frontier_nondominated_and_endpoint_exact(seed):
    g = _random_dag(seed)
    serial = dp_schedule(g)
    for W in (2, 3):
        f = pareto_schedule(g, max_width=W)
        _assert_frontier_invariants(g, f)
        assert f.exact
        # the latency-unconstrained endpoint IS the serial DP optimum:
        # any step schedule serializes without raising its peak
        assert f.min_peak.peak_bytes == serial.peak_bytes
        # ... and no point beats the serial peak from below
        assert all(p.peak_bytes >= serial.peak_bytes for p in f.points)
        # makespan can only improve (weakly) with more width
        assert f.min_makespan.makespan <= serial.makespan


@pytest.mark.parametrize("seed", SEEDS[:10])
def test_width1_reproduces_serial_bitforbit(seed):
    g = _random_dag(seed)
    serial = dp_schedule(g)
    f = pareto_schedule(g, max_width=1)
    assert len(f.points) == 1
    pt = f.points[0]
    assert pt.order == serial.order          # bit-for-bit, not just equal peak
    assert pt.steps == tuple((u,) for u in serial.order)
    assert pt.makespan == serial.makespan
    assert pt.peak_bytes == serial.peak_bytes
    assert pt.width == 1 and f.exact


@pytest.mark.parametrize("seed", SEEDS[:8])
def test_simulate_steps_serial_parity(seed):
    """Singleton steps replay identically to the serial footprint model."""
    g = _random_dag(seed)
    order = dp_schedule(g).order
    a = simulate_schedule(g, order)
    b = simulate_steps(g, [(u,) for u in order])
    assert a.peak_bytes == b.peak_bytes
    assert a.final_bytes == b.final_bytes


def test_best_under_budget_selection():
    g = _random_dag(3)
    f = pareto_schedule(g, max_width=3)
    if len(f.points) < 2:
        pytest.skip("frontier collapsed to one point on this seed")
    # unconstrained -> min peak; tight budget -> fastest point; between
    # two adjacent points -> the earlier one
    assert f.best_under(None) is f.min_peak
    assert f.best_under(f.min_makespan.makespan) is f.min_makespan
    mid = f.points[1].makespan
    assert f.best_under(mid) is f.points[1]
    with pytest.raises(NoSolutionError):
        f.best_under(f.min_makespan.makespan - 1)


def test_latency_budget_prunes_frontier():
    g = _random_dag(5)
    full = pareto_schedule(g, max_width=2)
    if len(full.points) < 2:
        pytest.skip("frontier collapsed to one point on this seed")
    cap = full.points[-2].makespan
    capped = pareto_schedule(g, max_width=2, latency_budget=cap)
    assert capped.pairs() == tuple(p for p in full.pairs() if p[0] <= cap)


def test_peak_budget_prunes_frontier():
    g = _random_dag(7)
    full = pareto_schedule(g, max_width=2)
    if len(full.points) < 2:
        pytest.skip("frontier collapsed to one point on this seed")
    cap = full.points[1].peak_bytes
    capped = pareto_schedule(g, max_width=2, budget=cap)
    assert capped.pairs() == tuple(p for p in full.pairs() if p[1] <= cap)


def test_infeasible_budgets_raise():
    g = _random_dag(2)
    f = pareto_schedule(g, max_width=2)
    with pytest.raises(NoSolutionError):
        pareto_schedule(g, max_width=2,
                        latency_budget=f.min_makespan.makespan - 1)
    with pytest.raises(NoSolutionError):
        pareto_schedule(g, max_width=2, budget=f.min_peak.peak_bytes - 1)
    with pytest.raises(NoSolutionError):
        pareto_schedule(g, max_width=1,
                        latency_budget=f.min_peak.makespan - 1)


def test_bad_arguments_rejected():
    g = _random_dag(0)
    with pytest.raises(ValueError):
        pareto_schedule(g, max_width=0)
    with pytest.raises(ValueError):
        pareto_schedule(g, max_width=2, on_quota="bogus")


# ---------------------------------------------------------------------------
# PlanConfig surface
# ---------------------------------------------------------------------------


def test_planconfig_pareto_validation():
    PlanConfig(objective="pareto", max_width=2)           # ok
    with pytest.raises(ValueError):
        PlanConfig(objective="frontier")
    with pytest.raises(ValueError):
        PlanConfig(objective="pareto", max_width=0)
    with pytest.raises(ValueError):
        PlanConfig(max_width=2)                  # width without pareto
    with pytest.raises(ValueError):
        PlanConfig(latency_budget=10)            # budget without pareto
    with pytest.raises(ValueError):
        PlanConfig(objective="pareto", scheduler="kahn")


def test_plan_pareto_threads_frontier_and_steps():
    g = _random_dag(11)
    res = plan(g, PlanConfig(objective="pareto", max_width=2,
                             rewrite=False), cache=False)
    f = res.schedule_frontier
    assert f is not None and res.latency_frontier == f.pairs()
    # unconstrained: the realized plan is the min-peak endpoint
    assert res.steps == f.min_peak.steps
    assert res.makespan == f.min_peak.makespan
    assert res.peak_bytes == f.min_peak.peak_bytes
    serial = plan(g, PlanConfig(rewrite=False), cache=False)
    assert res.peak_bytes == serial.peak_bytes
    assert serial.latency_frontier == () and serial.steps is None
    # latency budget picks the min-peak point that fits
    if len(f.points) >= 2:
        budget = f.points[0].makespan
        fast = plan(g, PlanConfig(objective="pareto", max_width=2,
                                  rewrite=False, latency_budget=budget),
                    cache=False)
        assert fast.makespan <= budget
        assert fast.steps == f.points[0].steps


def test_plan_pareto_rejects_precomputed_order():
    g = _random_dag(1)
    order = dp_schedule(g).order
    with pytest.raises(ValueError):
        plan(g, PlanConfig(objective="pareto", max_width=2), order=order,
             cache=False)


# ---------------------------------------------------------------------------
# arena + executor integration: co-issued outputs are disjoint and the
# realized concurrent peak matches the plan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS[:8])
def test_step_packed_arena_keeps_coissued_disjoint(seed):
    g = _random_dag(seed)
    f = pareto_schedule(g, max_width=3)
    pt = f.min_makespan
    apl = plan_arena_best(g, pt.order, steps=pt.steps)
    assert apl.peak_bytes == pt.peak_bytes
    for st in pt.steps:
        if len(st) < 2:
            continue
        spans = sorted((apl.offset_of(u), apl.offset_of(u) + g.sizes[u], u)
                       for u in st)
        for a, b in zip(spans, spans[1:]):
            assert b[0] >= a[1], \
                f"step {st}: outputs of {a[2]} and {b[2]} overlap"


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_executor_realizes_step_plan(seed):
    """Non-serial frontier point: realized concurrent peak == planned, and
    outputs bit-equal the serial reference interpreter."""
    from repro.core.executor import run_reference

    g = _random_dag(seed)
    f = pareto_schedule(g, max_width=3)
    pt = f.min_makespan
    if pt.width == 1:
        pytest.skip("no co-issue on this seed")
    apl = plan_arena_best(g, pt.order, steps=pt.steps)
    ex = execute_plan(g, pt.order, apl, inputs=None, steps=pt.steps)
    assert ex.realized_peak_bytes == apl.peak_bytes == pt.peak_bytes
    ref = run_reference(g, inputs=None)
    for name, val in ex.outputs.items():
        assert (val == ref[name]).all()


def test_executor_rejects_serial_plan_for_steps():
    """A width-2 step schedule against a serially-packed arena (co-issued
    outputs share bytes) must be refused, not silently corrupted."""
    from repro.core.executor import ExecutorError

    for seed in SEEDS:
        g = _random_dag(seed)
        f = pareto_schedule(g, max_width=3)
        pt = f.min_makespan
        if pt.width == 1:
            continue
        serial_plan = plan_arena_best(g, pt.order)   # no steps= -> serial
        overlaps = False
        for st in pt.steps:
            spans = sorted((serial_plan.offset_of(u),
                            serial_plan.offset_of(u) + g.sizes[u])
                           for u in st)
            overlaps |= any(b[0] < a[1] for a, b in zip(spans, spans[1:]))
        if not overlaps:
            continue
        with pytest.raises(ExecutorError):
            execute_plan(g, pt.order, serial_plan, inputs=None,
                         steps=pt.steps)
        return
    pytest.skip("no seed produced a serially-overlapping co-issue")


# ---------------------------------------------------------------------------
# paper cells: the acceptance criterion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(BENCHMARK_GRAPHS))
def test_paper_cell_endpoint_equals_serial_dp_peak(name):
    g = BENCHMARK_GRAPHS[name]()
    f = pareto_schedule(g, max_width=2, state_quota=20_000, on_quota="beam")
    _assert_frontier_invariants(g, f)
    assert f.min_peak.peak_bytes == dp_schedule(g).peak_bytes, (
        f"{name}: latency-unconstrained endpoint != exact serial DP peak")


# ---------------------------------------------------------------------------
# hypothesis property variants
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # hypothesis is a test extra; the seeded
    pass                       # sweep above still runs without it
else:
    @st.composite
    def random_dags(draw, max_nodes=8):
        n = draw(st.integers(min_value=2, max_value=max_nodes))
        specs = []
        for i in range(n):
            preds = []
            if i > 0:
                k = draw(st.integers(min_value=0, max_value=min(i, 3)))
                preds = sorted(draw(st.sets(
                    st.integers(min_value=0, max_value=i - 1),
                    min_size=min(k, i), max_size=min(k, i),
                )))
            size = draw(st.integers(min_value=1, max_value=64))
            specs.append(dict(name=f"n{i}",
                              op="input" if not preds else "op",
                              size_bytes=size, preds=preds))
        return Graph.build(specs)

    @given(random_dags(), st.integers(min_value=1, max_value=4))
    @settings(max_examples=50, deadline=None)
    def test_property_frontier_invariants(g, W):
        f = pareto_schedule(g, max_width=W)
        _assert_frontier_invariants(g, f)
        assert f.min_peak.peak_bytes == dp_schedule(g).peak_bytes

    @given(random_dags())
    @settings(max_examples=30, deadline=None)
    def test_property_width_monotone(g):
        """More width never hurts either endpoint: min makespan weakly
        improves, min peak stays the serial optimum."""
        prev_ms = None
        serial_peak = dp_schedule(g).peak_bytes
        for W in (1, 2, 3):
            f = pareto_schedule(g, max_width=W)
            assert f.min_peak.peak_bytes == serial_peak
            if prev_ms is not None:
                assert f.min_makespan.makespan <= prev_ms
            prev_ms = f.min_makespan.makespan
