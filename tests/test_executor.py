"""Arena-backed executor: numeric transparency + realized-vs-planned bytes.

The contract under test (DESIGN.md §6): executing a schedule through the
planned arena must (a) reproduce the plain interpreter's outputs exactly,
and (b) realize — measured from executed alloc/free events, not estimated —
a live-byte high-water equal to ``ArenaPlan.peak_bytes`` and a byte extent
equal to ``ArenaPlan.arena_bytes``.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    ExecutorError,
    Graph,
    execute,
    execute_plan,
    plan_arena_best,
    run_reference,
    schedule,
)
from repro.graphs import BENCHMARK_GRAPHS  # noqa: E402
from repro.kernels.arena import arena_accum, arena_read, arena_write  # noqa: E402
from repro.kernels.arena.ref import (  # noqa: E402
    arena_accum_ref,
    arena_read_ref,
    arena_write_ref,
)

PAPER_GRAPHS = ["darts_imagenet_cell", "swiftnet_cell_c", "randwire_cifar10"]


def _inputs(g, seed=0):
    rng = np.random.default_rng(seed)
    return {
        g.nodes[i].name: rng.standard_normal(g.sizes[i] // 4)
        .astype(np.float32)
        for i in g.entries() if g.nodes[i].op == "input"
    }


def _max_err(ref, outs):
    assert set(ref) == set(outs)
    return max(float(jnp.max(jnp.abs(ref[k] - outs[k]))) for k in ref)


# ---------------------------------------------------------------- acceptance

@pytest.mark.parametrize("name", PAPER_GRAPHS)
@pytest.mark.parametrize("rewrite", [False, True], ids=["plain", "rewritten"])
def test_execute_matches_reference_and_realizes_plan(name, rewrite):
    res = schedule(BENCHMARK_GRAPHS[name](), rewrite=rewrite,
                   inplace=rewrite, compute_baselines=False)
    g = res.graph
    inputs = _inputs(g)
    ref = run_reference(g, inputs)
    ex = execute_plan(g, res.order, res.arena, inputs)
    assert _max_err(ref, ex.outputs) <= 1e-5
    # realized == planned, exactly (strict=True above already asserted it)
    assert ex.realized_peak_bytes == res.arena.peak_bytes
    assert ex.realized_arena_bytes == res.arena.arena_bytes
    assert ex.realized_matches_plan


def test_execute_convenience_schedules_when_no_plan():
    g = BENCHMARK_GRAPHS["swiftnet_cell_c"]()
    ex = execute(g, _inputs(g))
    assert ex.realized_matches_plan
    with pytest.raises(ExecutorError, match="order"):
        res = schedule(g, compute_baselines=False)
        execute(res.graph, _inputs(res.graph), res.arena)


# ------------------------------------------------------- rewritten aliasing

def _concat_depthconv_graph():
    return Graph.build([
        dict(name="i", op="input", size_bytes=64),
        dict(name="a", op="conv", size_bytes=64, preds=[0]),
        dict(name="b", op="conv", size_bytes=128, preds=[0]),
        dict(name="cc", op="concat", size_bytes=192, preds=[1, 2]),
        dict(name="dw", op="depthconv", size_bytes=192, preds=[3]),
        dict(name="out", op="op", size_bytes=32, preds=[4]),
    ])


def test_concat_view_executes_without_materializing():
    res = schedule(_concat_depthconv_graph(), compute_baselines=False,
                   cache=False)
    g = res.graph
    assert any(nd.op == "concat_view" for nd in g.nodes)
    x = {"i": np.linspace(-1.0, 1.0, 16, dtype=np.float32)}
    ref = run_reference(g, x)
    ex = execute_plan(g, res.order, res.arena, x)
    assert _max_err(ref, ex.outputs) == 0.0
    assert ex.realized_matches_plan
    # the parts sit back-to-back inside the view's buffer
    view = next(nd for nd in g.nodes if nd.op == "concat_view")
    offs = sorted(res.arena.offset_of(p) for p in view.preds)
    assert offs[0] == res.arena.offset_of(view.id)
    sizes = sorted((res.arena.offset_of(p), g.sizes[p]) for p in view.preds)
    assert sizes[0][0] + sizes[0][1] == sizes[1][0]


def test_mixed_alias_concat_view_is_refused():
    # a concat_view aliasing only SOME preds has no arena layout for the
    # rest: the executor must refuse instead of silently zero-filling
    g = Graph.build([
        dict(name="i", op="input", size_bytes=32),
        dict(name="a", op="conv", size_bytes=32, preds=[0]),
        dict(name="b", op="conv", size_bytes=32, preds=[0]),
        dict(name="v", op="concat_view", size_bytes=64, preds=[1, 2],
             alias_preds=[1]),
    ])
    from repro.core import kahn_schedule
    order = kahn_schedule(g).order
    plan = plan_arena_best(g, order)
    with pytest.raises(ExecutorError, match="not all aliased"):
        execute_plan(g, order, plan, inputs=None)
    # the reference interpreter still defines its semantics
    assert "v" in run_reference(g, None)


def test_pallas_interpret_path_matches_xla_path():
    res = schedule(_concat_depthconv_graph(), compute_baselines=False,
                   cache=False)
    x = {"i": np.linspace(-1.0, 1.0, 16, dtype=np.float32)}
    a = execute_plan(res.graph, res.order, res.arena, x, impl="xla")
    b = execute_plan(res.graph, res.order, res.arena, x, impl="pallas",
                     interpret=True)
    assert _max_err(a.outputs, b.outputs) == 0.0


@pytest.mark.parametrize("name", ["swiftnet_cell_c"])
def test_pallas_interpret_on_rewritten_cell(name):
    # covers the in-place accumulate kernel on real partial-conv chains
    res = schedule(BENCHMARK_GRAPHS[name](), compute_baselines=False)
    ref = run_reference(res.graph, _inputs(res.graph))
    ex = execute_plan(res.graph, res.order, res.arena, _inputs(res.graph),
                      impl="pallas", interpret=True)
    assert _max_err(ref, ex.outputs) == 0.0
    assert ex.realized_matches_plan


def test_jit_and_donated_arena():
    res = schedule(_concat_depthconv_graph(), compute_baselines=False,
                   cache=False)
    x = {"i": np.linspace(-1.0, 1.0, 16, dtype=np.float32)}
    ref = run_reference(res.graph, x)
    arena = jnp.zeros(-(-res.arena.arena_bytes // 4), jnp.float32)
    ex = execute_plan(res.graph, res.order, res.arena, x, arena=arena,
                      jit=True)
    assert _max_err(ref, ex.outputs) <= 1e-5
    # an undersized donated arena is rejected up front
    with pytest.raises(ExecutorError, match="donated arena"):
        execute_plan(res.graph, res.order, res.arena, x,
                     arena=jnp.zeros(3, jnp.float32))


def test_strict_catches_plan_schedule_mismatch():
    g = BENCHMARK_GRAPHS["randwire_cifar10"]()
    res = schedule(g, rewrite=False, compute_baselines=False)
    # a different (valid) schedule does not realize this plan's lifetimes
    other = g.topo_order()
    if other == res.order:
        pytest.skip("topo order equals DP order on this seed")
    with pytest.raises(ExecutorError, match="realized arena diverges"):
        execute_plan(res.graph, other, res.arena, _inputs(res.graph))


# ------------------------------------------------------------ arena kernels

@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_arena_ops_match_ref_oracle(impl):
    rng = np.random.default_rng(3)
    arena = jnp.asarray(rng.standard_normal(32).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(5).astype(np.float32))
    kw = dict(impl=impl, interpret=True)
    np.testing.assert_array_equal(
        arena_write(arena, x, 7, **kw), arena_write_ref(arena, x, 7))
    np.testing.assert_allclose(
        arena_accum(arena, x, 7, **kw), arena_accum_ref(arena, x, 7),
        rtol=1e-6)
    np.testing.assert_array_equal(
        arena_read(arena, 7, 5, **kw), arena_read_ref(arena, 7, 5))


# ------------------------------------------------------------- real tensors

def test_pack_unpack_roundtrip_mixed_dtypes():
    from repro.core.executor import pack_buffers, unpack_buffer
    from repro.core import kahn_schedule

    arrays = {
        0: jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4)),
        1: jnp.asarray(np.arange(8, dtype=np.int32)),
        2: jnp.asarray(np.linspace(0, 1, 16, dtype=np.float32)
                       .astype(jnp.bfloat16)),
    }
    specs = [dict(name=f"b{i}", op="cache",
                  size_bytes=int(np.prod(a.shape)) * a.dtype.itemsize,
                  preds=[]) for i, a in arrays.items()]
    specs.append(dict(name="sink", op="act", size_bytes=8,
                      preds=[0, 1, 2]))
    g = Graph.build(specs)
    plan = plan_arena_best(g, kahn_schedule(g).order)
    arena = pack_buffers(plan, arrays)
    assert arena.dtype == jnp.uint8 and arena.shape[0] == plan.arena_bytes
    for nid, a in arrays.items():
        back = unpack_buffer(arena, plan, nid, a.shape, a.dtype)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(a))


# -------------------------------------------------------------- jaxpr path

def test_compile_scheduled_nas_like():
    from repro.core.jax_bridge import compile_scheduled

    def nas_like(x):
        branches = []
        for i in range(4):
            h = jnp.tanh(x * (i + 1.0))
            h = h @ jnp.ones((x.shape[-1], 2 * x.shape[-1]), x.dtype)
            h = jax.nn.relu(h) @ jnp.ones((2 * x.shape[-1], 8), x.dtype)
            branches.append(h)
        return jnp.sum(jnp.concatenate(branches, -1) ** 2)

    x = jnp.ones((16, 32), jnp.float32)
    fn = compile_scheduled(nas_like, cache=False)
    y = fn(x)                      # asserts equivalence internally too
    assert jnp.allclose(y, nas_like(x), atol=1e-5)
    r = fn.report
    assert r.realized_bytes == r.optimal_peak > 0
    assert r.realized_matches_plan
    assert r.arena_bytes >= r.optimal_peak


def test_compile_scheduled_mixed_dtypes_and_pytree():
    from repro.core.jax_bridge import compile_scheduled

    def mixed(a, b):
        c = (a * 2).astype(jnp.bfloat16)
        d = jnp.sum(c.astype(jnp.float32)) + b
        return {"c": c, "d": d, "count": (a > 0).sum()}

    fn = compile_scheduled(mixed, cache=False)
    a = jnp.linspace(-1, 1, 40).reshape(5, 8)
    out = fn(a, jnp.float32(3.0))
    assert out["c"].dtype == jnp.bfloat16
    assert fn.report.realized_matches_plan
    assert fn.report.n_env_bypassed >= 1          # the bool intermediate
