"""Fused alias-chain execution (DESIGN.md §11).

The contract under test: fusing in-place alias chains — forwarding the
running value in registers between chain members and storing only the
region tail (one chain-kernel launch for contiguous elementwise runs) —
must change *nothing observable*: outputs stay bit-equal to
``run_reference`` on every impl path, and the realized peak/extent stay
exactly the planned bytes (the skipped interior stores land in the chain's
own already-reserved slice, so no liveness event moves).
"""

import pickle

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    PlanConfig,
    compile_plan,
    execute_plan,
    fuse_alias_chains,
    plan,
    run_reference,
)
from repro.graphs import BENCHMARK_GRAPHS  # noqa: E402
from repro.kernels.arena import (  # noqa: E402
    arena_accum,
    arena_chain_write,
    arena_read,
    arena_write,
)
from repro.kernels.arena.ops import ENV_IMPL, _resolve  # noqa: E402
from repro.kernels.arena.ref import (  # noqa: E402
    arena_accum_ref,
    arena_chain_write_ref,
    arena_read_ref,
    arena_write_ref,
)

PAPER_GRAPHS = ["darts_imagenet_cell", "swiftnet_cell_c", "randwire_cifar10"]


def _planned(name):
    res = plan(BENCHMARK_GRAPHS[name](), PlanConfig(), cache=False)
    return res.graph, res.order, res.arena


# ----------------------------------------------------------- region algebra


@pytest.mark.parametrize("name", PAPER_GRAPHS)
def test_regions_partition_schedule(name):
    g, order, apl = _planned(name)
    regions = fuse_alias_chains(g, order, apl)
    flat = [u for r in regions for u in r.node_ids]
    assert sorted(flat) == sorted(order), "regions must cover order exactly"
    pos = {u: i for i, u in enumerate(order)}
    for r in regions:
        assert r.head == r.node_ids[0] and r.out == r.node_ids[-1]
        for u, v in zip(r.node_ids, r.node_ids[1:]):
            # every link is a true in-place alias step on the same slice
            assert pos[u] < pos[v]
            assert set(g.nodes[v].alias_preds) == {u}
            assert g.sizes[u] == g.sizes[v]
            assert apl.offset_of(u) == apl.offset_of(v)
            assert "concat_view" not in (g.nodes[u].op, g.nodes[v].op)
        for u in r.node_ids[:-1]:
            # value forwarding is legal only under the single-consumer
            # invariant of aliased predecessors
            assert len(g.succs[u]) == 1


def test_paper_cells_actually_fuse():
    # the rewriter's chains survive planning on every paper workload: unary
    # elementwise runs on DARTS, partial-conv accumulation (which the DP
    # schedules non-contiguously) on SwiftNet
    members = {}
    for name in PAPER_GRAPHS:
        g, order, apl = _planned(name)
        prog = compile_plan(g, order, apl, fuse=True)
        members[name] = prog.n_fused_nodes
        assert prog.n_regions + prog.n_fused_nodes == len(order)
    assert members["darts_imagenet_cell"] >= 20
    assert members["swiftnet_cell_c"] >= 4
    assert all(v >= 1 for v in members.values())


def test_fuse_alias_chains_empty_and_unaliased():
    g, order, apl = _planned("randwire_cifar10")
    assert fuse_alias_chains(g, [], apl) == []
    singles = fuse_alias_chains(
        g, [u for u in order if not g.nodes[u].alias_preds], apl)
    assert all(len(r) == 1 for r in singles)


# ----------------------------------------------------- fused == reference


@pytest.mark.parametrize("name", PAPER_GRAPHS)
@pytest.mark.parametrize("impl,interpret",
                         [("xla", False), ("pallas", True)],
                         ids=["xla", "pallas-interpret"])
def test_fused_matches_reference_and_realizes_plan(name, impl, interpret):
    g, order, apl = _planned(name)
    prog = compile_plan(g, order, apl, fuse=True, impl=impl,
                        interpret=interpret)
    ref = run_reference(g)
    r = prog.run()
    assert r.fused and r.n_regions == prog.n_regions
    assert r.realized_peak_bytes == apl.peak_bytes
    assert r.realized_arena_bytes == apl.arena_bytes
    assert set(r.outputs) == set(ref)
    for k, v in ref.items():
        if impl == "xla":
            # the xla chain path issues the same eager op sequence as the
            # slice-per-node executor: bit-equal by construction
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(r.outputs[k]),
                err_msg=f"{name}/{impl}: fused output {k!r} diverges")
        else:
            # inside a single Pallas kernel XLA may contract a chain's
            # mul+add into an fma: last-ulp tolerance, not bit-equality
            np.testing.assert_allclose(
                np.asarray(r.outputs[k]), np.asarray(v),
                rtol=2e-6, atol=1e-6,
                err_msg=f"{name}/{impl}: fused output {k!r} diverges")


def test_fused_jit_reuses_trace_and_stays_close():
    g, order, apl = _planned("darts_imagenet_cell")
    prog = compile_plan(g, order, apl, fuse=True)
    ref = run_reference(g)
    r1 = prog.run(jit=True)
    traced = prog._jitted
    assert traced is not None
    r2 = prog.run(jit=True)
    assert prog._jitted is traced, "steady-state call must reuse the trace"
    # jit reassociates float math (XLA), so the jit contract is allclose,
    # not bit-equality (matches the unfused executor's jit contract)
    for k, v in ref.items():
        np.testing.assert_allclose(np.asarray(r2.outputs[k]), np.asarray(v),
                                   rtol=2e-5, atol=2e-6)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(r1.outputs[k]),
                                      np.asarray(r2.outputs[k]))


# ------------------------------------------------------ kernel-level parity


_ODD_SPANS = [(0, 5), (1, 7), (13, 11), (36, 1), (7, 0)]   # (offset, n)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.int8],
                         ids=["f32", "i32", "i8"])
def test_arena_ops_parity_odd_spans_and_dtypes(impl, dtype):
    rng = np.random.default_rng(11)
    kw = dict(impl=impl, interpret=True)
    arena_np = rng.integers(-40, 40, 37).astype(dtype)
    arena = jnp.asarray(arena_np)
    for offset, n in _ODD_SPANS:
        x_np = rng.integers(-40, 40, n).astype(dtype)
        x = jnp.asarray(x_np)
        np.testing.assert_array_equal(
            arena_write(arena, x, offset, **kw),
            arena_write_ref(arena_np, x_np, offset),
            err_msg=f"write {impl} {dtype} @{offset}+{n}")
        np.testing.assert_array_equal(
            arena_read(arena, offset, n, **kw),
            arena_read_ref(arena_np, offset, n),
            err_msg=f"read {impl} {dtype} @{offset}+{n}")
        np.testing.assert_array_equal(
            arena_accum(arena, x, offset, **kw),
            arena_accum_ref(arena_np, x_np, offset),
            err_msg=f"accum {impl} {dtype} @{offset}+{n}")


_CHAINS = [(), ("relu",), ("bn", "relu6"), ("sigmoid", "scale", "bias_add"),
           ("gelu", "tanh", "silu", "identity")]


@pytest.mark.parametrize("ops", _CHAINS, ids=lambda c: "+".join(c) or "empty")
def test_chain_write_parity(ops):
    rng = np.random.default_rng(5)
    arena_np = rng.standard_normal(41).astype(np.float32)
    arena = jnp.asarray(arena_np)
    for offset, n in _ODD_SPANS:
        x_np = rng.standard_normal(n).astype(np.float32)
        x = jnp.asarray(x_np)
        got_xla = arena_chain_write(arena, x, offset, ops, impl="xla")
        got_pal = arena_chain_write(arena, x, offset, ops, impl="pallas",
                                    interpret=True)
        # pallas composes the same jnp callables, but inside one kernel XLA
        # may contract mul+add chains into fmas: last-ulp tolerance
        np.testing.assert_allclose(
            got_pal, got_xla, rtol=2e-6, atol=1e-6,
            err_msg=f"xla vs pallas {ops} @{offset}+{n}")
        # the numpy twin is an independent oracle: allclose ground truth
        np.testing.assert_allclose(
            got_xla, arena_chain_write_ref(arena_np, x_np, offset, ops),
            rtol=1e-5, atol=1e-6, err_msg=f"ref oracle {ops} @{offset}+{n}")


def test_chain_write_rejects_unknown_op():
    arena = jnp.zeros(8, jnp.float32)
    with pytest.raises(KeyError):
        arena_chain_write(arena, jnp.ones(3, jnp.float32), 0,
                          ("not_an_op",), impl="xla")


# ------------------------------------------------------------ env override


def test_env_impl_override(monkeypatch):
    monkeypatch.delenv(ENV_IMPL, raising=False)
    assert _resolve("xla", False) == ("xla", False)
    monkeypatch.setenv(ENV_IMPL, "ref")
    assert _resolve("auto", False) == ("ref", False)
    # explicit impl always beats the env
    assert _resolve("xla", False) == ("xla", False)
    monkeypatch.setenv(ENV_IMPL, "pallas_interpret")
    assert _resolve("auto", False) == ("pallas", True)
    monkeypatch.setenv(ENV_IMPL, "pallas-interpret")
    assert _resolve("auto", False) == ("pallas", True)
    monkeypatch.setenv(ENV_IMPL, "xla")
    assert _resolve("auto", True) == ("xla", True)
    monkeypatch.setenv(ENV_IMPL, "cuda")
    with pytest.raises(ValueError, match="REPRO_ARENA_IMPL"):
        _resolve("auto", False)


def test_env_impl_override_is_read_per_call(monkeypatch):
    arena, x = jnp.zeros(8, jnp.float32), jnp.ones(3, jnp.float32)
    monkeypatch.setenv(ENV_IMPL, "bogus")
    with pytest.raises(ValueError, match="bogus"):
        arena_write(arena, x, 2)
    monkeypatch.setenv(ENV_IMPL, "ref")
    np.testing.assert_array_equal(arena_write(arena, x, 2),
                                  arena_write_ref(arena, x, 2))
    monkeypatch.delenv(ENV_IMPL)
    np.testing.assert_array_equal(arena_write(arena, x, 2),
                                  arena_write_ref(arena, x, 2))


# ------------------------------------------------------- program memoization


def test_compile_plan_memoizes_on_plan():
    g, order, apl = _planned("swiftnet_cell_c")
    p1 = compile_plan(g, order, apl, fuse=True)
    assert compile_plan(g, order, apl, fuse=True) is p1
    p2 = compile_plan(g, order, apl, fuse=False)
    assert p2 is not p1
    assert compile_plan(g, order, apl, fuse=False) is p2
    # execute_plan routes through the same cache
    r = execute_plan(g, order, apl, fuse=True)
    for k, v in run_reference(g).items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(r.outputs[k]))
    assert "_programs" in apl.__dict__


def test_plan_pickling_drops_program_cache():
    g, order, apl = _planned("randwire_cifar10")
    compile_plan(g, order, apl)
    assert "_programs" in apl.__dict__
    apl2 = pickle.loads(pickle.dumps(apl))
    assert "_programs" not in apl2.__dict__
    assert apl2.arena_bytes == apl.arena_bytes
    # and the thawed plan can compile fresh programs
    r = execute_plan(g, order, apl2, fuse=True)
    assert r.realized_arena_bytes == apl2.arena_bytes
