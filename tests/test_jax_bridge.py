"""SERENITY-JAX bridge: semantics preservation + footprint reduction."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jax_bridge import (
    analyze_fn,
    jaxpr_to_graph,
    memory_aware_remat,
    serenity_transform,
)


def _wide(x):
    hs = [jnp.tanh(x * i) @ jnp.ones((64, 256)) for i in range(1, 5)]
    return sum((h @ jnp.ones((256, 4))).sum() for h in hs)


def test_jaxpr_graph_sizes():
    x = jnp.ones((8, 64))
    closed = jax.make_jaxpr(_wide)(x)
    g, eqn_nodes = jaxpr_to_graph(closed)
    assert len(eqn_nodes) == len(closed.jaxpr.eqns)
    # invars present as inputs
    assert g.nodes[0].op == "input"
    assert g.nodes[0].size_bytes == 8 * 64 * 4


def test_transform_preserves_semantics_and_jits():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    f2 = serenity_transform(_wide)
    np.testing.assert_allclose(np.asarray(_wide(x)), np.asarray(f2(x)),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(jax.jit(f2)(x)),
                               np.asarray(_wide(x)), rtol=1e-5)
    assert f2.report is not None
    assert f2.report.optimal_peak <= f2.report.original_peak


def test_transform_reduces_bad_trace_order():
    x = jnp.ones((8, 64))
    rep = analyze_fn(_wide, x)
    assert rep.optimal_peak < rep.original_peak     # expansions interleave


def test_transform_with_grad():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))

    def loss(x):
        return _wide(x)

    f2 = serenity_transform(loss)
    g1 = jax.grad(loss)(x)
    g2 = jax.grad(f2)(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


def test_nas_cell_sized_jaxpr_schedules_exactly():
    """Regression gate for the beam fallback: the 45-eqn NAS-cell pattern
    (six expand/project branches into one concat) used to exhaust the DP
    quota and silently fall back to beam (`exact=False`, reduction 1.0).
    With hierarchical decomposition + branch-and-bound it must schedule
    exactly at the default quota — if this flips back to False, the
    pruning has regressed."""

    def nas_cell(x):
        branches = []
        for i in range(6):
            h = jnp.tanh(x * (i + 1.0))
            h = h @ jnp.ones((x.shape[-1], 4 * x.shape[-1]), x.dtype)
            h = jax.nn.relu(h)
            h = h @ jnp.ones((4 * x.shape[-1], 16), x.dtype)
            branches.append(h)
        return jnp.sum(jnp.concatenate(branches, -1) ** 2)

    x = jnp.ones((64, 128), jnp.float32)
    rep = analyze_fn(nas_cell, x, cache=False)
    assert rep.n_eqns >= 40                     # the pattern actually traced
    assert rep.exact, "NAS-cell jaxpr fell back to beam (exact=False)"
    assert rep.optimal_peak <= rep.original_peak


def test_memory_aware_remat_decision():
    x = jnp.ones((8, 64))
    fn_lo, dec_lo = memory_aware_remat(_wide, 10**12, x)
    assert not dec_lo["remat"]
    fn_hi, dec_hi = memory_aware_remat(_wide, 1, x)
    assert dec_hi["remat"]
    np.testing.assert_allclose(np.asarray(fn_hi(x)), np.asarray(_wide(x)),
                               rtol=1e-5)
