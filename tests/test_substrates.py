"""Data pipeline, optimizers, gradient compression, checkpointing,
fault-tolerant loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.data import DataPipeline
from repro.optim import adafactor, adamw
from repro.optim.grad_compress import ef_compress, init_error, quantize
from repro.runtime import FaultTolerantLoop


def test_pipeline_deterministic_and_sharded():
    cfg = C.smoke("llama3.2-1b")
    p1 = DataPipeline(cfg=cfg, seq_len=16, global_batch=8, seed=3)
    p2 = DataPipeline(cfg=cfg, seq_len=16, global_batch=8, seed=3)
    np.testing.assert_array_equal(p1.batch_at(5)["tokens"],
                                  p2.batch_at(5)["tokens"])
    # different steps differ
    assert not np.array_equal(p1.batch_at(5)["tokens"],
                              p1.batch_at(6)["tokens"])
    # process sharding partitions the global batch
    shards = [
        DataPipeline(cfg=cfg, seq_len=16, global_batch=8, seed=3,
                     n_processes=2, process_index=i).batch_at(0)["tokens"]
        for i in range(2)
    ]
    assert shards[0].shape == (4, 16)
    assert not np.array_equal(shards[0], shards[1])


def test_pipeline_resume_matches():
    cfg = C.smoke("llama3.2-1b")
    p = DataPipeline(cfg=cfg, seq_len=8, global_batch=4)
    it = p.iter_from(10)
    np.testing.assert_array_equal(next(it)["tokens"],
                                  p.batch_at(10)["tokens"])


def _quad_params():
    return {"w": jnp.array([2.0, -1.5, 0.5]), "b": jnp.zeros(())}


def _quad_loss(p):
    return jnp.sum((p["w"] - 1.0) ** 2) + (p["b"] - 2.0) ** 2


@pytest.mark.parametrize("opt_cls", [adamw, adafactor])
def test_optimizers_converge_on_quadratic(opt_cls):
    opt = opt_cls(lr=0.1, weight_decay=0.0)
    params = _quad_params()
    state = opt.init(params)
    for _ in range(300):
        grads = jax.grad(_quad_loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(_quad_loss(params)) < 1e-2


def test_adafactor_state_is_factored():
    opt = adafactor()
    p = {"mat": jnp.zeros((64, 32)), "vec": jnp.zeros((64,))}
    st = opt.init(p)
    assert st["v"]["mat"]["vr"].shape == (64,)
    assert st["v"]["mat"]["vc"].shape == (32,)
    assert st["v"]["vec"]["v"].shape == (64,)


def test_quantize_roundtrip_accuracy():
    x = jnp.linspace(-3, 3, 1000)
    q, s = quantize(x)
    err = jnp.abs(q.astype(jnp.float32) * s - x).max()
    assert float(err) <= float(s)      # within one quantization step


def test_error_feedback_unbiased_over_steps():
    # with EF, the *accumulated* applied update converges to the true sum
    g = {"w": jnp.full((128,), 0.003)}
    err = init_error(g)
    applied = jnp.zeros((128,))
    for _ in range(50):
        gq, err = ef_compress(g, err)
        applied = applied + gq["w"]
    np.testing.assert_allclose(np.asarray(applied),
                               np.full(128, 0.15), rtol=0.05)


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.float32)}}
    d = str(tmp_path / "ck")
    save(d, 7, tree)
    assert latest_step(d) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    out = restore(d, 7, like)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
    assert not any(f.startswith("tmp.") for f in os.listdir(d))


def test_checkpoint_manager_gc_and_async(tmp_path):
    m = CheckpointManager(str(tmp_path / "ck"), keep=2)
    for s in (10, 20, 30):
        m.save_async(s, {"x": jnp.full((2,), s)})
    m.wait()
    m.save(40, {"x": jnp.full((2,), 40)})
    assert latest_step(m.dir) == 40
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(m.dir))
    assert len(steps) == 2


def test_fault_tolerant_loop_recovers(tmp_path):
    """A step that crashes once mid-run must resume from the checkpoint."""
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 7:
            raise RuntimeError("injected preemption")
        return {"x": state["x"] + 1}, {"loss": state["x"]}

    m = CheckpointManager(str(tmp_path / "ck"), keep=2)

    def batches(start):
        while True:
            yield {}

    loop = FaultTolerantLoop(step, m, batches, ckpt_every=2, max_retries=2)
    state, end = loop.run({"x": jnp.zeros(())}, 0, 10)
    assert end == 10
    assert calls["n"] >= 11           # one extra call for the failed step
    assert float(state["x"]) == 10.0 or float(state["x"]) >= 9.0
